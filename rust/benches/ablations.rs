//! Ablations — the design choices DESIGN.md §6 calls out.
//!
//! 1. **Disk vs in-memory pod building** (paper §6 future work, here
//!    implemented): disk mode reproduces the I/O bottleneck the paper
//!    measured; memory mode is their prototyped fix. Expect memory mode
//!    to cut OVH and raise TH, most strongly for SCPP.
//! 2. **Bulk vs per-task submission** (paper §3.2: bulk "reduces the
//!    communication between Hydra and the provider"): simulated platform
//!    API cost of 1 batch vs N batches.
//! 3. **Concurrent vs sequential provider managers** (Exp 2's design
//!    point): same 4-provider workload through the service proxy vs a
//!    serial loop (CloudBridge/CloudMesh-style unified API without
//!    brokering concurrency).

mod common;

use common::*;
use hydra::broker::{BrokerPolicy, PartitionModel, PodBuildMode};
use hydra::sim::kubernetes::{ClusterSpec, KubernetesSim, PodSpec};
use hydra::sim::provider::{PlatformProfile, ProviderId};
use hydra::util::Stopwatch;

const TASKS: usize = 16_000;

fn main() {
    header("A", "design ablations", "DESIGN.md §6");

    // ---- 1. disk vs memory pod building -----------------------------------
    println!("\n--- Ablation 1: pod manifest build mode (16K tasks, one provider) ---");
    println!("{:<6} {:<8} {:>16} {:>14}", "MODEL", "MODE", "OVH (ms)", "TH (task/s)");
    let staging = std::env::temp_dir().join(format!("hydra-abl-{}", std::process::id()));
    let mut improvements = Vec::new();
    for model in [PartitionModel::Mcpp { max_cpp: 16 }, PartitionModel::Scpp] {
        let mut ovh_by_mode = Vec::new();
        for (name, mode) in [
            ("disk", PodBuildMode::Disk { staging_dir: staging.clone() }),
            ("memory", PodBuildMode::Memory),
        ] {
            let p = measure(|seed| {
                let hydra = hydra::broker::Hydra::builder()
                    .simulated_provider(ProviderId::Jetstream2)
                    .resource(hydra::api::ResourceRequest::kubernetes(
                        ProviderId::Jetstream2, 1, 16,
                    ))
                    .partition_model(model)
                    .build_mode(mode.clone())
                    .seed(seed)
                    .build()
                    .unwrap();
                hydra
                    .submit(noop_containers(TASKS), &BrokerPolicy::RoundRobin)
                    .unwrap()
                    .aggregate
            });
            println!("{:<6} {:<8} {:>16} {:>14.0}", model.short_name(), name,
                     fmt_ms(&p.ovh), p.th.mean);
            ovh_by_mode.push(p.ovh.mean);
        }
        let gain = ovh_by_mode[0] / ovh_by_mode[1];
        improvements.push((model.short_name(), gain));
    }
    for (model, gain) in &improvements {
        println!("  {model}: in-memory building cuts OVH {gain:.1}x (paper §6 expectation)");
    }
    std::fs::remove_dir_all(&staging).ok();

    // ---- 2. bulk vs per-task submission ------------------------------------
    println!("\n--- Ablation 2: bulk vs per-pod API submission (broker-blocking time) ---");
    // Paper §3.2: submitting "in a single batch ... reduces the
    // communication between Hydra and the provider, reducing Hydra's
    // overheads and increasing its throughput". Each API round-trip blocks
    // the manager for `api_batch_base_s`; a bulk call pays it once plus a
    // marginal per-object cost.
    let profile = PlatformProfile::of(ProviderId::Aws);
    let n_pods = 4000usize;
    let bulk_s = profile.api_batch_base_s + profile.api_per_object_s * n_pods as f64;
    let per_task_s = (profile.api_batch_base_s + profile.api_per_object_s) * n_pods as f64;
    println!("  bulk submission   : 1 call, {bulk_s:.1}s of broker-blocking API time");
    println!("  per-pod submission: {n_pods} calls, {per_task_s:.1}s ({:.0}x worse)",
             per_task_s / bulk_s);
    // The platform-side makespan is unaffected (submission overlaps
    // execution), which we verify with the simulator:
    let cluster = ClusterSpec::uniform(1, 16);
    let pods: Vec<PodSpec> = (0..n_pods as u64)
        .map(|i| PodSpec {
            id: i,
            containers: vec![hydra::sim::kubernetes::ContainerSpec::noop(i)],
        })
        .collect();
    let mut sim = KubernetesSim::new(profile.clone(), cluster, 1);
    sim.submit(pods, 0.0);
    let bulk_tpt = sim.run().makespan_s;
    println!("  (platform TPT itself stays ~{bulk_tpt:.0}s either way; the win is broker TH)");

    // ---- 3. concurrent vs sequential managers ------------------------------
    println!("\n--- Ablation 3: concurrent vs sequential provider managers (4x4K tasks) ---");
    let conc = measure(|seed| {
        let hydra = clouds_hydra(PartitionModel::Scpp, seed);
        hydra
            .submit(noop_containers(TASKS), &BrokerPolicy::RoundRobin)
            .unwrap()
            .aggregate
    });
    // Sequential: four single-provider runs one after the other.
    let mut seq_wall = Vec::new();
    for trial in 0..TRIALS {
        let sw = Stopwatch::start();
        for p in ProviderId::CLOUDS {
            let _ = run_cloud_point(p, TASKS / 4, 16, PartitionModel::Scpp, 0x5E0 + trial);
        }
        seq_wall.push(sw.elapsed_secs());
    }
    let seq = hydra::util::stats::Summary::of(&seq_wall);
    println!("  concurrent broker window (max provider OVH): {:.1}ms", conc.ovh.mean * 1e3);
    println!("  sequential loop wall time                  : {:.1}ms", seq.mean * 1e3);
    println!("  (on a 1-core host these converge; with >=4 cores the concurrent");
    println!("   window approaches a single provider's OVH — the paper's 4x TH)");
}
