//! Experiment 3B — Cross-Platform Scalability, heterogeneous (paper §5.3,
//! Fig. 4 bottom).
//!
//! 10,240 heterogeneous tasks (1–10 s, 1–4 CPUs, 0–8 GPUs; containers and
//! executables) on 2/4/6-node Kubernetes clusters plus the Bridges2 pilot,
//! SCPP. Short tasks at small sizes are the paper's "worst case" for the
//! broker.
//!
//! Expected shapes: OVH ~ +5% above 2 nodes then flat; TH invariant in
//! node count; TPT scales linearly 2→4 nodes, sublinearly 4→6 (Kubernetes
//! overheads).

mod common;

use common::*;
use hydra::api::task::Payload;
use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::provider::ProviderId;
use hydra::util::prng::Prng;

const TASKS: usize = 10_240;

fn hetero_workload(seed: u64) -> Vec<TaskDescription> {
    let mut rng = Prng::new(seed);
    (0..TASKS)
        .map(|i| {
            let dur = rng.range_f64(1.0, 10.0);
            let cpus = rng.range_u64(1, 5) as u32;
            let gpus = (rng.range_u64(0, 9) / 2) as u32;
            if rng.bool_with_p(0.5) {
                TaskDescription::container(format!("con-{i}"), "hydra/stress")
                    .with_cpus(cpus)
                    .with_gpus(gpus)
                    .with_payload(Payload::Sleep(dur))
            } else {
                TaskDescription::executable(format!("exe-{i}"), "sleep")
                    .with_cpus(cpus)
                    .with_payload(Payload::Sleep(dur))
            }
        })
        .collect()
}

fn hydra_with_nodes(nodes: u32, seed: u64) -> Hydra {
    let mut b = Hydra::builder().partition_model(PartitionModel::Scpp).seed(seed);
    for p in [ProviderId::Jetstream2, ProviderId::Azure] {
        b = b.simulated_provider(p).resource(
            ResourceRequest::kubernetes(p, nodes, 16).with_gpus_per_node(8),
        );
    }
    b = b
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1));
    b.build().unwrap()
}

fn main() {
    println!("{TABLE1}");
    header("3B", "cloud + HPC, heterogeneous tasks (1-10 s, 1-4 CPU, 0-8 GPU)",
           "Fig. 4 (bottom)");

    println!("{:<6} {:>8} {:>16} {:>14} {:>14}", "NODES", "TASKS", "OVH (ms)",
             "TH (task/s)", "TTX (s)");
    let mut ovhs = Vec::new();
    let mut ttxs = Vec::new();
    for nodes in [2u32, 4, 6] {
        let p = measure(|seed| {
            let hydra = hydra_with_nodes(nodes, seed);
            hydra
                .submit(hetero_workload(seed ^ 0x3B), &BrokerPolicy::ByTaskKind)
                .unwrap()
                .aggregate
        });
        println!("{:<6} {:>8} {:>16} {:>14.0} {:>14}", nodes, TASKS, fmt_ms(&p.ovh),
                 p.th.mean, fmt_s(&p.ttx));
        ovhs.push(p.ovh.mean);
        ttxs.push(p.ttx.mean);
    }

    println!("\nFig. 4 (bottom) shapes:");
    println!("  OVH 2->4 nodes: {:+.1}% | 4->6 nodes: {:+.1}%  (paper: +~5% then flat)",
             (ovhs[1] / ovhs[0] - 1.0) * 100.0, (ovhs[2] / ovhs[1] - 1.0) * 100.0);
    let s24 = ttxs[0] / ttxs[1];
    let s46 = ttxs[1] / ttxs[2];
    println!("  TTX speedup 2->4 nodes: {s24:.2}x (ideal 2.0) | 4->6: {s46:.2}x (ideal 1.5)");
    println!("  (paper: linear 2->4, sublinear 4->6 from Kubernetes overheads)");
}
