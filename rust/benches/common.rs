//! Shared support for the experiment harnesses (`cargo bench`).
//!
//! criterion is unavailable offline, so each bench is a `harness = false`
//! binary using this module: multi-trial runs over distinct seeds,
//! mean ± std summaries, and paper-style table output. Every harness
//! prints the Table-1 row it reproduces plus the figure series.

#![allow(dead_code)]

use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel, PodBuildMode};
use hydra::metrics::AggregateMetrics;
use hydra::sim::provider::ProviderId;
use hydra::util::stats::Summary;

/// Trials per experimental point (the paper reports error bars over
/// repeated runs).
pub const TRIALS: u64 = 3;

/// Table 1 — the experiment setup matrix, printed by every harness.
pub const TABLE1: &str = "\
| ID  | Exp. Type | Workload | Platform  | Tasks        | Task Type  | Nodes   | CPUs     |
|-----|-----------|----------|-----------|--------------|------------|---------|----------|
| 1   | P-PR      | HOM      | Cloud     | 4K,8K,16K    | CON        | 1       | 4-16     |
| 2   | C-PR      | HOM      | Cloud     | 16K,32K,64K  | CON        | 1       | 16       |
| 3-A | C-PL      | HOM      | Cloud-HPC | 20K,40K,80K  | CON        | 1       | 16       |
| 3-B | C-PL      | HET      | Cloud-HPC | 10,240       | CON,EXEC   | 2,4,6   | 4-128    |
| 4   | FACTS     | HET      | Cloud-HPC | 200-3200     | CON,EXEC   | 1-16    | 16-256   |";

/// Build a single-provider Hydra with one Kubernetes node.
pub fn cloud_hydra(
    provider: ProviderId,
    vcpus: u32,
    model: PartitionModel,
    seed: u64,
) -> Hydra {
    Hydra::builder()
        .simulated_provider(provider)
        .resource(ResourceRequest::kubernetes(provider, 1, vcpus))
        .partition_model(model)
        .seed(seed)
        .build()
        .expect("simulated provider must build")
}

/// Build Hydra across all four clouds (16 vCPUs each, as Exp 2).
pub fn clouds_hydra(model: PartitionModel, seed: u64) -> Hydra {
    clouds_hydra_mode(model, PodBuildMode::Memory, seed)
}

pub fn clouds_hydra_mode(model: PartitionModel, mode: PodBuildMode, seed: u64) -> Hydra {
    let mut b = Hydra::builder().partition_model(model).build_mode(mode).seed(seed);
    for p in ProviderId::CLOUDS {
        b = b
            .simulated_provider(p)
            .resource(ResourceRequest::kubernetes(p, 1, 16));
    }
    b.build().expect("simulated providers must build")
}

/// Noop container workload (Experiments 1, 2, 3A).
pub fn noop_containers(n: usize) -> Vec<TaskDescription> {
    (0..n)
        .map(|i| TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"))
        .collect()
}

/// One experimental point: aggregate metrics over TRIALS seeds.
pub struct Point {
    pub ovh: Summary,
    pub th: Summary,
    pub tpt: Summary,
    pub ttx: Summary,
    pub pods: usize,
}

/// Run `make_run` across TRIALS seeds and summarize.
pub fn measure(mut make_run: impl FnMut(u64) -> AggregateMetrics) -> Point {
    let mut ovh = Vec::new();
    let mut th = Vec::new();
    let mut tpt = Vec::new();
    let mut ttx = Vec::new();
    let mut pods = 0;
    for trial in 0..TRIALS {
        let m = make_run(0xBEEF + trial * 7919);
        ovh.push(m.ovh_s);
        th.push(m.th_tps);
        tpt.push(m.tpt_s);
        ttx.push(m.ttx_s);
        pods = m.pods;
    }
    Point {
        ovh: Summary::of(&ovh),
        th: Summary::of(&th),
        tpt: Summary::of(&tpt),
        ttx: Summary::of(&ttx),
        pods,
    }
}

/// Run a single-provider workload and return the aggregate.
pub fn run_cloud_point(
    provider: ProviderId,
    tasks: usize,
    vcpus: u32,
    model: PartitionModel,
    seed: u64,
) -> AggregateMetrics {
    let hydra = cloud_hydra(provider, vcpus, model, seed);
    hydra
        .submit(noop_containers(tasks), &BrokerPolicy::RoundRobin)
        .expect("noop workload must broker")
        .aggregate
}

pub fn fmt_ms(s: &Summary) -> String {
    format!("{:8.2} ±{:5.2}", s.mean * 1e3, s.std * 1e3)
}

pub fn fmt_s(s: &Summary) -> String {
    format!("{:8.1} ±{:5.1}", s.mean, s.std)
}

pub fn fmt_tps(s: &Summary) -> String {
    format!("{:9.0} ±{:6.0}", s.mean, s.std)
}

pub fn header(id: &str, title: &str, fig: &str) {
    println!("\n================================================================");
    println!("Experiment {id}: {title}");
    println!("Reproduces: {fig}");
    println!("Trials per point: {TRIALS} (mean ± std). Seeds printed = reproducible.");
    println!("================================================================");
}
