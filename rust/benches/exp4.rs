//! Experiment 4 — Use-Case Scalability: the FACTS workflow (paper §5.4,
//! Fig. 5).
//!
//! Runs 50–800 FACTS workflow instances on Jetstream2, AWS (multi-node
//! Kubernetes + Argo-like engine) and Bridges2 (pilot + EnTK-like engine),
//! measuring TTX (strong + weak scaling) and Hydra OVH. The FACTS compute
//! is *real*: one instance executes through PJRT (pre → fit → project →
//! post over the AOT JAX/Pallas artifacts) and its measured step times
//! become the simulated task durations (× WORK_SCALE; see facts::).
//!
//! Expected shapes: weak scaling near-ideal on all platforms; strong
//! scaling sublinear on the clouds; Bridges2 flat until cores < workflows
//! then scaling; TTX ordering B2 < JET2 < AWS with JET2 ≈ 2.5× AWS;
//! OVH negligible vs makespan.

mod common;

use common::*;
use hydra::api::{ProviderConfig, ResourceRequest};
use hydra::broker::state::TaskRegistry;
use hydra::facts::{self, data, pipeline::FactsPipeline, FactsSize};
use hydra::runtime::{default_artifacts_dir, PjRtRuntime};
use hydra::sim::provider::ProviderId;
use hydra::workflow::engine::WorkflowEngine;

const SIZE: FactsSize = FactsSize::Default;

fn engine(provider: ProviderId, nodes: u32) -> WorkflowEngine {
    let req = match provider {
        ProviderId::Bridges2 => ResourceRequest::pilot(provider, nodes),
        _ => ResourceRequest::kubernetes(provider, nodes, 16),
    };
    WorkflowEngine::new(ProviderConfig::simulated(provider), req)
}

fn cores(provider: ProviderId, nodes: u32) -> u32 {
    match provider {
        ProviderId::Bridges2 => 128 * nodes,
        _ => 16 * nodes,
    }
}

fn main() {
    println!("{TABLE1}");
    header("4", "FACTS workflow at scale (real PJRT compute)", "Fig. 5");

    let rt = PjRtRuntime::load(default_artifacts_dir())
        .expect("run `make artifacts` before `cargo bench --bench exp4`");
    let pipe = FactsPipeline::new(&rt, SIZE);
    let inputs = data::generate(4, SIZE);
    pipe.run(&inputs).unwrap(); // warm-up compile
    let timings = pipe.run(&inputs).unwrap().timings;
    println!(
        "\nmeasured FACTS step times (host): pre {:.2}ms fit {:.2}ms project {:.2}ms \
         post {:.2}ms (x WORK_SCALE {} => simulated work)",
        timings.pre_s * 1e3, timings.fit_s * 1e3, timings.project_s * 1e3,
        timings.post_s * 1e3, facts::WORK_SCALE
    );
    let spec = facts::workflow_spec(SIZE);

    // ---- weak scaling: workflows grow with cores -------------------------
    println!("\n--- WEAK SCALING (workflows/cores grow together) ---");
    println!("{:<10} {:>10} {:>7} {:>12} {:>12} {:>16}", "PLATFORM", "WORKFLOWS", "CORES",
             "OVH (ms)", "TTX (s)", "TTX/workflows(s)");
    for provider in [ProviderId::Jetstream2, ProviderId::Aws, ProviderId::Bridges2] {
        let points: &[(usize, u32)] = match provider {
            // Jetstream2 capped at 128 cores (paper: fewer cores available).
            ProviderId::Jetstream2 => &[(50, 1), (100, 2), (200, 4), (400, 8)],
            ProviderId::Aws => &[(50, 1), (100, 2), (200, 4), (400, 8), (800, 16)],
            // Bridges2 hands out whole 128-core nodes.
            _ => &[(400, 1), (800, 2)],
        };
        for &(wf, nodes) in points {
            let mut ovh = Vec::new();
            let mut ttx = Vec::new();
            for trial in 0..TRIALS {
                let mut eng = engine(provider, nodes);
                eng.seed = 0xFAC7 + trial;
                let reg = TaskRegistry::new();
                let r = eng
                    .execute_many(&spec, wf, &reg, facts::measured_workflow(timings))
                    .unwrap();
                ovh.push(r.ovh_s());
                ttx.push(r.ttx_s);
            }
            let ovh = hydra::util::stats::Summary::of(&ovh);
            let ttx = hydra::util::stats::Summary::of(&ttx);
            println!(
                "{:<10} {:>10} {:>7} {:>12} {:>12} {:>16.3}",
                provider.short_name(), wf, cores(provider, nodes), fmt_ms(&ovh),
                fmt_s(&ttx), ttx.mean / wf as f64
            );
        }
    }

    // ---- strong scaling: 400 workflows, cores grow -----------------------
    println!("\n--- STRONG SCALING (400 workflows; cores grow) ---");
    println!("{:<10} {:>7} {:>12} {:>12} {:>10}", "PLATFORM", "CORES", "OVH (ms)",
             "TTX (s)", "SPEEDUP");
    let mut ttx_at_128 = std::collections::BTreeMap::new();
    for provider in [ProviderId::Jetstream2, ProviderId::Aws, ProviderId::Bridges2] {
        let node_points: &[u32] = match provider {
            ProviderId::Bridges2 => &[1, 2], // 128, 256 cores
            _ => &[1, 2, 4, 8, 16],          // 16..256 cores
        };
        let mut first_ttx = None;
        for &nodes in node_points {
            let mut ttx = Vec::new();
            let mut ovh = Vec::new();
            for trial in 0..TRIALS {
                let mut eng = engine(provider, nodes);
                eng.seed = 0x57_04 + trial;
                let reg = TaskRegistry::new();
                let r = eng
                    .execute_many(&spec, 400, &reg, facts::measured_workflow(timings))
                    .unwrap();
                ttx.push(r.ttx_s);
                ovh.push(r.ovh_s());
            }
            let ttx = hydra::util::stats::Summary::of(&ttx);
            let ovh = hydra::util::stats::Summary::of(&ovh);
            let speedup = first_ttx.get_or_insert(ttx.mean).to_owned() / ttx.mean;
            println!("{:<10} {:>7} {:>12} {:>12} {:>9.2}x",
                     provider.short_name(), cores(provider, nodes), fmt_ms(&ovh),
                     fmt_s(&ttx), speedup);
            if cores(provider, nodes) == 128 {
                ttx_at_128.insert(provider, ttx.mean);
            }
        }
    }

    // ---- headline ratios at equal cores (128) -----------------------------
    if let (Some(&jet2), Some(&aws), Some(&b2)) = (
        ttx_at_128.get(&ProviderId::Jetstream2),
        ttx_at_128.get(&ProviderId::Aws),
        ttx_at_128.get(&ProviderId::Bridges2),
    ) {
        println!("\nFig. 5 headline at 128 cores, 400 workflows:");
        println!("  JET2 vs AWS : {:.1}x faster (paper ~2.5x)", aws / jet2);
        println!("  B2 vs JET2  : {:.1}x faster (paper ~5x)", jet2 / b2);
        println!("  B2 vs AWS   : {:.1}x faster (paper ~10x)", aws / b2);
        println!("  OVH remains milliseconds against TTX of seconds-to-minutes.");
    }
}
