//! Experiment 2 — Cross-Provider Scalability (paper §5.2, Fig. 3).
//!
//! 16K/32K/64K noop container tasks split equally across four concurrent
//! cloud providers (16 vCPUs each), MCPP and SCPP. Reports aggregated
//! OVH / TH / TPT and compares against the Experiment-1 single-provider
//! baseline (the paper's consistency check: concurrency must not add
//! broker overhead; aggregate TH ≈ 4× a single provider's).
//!
//! Testbed note (EXPERIMENTS.md): this host has 1 CPU core, so the
//! *wall-clock* aggregate TH cannot show the 4× concurrency speedup the
//! paper measured on a multi-core host. We therefore report both the
//! wall-clock aggregate ("TH wall") and the sum of per-provider
//! throughputs ("TH sum" — what ≥4 cores would aggregate to), plus the
//! no-added-overhead check that is core-count independent.

mod common;

use common::*;
use hydra::broker::{BrokerPolicy, PartitionModel};
use hydra::sim::provider::ProviderId;

fn main() {
    println!("{TABLE1}");
    header("2", "cross-provider concurrent brokering", "Fig. 3");

    for model in [PartitionModel::Mcpp { max_cpp: 16 }, PartitionModel::Scpp] {
        println!("\n--- {} ---", model.short_name());
        println!("{:<8} {:>8} {:>16} {:>13} {:>13} {:>12} {:>14}",
                 "TASKS", "PODS", "OVH (ms)", "TH wall", "TH sum", "TPT (s)",
                 "OVH/task vs E1");
        for total in [16_000usize, 32_000, 64_000] {
            // Exp-1 baseline: one provider processing the per-provider share.
            let share = total / 4;
            let base = measure(|seed| {
                run_cloud_point(ProviderId::Jetstream2, share, 16, model, seed)
            });
            let base_per_task = base.ovh.mean / share as f64;

            let mut th_sum_acc = 0.0;
            let p = measure(|seed| {
                let hydra = clouds_hydra(model, seed);
                let run = hydra
                    .submit(noop_containers(total), &BrokerPolicy::RoundRobin)
                    .unwrap();
                th_sum_acc += run
                    .per_provider()
                    .iter()
                    .map(|m| m.throughput_tps())
                    .sum::<f64>();
                run.aggregate
            });
            let th_sum = th_sum_acc / TRIALS as f64;
            let per_task = p.ovh.mean / total as f64;
            println!(
                "{:<8} {:>8} {:>16} {:>13.0} {:>13.0} {:>12} {:>13.2}x",
                total,
                p.pods,
                fmt_ms(&p.ovh),
                p.th.mean,
                th_sum,
                fmt_s(&p.tpt),
                per_task / base_per_task,
            );
        }
    }
    println!("\nFig. 3 checks: OVH/task vs E1 ~ 1x (concurrency adds no broker overhead);");
    println!("'TH sum' ~ 4x a single provider's TH (the paper's aggregate on >=4 cores).");
}
