//! Experiment 3A — Cross-Platform Scalability, homogeneous (paper §5.3,
//! Fig. 4 top).
//!
//! 20K/40K/80K noop tasks across the four clouds *plus* the Bridges2
//! pilot, SCPP only (the paper: SCPP "best fits a scenario where tasks
//! execute outside a pod on HPC resources"). The check: adding the HPC
//! path leaves Hydra's OVH and TH in the same regime as Experiment 2 —
//! HPC-specific capabilities add no broker-side cost.

mod common;

use common::*;
use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::provider::ProviderId;

fn hybrid_hydra(seed: u64) -> Hydra {
    let mut b = Hydra::builder().partition_model(PartitionModel::Scpp).seed(seed);
    for p in ProviderId::CLOUDS {
        b = b
            .simulated_provider(p)
            .resource(ResourceRequest::kubernetes(p, 1, 16));
    }
    b = b
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1));
    b.build().unwrap()
}

/// Exp 3A workload: containers for the clouds, executables for the pilot,
/// split evenly across the five platforms by ByTaskKind + RoundRobin.
fn workload(total: usize) -> Vec<TaskDescription> {
    (0..total)
        .map(|i| {
            if i % 5 == 4 {
                TaskDescription::executable(format!("noop-{i}"), "true")
            } else {
                TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest")
            }
        })
        .collect()
}

fn main() {
    println!("{TABLE1}");
    header("3A", "cloud + HPC, homogeneous tasks (SCPP)", "Fig. 4 (top)");

    println!("{:<8} {:>8} {:>16} {:>14} {:>12} {:>17}",
             "TASKS", "PODS", "OVH (ms)", "TH (task/s)", "TPT (s)", "OVH/task vs E2");
    for total in [20_000usize, 40_000, 80_000] {
        // Experiment-2 reference: same scale on clouds only.
        let e2 = measure(|seed| {
            let hydra = clouds_hydra(PartitionModel::Scpp, seed);
            hydra
                .submit(noop_containers(total), &BrokerPolicy::RoundRobin)
                .unwrap()
                .aggregate
        });
        let p = measure(|seed| {
            let hydra = hybrid_hydra(seed);
            hydra
                .submit(workload(total), &BrokerPolicy::ByTaskKind)
                .unwrap()
                .aggregate
        });
        println!(
            "{:<8} {:>8} {:>16} {:>14.0} {:>12} {:>16.2}x",
            total,
            p.pods,
            fmt_ms(&p.ovh),
            p.th.mean,
            fmt_s(&p.tpt),
            (p.ovh.mean / total as f64) / (e2.ovh.mean / total as f64),
        );
    }
    println!("\nFig. 4 (top) check: OVH/task vs Experiment 2 ~ 1x — the HPC connector");
    println!("adds no broker overhead beyond the cloud path. TPT includes the pilot's");
    println!("queue wait (short and consistent: mean 45 s, cv 0.15).");
}
