//! Experiment 1 — Per-Provider Scalability (paper §5.1, Fig. 2 a–f).
//!
//! For each cloud provider (JET2, CHI, AWS, AZURE): 4K/8K/16K noop
//! container tasks on 4/8/16 vCPUs, MCPP and SCPP. Reports the three
//! panels of Fig. 2: OVH (top), TH (middle), TPT (bottom) for weak
//! scaling (tasks/vCPUs grow together) and strong scaling (tasks fixed,
//! vCPUs grow).
//!
//! Expected shapes (DESIGN.md §4): OVH tracks #tasks/#pods and is
//! ~provider-invariant; SCPP OVH ≈ +46% vs MCPP; TH(MCPP) > TH(SCPP);
//! TPT: JET2 best at 4 vCPUs, AZURE overtakes at 16, CHI scales worst,
//! SCPP ≈ +9% TPT.

mod common;

use common::*;
use hydra::broker::PartitionModel;
use hydra::sim::provider::ProviderId;
use hydra::util::stats::scaling_exponent;

fn model_name(m: PartitionModel) -> &'static str {
    m.short_name()
}

fn main() {
    println!("{TABLE1}");
    header("1", "per-provider weak/strong scaling", "Fig. 2 (a-f)");

    let models = [PartitionModel::Mcpp { max_cpp: 16 }, PartitionModel::Scpp];
    // (tasks, vcpus) — weak scaling points double together.
    let weak: [(usize, u32); 3] = [(4000, 4), (8000, 8), (16000, 16)];

    let mut scpp_ovh_sum = 0.0;
    let mut mcpp_ovh_sum = 0.0;
    let mut scpp_th_sum = 0.0;
    let mut mcpp_th_sum = 0.0;
    let mut scpp_tpt_sum = 0.0;
    let mut mcpp_tpt_sum = 0.0;

    for model in models {
        println!("\n--- {} | WEAK SCALING (tasks/vCPUs: 4K/4, 8K/8, 16K/16) ---",
                 model_name(model));
        println!("{:<8} {:>10} {:>6} {:>6} {:>16} {:>17} {:>16}",
                 "PROVIDER", "TASKS", "vCPU", "PODS", "OVH (ms)", "TH (task/s)", "TPT (s)");
        for provider in ProviderId::CLOUDS {
            for (tasks, vcpus) in weak {
                let p = measure(|seed| run_cloud_point(provider, tasks, vcpus, model, seed));
                println!(
                    "{:<8} {:>10} {:>6} {:>6} {:>16} {:>17} {:>16}",
                    provider.short_name(), tasks, vcpus, p.pods,
                    fmt_ms(&p.ovh), fmt_tps(&p.th), fmt_s(&p.tpt)
                );
                match model {
                    PartitionModel::Scpp => {
                        scpp_ovh_sum += p.ovh.mean;
                        scpp_th_sum += p.th.mean;
                        scpp_tpt_sum += p.tpt.mean;
                    }
                    _ => {
                        mcpp_ovh_sum += p.ovh.mean;
                        mcpp_th_sum += p.th.mean;
                        mcpp_tpt_sum += p.tpt.mean;
                    }
                }
            }
        }

        println!("\n--- {} | STRONG SCALING (16K tasks; vCPUs 4 -> 16) ---", model_name(model));
        println!("{:<8} {:>6} {:>16} {:>16}  scaling-exp(TPT~vCPU)",
                 "PROVIDER", "vCPU", "OVH (ms)", "TPT (s)");
        for provider in ProviderId::CLOUDS {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            let mut rows = Vec::new();
            for vcpus in [4u32, 8, 16] {
                let p = measure(|seed| run_cloud_point(provider, 16000, vcpus, model, seed));
                xs.push(vcpus as f64);
                ys.push(p.tpt.mean);
                rows.push((vcpus, p));
            }
            let alpha = scaling_exponent(&xs, &ys);
            for (i, (vcpus, p)) in rows.iter().enumerate() {
                let tail = if i == 2 { format!("   alpha = {alpha:+.2}") } else { String::new() };
                println!("{:<8} {:>6} {:>16} {:>16}{tail}",
                         provider.short_name(), vcpus, fmt_ms(&p.ovh), fmt_s(&p.tpt));
            }
        }
    }

    println!("\n--- Fig. 2 headline ratios (paper: SCPP OVH ~ +46%, TH(MCPP) ~ +44%, \
              SCPP TPT ~ +9%) ---");
    println!("SCPP/MCPP OVH : {:+.0}%", (scpp_ovh_sum / mcpp_ovh_sum - 1.0) * 100.0);
    println!("MCPP/SCPP TH  : {:+.0}%", (mcpp_th_sum / scpp_th_sum - 1.0) * 100.0);
    println!("SCPP/MCPP TPT : {:+.1}%", (scpp_tpt_sum / mcpp_tpt_sum - 1.0) * 100.0);
}
