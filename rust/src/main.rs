//! `hydra` — leader entrypoint and CLI.
//!
//! Subcommands map to the paper's usage surface:
//! * `providers` — validate and list the configured providers.
//! * `run`       — broker a synthetic workload (Experiments 1–3 style).
//! * `facts`     — run FACTS workflow instances end to end (Experiment 4),
//!                 executing the real AOT compute through PJRT.
//! * `inspect`   — print the artifact manifest the runtime would load.

use hydra::api::resource::FaultSpec;
use hydra::api::task::{Payload, TaskDescription};
use hydra::api::ResourceRequest;
use hydra::broker::{
    BrokerPolicy, Hydra, PartitionModel, PodBuildMode, ProviderFaultSpec, RetryPolicy,
};
use hydra::facts::{self, data, pipeline::FactsPipeline, FactsSize};
use hydra::runtime::{default_artifacts_dir, PjRtRuntime};
use hydra::sim::provider::ProviderId;
use hydra::util::cli::{App, Command, Matches, Parsed};
use hydra::util::{fmt_secs, Stopwatch};
use hydra::workflow::engine::WorkflowEngine;

fn app() -> App {
    App::new("hydra", "cloud/HPC broker for heterogeneous workloads (paper reproduction)")
        .command(Command::new("providers", "validate and list configured providers"))
        .command(
            Command::new("run", "broker a synthetic workload")
                .opt("provider", "jet2", "provider (jet2|chi|aws|azure|bridges2) or 'clouds'")
                .opt("tasks", "4000", "number of tasks")
                .opt("vcpus", "16", "vCPUs per node (cloud)")
                .opt("nodes", "1", "nodes per cluster / pilot")
                .opt("pilots", "1", "concurrent pilot jobs (HPC providers)")
                .opt(
                    "pilot-nodes",
                    "-",
                    "heterogeneous pilot widths, e.g. 2,4,8 (HPC; overrides nodes/pilots; '-' = off)",
                )
                .opt("task-failure-rate", "0", "per-task failure probability in [0,1]")
                .opt("pilot-walltime", "0", "pilot walltime seconds, 0 = off (HPC)")
                .opt("pilot-mtbf", "0", "pilot mean time between failures seconds, 0 = off (HPC)")
                .opt("retry-budget", "3", "re-queues per task before abandoning it (HPC)")
                .opt(
                    "provider-outage",
                    "-",
                    "control-plane outage <provider>:<t0>:<t1> on the submit clock ('-' = off)",
                )
                .opt(
                    "submit-error-rate",
                    "0",
                    "per-attempt transient submit error probability in [0,1] (all providers)",
                )
                .opt("max-submit-attempts", "5", "submit attempts before a slice fails over")
                .opt("sleep", "0", "per-task sleep seconds (0 = noop)")
                .opt("seed", "42", "simulation seed")
                .opt(
                    "report",
                    "-",
                    "write a JSON run report (metrics + trace) to this path ('-' = off)",
                )
                .flag("scpp", "single-container-per-pod (default MCPP)")
                .flag("disk", "build pod manifests on disk (paper's measured mode)")
                .flag("faas", "broker function tasks through FaaS on cloud providers"),
        )
        .command(
            Command::new("facts", "run FACTS workflow instances (Experiment 4)")
                .opt("provider", "jet2", "jet2|aws|bridges2")
                .opt("workflows", "50", "number of workflow instances")
                .opt("nodes", "1", "cluster nodes / pilot nodes")
                .opt("size", "default", "artifact size: small|default|large")
                .opt("seed", "42", "data generation seed"),
        )
        .command(Command::new("inspect", "print the artifact manifest"))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            eprintln!("{}", app().top_usage());
            std::process::exit(2);
        }
    };
    let m = match parsed {
        Parsed::Help(h) => {
            println!("{h}");
            return;
        }
        Parsed::Run(m) => m,
    };
    let result = match m.command.as_str() {
        "providers" => cmd_providers(),
        "run" => cmd_run(&m),
        "facts" => cmd_facts(&m),
        "inspect" => cmd_inspect(),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_providers() -> Result<(), Box<dyn std::error::Error>> {
    println!("{:<10} {:>5} {:>12} {:>10} {:>8}", "PROVIDER", "KIND", "CORES/NODE", "CPU-SPEED",
             "PINNING");
    for id in ProviderId::ALL {
        let p = hydra::sim::provider::PlatformProfile::of(id);
        println!(
            "{:<10} {:>5} {:>12} {:>10.1} {:>8}",
            id.short_name(),
            match p.kind {
                hydra::sim::provider::PlatformKind::Cloud => "cloud",
                hydra::sim::provider::PlatformKind::Hpc => "hpc",
            },
            p.cores_per_node,
            p.cpu_speed,
            match p.pinning {
                hydra::sim::provider::CpuPinning::PhysicalCore => "core",
                hydra::sim::provider::CpuPinning::Thread => "thread",
                hydra::sim::provider::CpuPinning::BareMetal => "metal",
            }
        );
    }
    Ok(())
}

fn providers_from_arg(arg: &str) -> Result<Vec<ProviderId>, String> {
    if arg == "clouds" {
        return Ok(ProviderId::CLOUDS.to_vec());
    }
    arg.split(',')
        .map(|s| ProviderId::parse(s.trim()).ok_or_else(|| format!("unknown provider '{s}'")))
        .collect()
}

fn cmd_run(m: &Matches) -> Result<(), Box<dyn std::error::Error>> {
    let providers = providers_from_arg(m.str("provider"))?;
    let n_tasks = m.usize("tasks")?;
    let vcpus = m.u64("vcpus")? as u32;
    let nodes = m.u64("nodes")? as u32;
    let pilots = m.u64("pilots")? as u32;
    let pilot_nodes: Vec<u32> = if m.str("pilot-nodes") == "-" {
        Vec::new()
    } else {
        m.u64_list("pilot-nodes")?.into_iter().map(|w| w as u32).collect()
    };
    let task_failure_rate = m.f64("task-failure-rate")?;
    let fault = FaultSpec {
        walltime_s: m.f64("pilot-walltime")?,
        mtbf_s: m.f64("pilot-mtbf")?,
        retry_budget: m.u64("retry-budget")? as u32,
        ..FaultSpec::none()
    };
    let outage: Option<(ProviderId, f64, f64)> = match m.str("provider-outage") {
        "-" => None,
        s => {
            let parts: Vec<&str> = s.split(':').collect();
            if parts.len() != 3 {
                return Err(format!(
                    "--provider-outage: expected <provider>:<t0>:<t1>, got '{s}'"
                )
                .into());
            }
            let p = ProviderId::parse(parts[0])
                .ok_or_else(|| format!("--provider-outage: unknown provider '{}'", parts[0]))?;
            let t0: f64 = parts[1].parse().map_err(|_| format!("bad t0 '{}'", parts[1]))?;
            let t1: f64 = parts[2].parse().map_err(|_| format!("bad t1 '{}'", parts[2]))?;
            Some((p, t0, t1))
        }
    };
    let submit_error_rate = m.f64("submit-error-rate")?;
    let retry = RetryPolicy {
        max_attempts: m.u64("max-submit-attempts")? as u32,
        ..RetryPolicy::default()
    };
    let sleep = m.f64("sleep")?;
    let model = if m.flag("scpp") {
        PartitionModel::Scpp
    } else {
        PartitionModel::Mcpp { max_cpp: 16 }
    };

    let mut b = Hydra::builder().partition_model(model).seed(m.u64("seed")?);
    if m.flag("disk") {
        b = b.build_mode(PodBuildMode::Disk {
            staging_dir: std::env::temp_dir().join("hydra-staging"),
        });
    }
    let use_faas = m.flag("faas");
    for &p in &providers {
        b = b.simulated_provider(p);
        let req = if hydra::sim::provider::PlatformProfile::of(p).kind
            == hydra::sim::provider::PlatformKind::Hpc
        {
            let mut req = ResourceRequest::hpc(p, nodes, pilots);
            if !pilot_nodes.is_empty() {
                req = req.with_pilot_nodes(&pilot_nodes);
            }
            req.with_faults(fault).with_task_failure_rate(task_failure_rate)
        } else if use_faas {
            // Clouds serve functions; the vcpus knob doubles as the
            // account-level concurrency limit.
            ResourceRequest::faas(p, vcpus.max(1) * 4)
        } else {
            ResourceRequest::kubernetes(p, nodes, vcpus)
        };
        let mut pf = ProviderFaultSpec {
            transient_error_p: submit_error_rate,
            ..ProviderFaultSpec::none()
        };
        if let Some((op, t0, t1)) = outage {
            if op == p {
                pf.outage_window = Some((t0, t1));
            }
        }
        b = b.resource(req.with_provider_faults(pf).with_retry_policy(retry));
    }
    let hydra = b.build()?;

    let payload = if sleep > 0.0 { Payload::Sleep(sleep) } else { Payload::Noop };
    let tasks: Vec<TaskDescription> = (0..n_tasks)
        .map(|i| {
            let t = if use_faas {
                TaskDescription::function(format!("task-{i}"), "hydra.noop:handler")
            } else {
                TaskDescription::container(format!("task-{i}"), "hydra/noop:latest")
            };
            t.with_payload(payload.clone())
        })
        .collect();

    // Functions must land on FaaS providers; kind-aware routing does
    // that (and degrades to the RoundRobin split when kinds are uniform).
    let policy = if use_faas { BrokerPolicy::ByTaskKind } else { BrokerPolicy::RoundRobin };
    let sw = Stopwatch::start();
    let run = hydra.submit(tasks, &policy)?;
    let wall = sw.elapsed_secs();

    println!("{:<10} {:>8} {:>8} {:>12} {:>12} {:>12}", "PROVIDER", "TASKS", "PODS", "OVH",
             "TH (t/s)", "TPT");
    for r in run.per_provider() {
        println!(
            "{:<10} {:>8} {:>8} {:>12} {:>12.0} {:>12}",
            r.provider.short_name(),
            r.tasks,
            r.pods,
            fmt_secs(r.ovh.total_s()),
            r.throughput_tps(),
            fmt_secs(r.tpt_s),
        );
    }
    println!(
        "{:<10} {:>8} {:>8} {:>12} {:>12.0} {:>12}",
        "AGGREGATE",
        run.aggregate.tasks,
        run.aggregate.pods,
        fmt_secs(run.aggregate.ovh_s),
        run.aggregate.th_tps,
        fmt_secs(run.aggregate.tpt_s),
    );
    println!("(broker wall time {})", fmt_secs(wall));
    if m.str("report") != "-" {
        let metrics: Vec<hydra::metrics::RunMetrics> =
            run.per_provider().into_iter().cloned().collect();
        let doc = hydra::metrics::run_report(
            &metrics,
            &run.aggregate,
            Some(hydra.registry().trace_json()),
        );
        std::fs::write(m.str("report"), doc.to_string_pretty())?;
        println!("(report written to {})", m.str("report"));
    }
    // OVH breakdown (the §Perf hot-path decomposition).
    for r in run.per_provider() {
        println!(
            "  {} OVH breakdown: partition {} | serialize {} | submit {}",
            r.provider.short_name(),
            fmt_secs(r.ovh.partition_s),
            fmt_secs(r.ovh.serialize_s),
            fmt_secs(r.ovh.submit_s),
        );
    }
    // Fault accounting, when any manager saw failures or retries.
    for (id, rep) in &run.reports {
        let f = rep.run().faults;
        if f.failed + f.retried + f.abandoned + f.retry_waves > 0 {
            println!(
                "  {} faults: failed {} | retried {} (waves {}, {} B resubmitted) | abandoned {}",
                id.short_name(),
                f.failed,
                f.retried,
                f.retry_waves,
                f.retry_bulk_bytes,
                f.abandoned,
            );
        }
    }
    // Provider-layer resilience: primary runs plus failover legs landed
    // on each provider, and the live circuit state off its handle.
    let mut resilience: std::collections::BTreeMap<ProviderId, (usize, u64, usize, usize)> =
        std::collections::BTreeMap::new();
    for (id, rep) in &run.reports {
        let f = rep.run().faults;
        let e = resilience.entry(*id).or_default();
        e.0 += f.submit_retries;
        e.1 += f.backoff_ms;
        e.2 += f.circuit_opens;
        e.3 += f.failed_over;
    }
    for fo in &run.failovers {
        let f = fo.report.run().faults;
        let e = resilience.entry(fo.to).or_default();
        e.0 += f.submit_retries;
        e.1 += f.backoff_ms;
        e.2 += f.circuit_opens;
        e.3 += f.failed_over;
        resilience.entry(fo.from).or_default();
    }
    for (id, (retries, backoff_ms, opens, failed_over)) in &resilience {
        let circuit = hydra
            .service_proxy()
            .providers
            .handle(*id)
            .map(|h| format!("{}", h.breaker.state()))
            .unwrap_or_else(|| "unknown".into());
        println!(
            "  {} resilience: submit retries {} | backoff {} ms | circuit {} (opened {}x) | \
             tasks failed over {}",
            id.short_name(),
            retries,
            backoff_ms,
            circuit,
            opens,
            failed_over,
        );
    }
    for fo in &run.failovers {
        println!(
            "  failover: {} -> {} ({} tasks re-brokered)",
            fo.from.short_name(),
            fo.to.short_name(),
            fo.tasks,
        );
    }
    if !run.abandoned.is_empty() {
        println!(
            "  abandoned: {} tasks (no surviving compatible provider)",
            run.abandoned.len()
        );
    }
    Ok(())
}

fn parse_size(s: &str) -> Result<FactsSize, String> {
    match s {
        "small" => Ok(FactsSize::Small),
        "default" => Ok(FactsSize::Default),
        "large" => Ok(FactsSize::Large),
        other => Err(format!("unknown size '{other}'")),
    }
}

fn cmd_facts(m: &Matches) -> Result<(), Box<dyn std::error::Error>> {
    let provider = ProviderId::parse(m.str("provider"))
        .ok_or_else(|| format!("unknown provider '{}'", m.str("provider")))?;
    let instances = m.usize("workflows")?;
    let nodes = m.u64("nodes")? as u32;
    let size = parse_size(m.str("size"))?;
    let seed = m.u64("seed")?;

    println!("loading artifacts from {:?} ...", default_artifacts_dir());
    let rt = PjRtRuntime::load(default_artifacts_dir())?;
    let pipe = FactsPipeline::new(&rt, size);

    // Run one real instance end to end: science output + measured timings.
    let inputs = data::generate(seed, size);
    pipe.run(&inputs)?; // warm-up (compilation)
    let result = pipe.run(&inputs)?;
    println!(
        "FACTS sample instance: total rise at horizon = {:.1} mm \
         (modules: se {:.1} / poly {:.1}); steps {} / {} / {} / {}",
        result.total_rise_mm,
        result.module_medians_mm.0,
        result.module_medians_mm.1,
        fmt_secs(result.timings.pre_s),
        fmt_secs(result.timings.fit_s),
        fmt_secs(result.timings.project_s),
        fmt_secs(result.timings.post_s),
    );

    // Broker `instances` copies across the chosen platform.
    let cfg = hydra::api::ProviderConfig::simulated(provider);
    let req = if provider == ProviderId::Bridges2 {
        ResourceRequest::pilot(provider, nodes)
    } else {
        ResourceRequest::kubernetes(provider, nodes, 16)
    };
    let engine = WorkflowEngine::new(cfg, req);
    let reg = hydra::broker::state::TaskRegistry::new();
    let r = engine.execute_many(
        &facts::workflow_spec(size),
        instances,
        &reg,
        facts::measured_workflow(result.timings),
    )?;
    println!(
        "{} x FACTS on {} ({} nodes): TTX {} (waves: {}), OVH {}",
        instances,
        provider.short_name(),
        nodes,
        fmt_secs(r.ttx_s),
        r.wave_ttx_s.iter().map(|w| fmt_secs(*w)).collect::<Vec<_>>().join(" + "),
        fmt_secs(r.ovh_s()),
    );
    Ok(())
}

fn cmd_inspect() -> Result<(), Box<dyn std::error::Error>> {
    let rt = PjRtRuntime::load(default_artifacts_dir())?;
    let m = rt.manifest();
    println!("quantiles: {:?}", m.quantiles);
    println!("{:<24} {:>8} {:>8}  SHAPES", "ARTIFACT", "INPUTS", "OUTPUTS");
    for a in &m.artifacts {
        println!(
            "{:<24} {:>8} {:>8}  {:?} -> {:?}",
            a.name,
            a.input_shapes.len(),
            a.output_shapes.len(),
            a.input_shapes,
            a.output_shapes
        );
    }
    Ok(())
}
