//! The FACTS pipeline: four steps executed against the PJRT artifacts.
//!
//! Dataflow (tensors pass step to step exactly as the paper's workflow
//! passes files):
//!
//! ```text
//! pre-processing : (temps, rates)        -> (X4, X2, y, tref)
//! fitting        : (X2, y) & (X4, y)     -> (theta, sigma2, A) x2
//! projecting     : posterior + scenario  -> (quants, mean) x2 modules
//! post-processing: module quantile fans  -> combined fan + total rise
//! ```
//!
//! Every step is timed; the measured wall times feed the workflow engine
//! (they become the simulated task durations of Experiment 4).

use super::data::FactsInputs;
use super::{FactsSize, StepTimings};
use crate::runtime::{PjRtRuntime, RuntimeError, Tensor};
use crate::util::Stopwatch;

/// Output of one full pipeline execution.
#[derive(Debug, Clone)]
pub struct FactsResult {
    /// (Q, Y) combined sea-level quantile fan (mm).
    pub combined: Tensor,
    /// (2, Y) min/max envelope across modules.
    pub envelope: Tensor,
    /// Weighted median rise at the horizon (mm).
    pub total_rise_mm: f64,
    /// Per-module medians at the horizon (se, poly).
    pub module_medians_mm: (f64, f64),
    pub timings: StepTimings,
}

/// Pipeline bound to a runtime + size variant.
pub struct FactsPipeline<'r> {
    pub rt: &'r PjRtRuntime,
    pub size: FactsSize,
}

impl<'r> FactsPipeline<'r> {
    pub fn new(rt: &'r PjRtRuntime, size: FactsSize) -> FactsPipeline<'r> {
        FactsPipeline { rt, size }
    }

    /// Execute the four steps for one instance's inputs.
    pub fn run(&self, inputs: &FactsInputs) -> Result<FactsResult, RuntimeError> {
        let size = self.size;
        let (_, _, _, y) = size.dims();
        let q = super::QUANTILES.len();

        // -- pre-processing ----------------------------------------------
        let sw = Stopwatch::start();
        let pre = self.rt.execute(
            &size.artifact("preprocess"),
            &[inputs.temps.clone(), inputs.rates.clone()],
        )?;
        let (x4, x2, ystd) = (pre[0].clone(), pre[1].clone(), pre[2].clone());
        let pre_s = sw.elapsed_secs();

        // -- fitting (both modules) ---------------------------------------
        let sw = Stopwatch::start();
        let fit2 = self.rt.execute(&size.artifact("fit_k2"), &[x2, ystd.clone()])?;
        let fit4 = self.rt.execute(&size.artifact("fit_k4"), &[x4, ystd])?;
        let fit_s = sw.elapsed_secs();

        // -- projecting (both modules) -------------------------------------
        let sw = Stopwatch::start();
        let proj_se = self.rt.execute(
            &size.artifact("project_se"),
            &[
                fit2[0].clone(),
                fit2[1].clone(),
                fit2[2].clone(),
                inputs.eps2.clone(),
                inputs.temps_fut.clone(),
            ],
        )?;
        let proj_poly = self.rt.execute(
            &size.artifact("project_poly"),
            &[
                fit4[0].clone(),
                fit4[1].clone(),
                fit4[2].clone(),
                inputs.eps4.clone(),
                inputs.phi_fut.clone(),
            ],
        )?;
        let project_s = sw.elapsed_secs();

        // -- post-processing -----------------------------------------------
        let sw = Stopwatch::start();
        let quants_se = &proj_se[0];
        let quants_poly = &proj_poly[0];
        let mut stacked = Vec::with_capacity(2 * q * y);
        stacked.extend_from_slice(&quants_se.data);
        stacked.extend_from_slice(&quants_poly.data);
        let post = self.rt.execute(
            &size.artifact("postprocess"),
            &[Tensor::new(stacked, vec![2, q, y]), inputs.weights.clone()],
        )?;
        let post_s = sw.elapsed_secs();

        let combined = post[0].clone();
        let envelope = post[1].clone();
        let total_rise_mm = post[2].data[0] as f64;
        let mid = q / 2;
        let module_medians_mm = (
            quants_se.data[mid * y + (y - 1)] as f64,
            quants_poly.data[mid * y + (y - 1)] as f64,
        );

        Ok(FactsResult {
            combined,
            envelope,
            total_rise_mm,
            module_medians_mm,
            timings: StepTimings { pre_s, fit_s, project_s, post_s },
        })
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (with real artifacts) by
    // rust/tests/integration_facts.rs and examples/facts_e2e.rs; unit
    // coverage here would require a PJRT client, which `cargo test --lib`
    // keeps out of the hot path.
}
