//! Synthetic FACTS input data with known ground truth.
//!
//! Substitutes for the ~21 GB of observational datasets FACTS consumes
//! (paper §4): per-site historical temperature anomalies and sea-level
//! rates generated from the same semi-empirical model the pipeline fits
//! (`rate(t) = a * (T(t) - T0) + eps`), so the fit step has a recoverable
//! ground truth (tested), plus an
//! SSP-style future warming scenario for the projection step.

use super::FactsSize;
use crate::runtime::Tensor;
use crate::util::prng::Prng;

/// One workflow instance's inputs (shapes follow `FactsSize::dims`).
#[derive(Debug, Clone)]
pub struct FactsInputs {
    /// (B, T) historical temperature anomaly series.
    pub temps: Tensor,
    /// (B, T) historical sea-level-rate series (mm/yr).
    pub rates: Tensor,
    /// (Y,) future temperature anomaly scenario.
    pub temps_fut: Tensor,
    /// (Y, 4) polynomial features of the scenario [1, T, T^2, tau].
    pub phi_fut: Tensor,
    /// (B, M, 2) posterior noise for the semi-empirical module.
    pub eps2: Tensor,
    /// (B, M, 4) posterior noise for the polynomial module.
    pub eps4: Tensor,
    /// (2,) module combination weights.
    pub weights: Tensor,
    /// Ground truth per site (for validation): (a, T0).
    pub truth: Vec<(f64, f64)>,
}

/// Generate one instance's inputs from a seed.
pub fn generate(seed: u64, size: FactsSize) -> FactsInputs {
    let (b, t, m, y) = size.dims();
    let mut rng = Prng::new(seed ^ 0xFAC75_DA7A);

    let mut temps = Vec::with_capacity(b * t);
    let mut rates = Vec::with_capacity(b * t);
    let mut truth = Vec::with_capacity(b);
    for _ in 0..b {
        let a = rng.range_f64(1.5, 4.0); // mm / yr / K
        let t0 = rng.range_f64(-0.4, 0.4); // K anomaly
        truth.push((a, t0));
        for step in 0..t {
            // Warming trend 0 → ~1.2 K over the record + weather noise.
            let trend = 1.2 * step as f64 / t as f64;
            let temp = trend + 0.08 * rng.normal();
            let rate = a * (temp - t0) + 0.15 * rng.normal();
            temps.push(temp as f32);
            rates.push(rate as f32);
        }
    }

    // SSP-style scenario: accelerate from ~1.2 K to ~3 K over Y years.
    let mut temps_fut = Vec::with_capacity(y);
    let mut phi_fut = Vec::with_capacity(y * 4);
    for step in 0..y {
        let tau = step as f64 / y.max(1) as f64;
        let temp = 1.2 + 1.8 * tau * tau.sqrt() + 0.03 * rng.normal();
        temps_fut.push(temp as f32);
        phi_fut.extend_from_slice(&[1.0f32, temp as f32, (temp * temp) as f32, tau as f32]);
    }

    let eps2: Vec<f32> = (0..b * m * 2).map(|_| rng.normal() as f32).collect();
    let eps4: Vec<f32> = (0..b * m * 4).map(|_| rng.normal() as f32).collect();

    FactsInputs {
        temps: Tensor::new(temps, vec![b, t]),
        rates: Tensor::new(rates, vec![b, t]),
        temps_fut: Tensor::new(temps_fut, vec![y]),
        phi_fut: Tensor::new(phi_fut, vec![y, 4]),
        eps2: Tensor::new(eps2, vec![b, m, 2]),
        eps4: Tensor::new(eps4, vec![b, m, 4]),
        weights: Tensor::new(vec![0.6, 0.4], vec![2]),
        truth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_size_dims() {
        for size in [FactsSize::Small, FactsSize::Default, FactsSize::Large] {
            let (b, t, m, y) = size.dims();
            let d = generate(7, size);
            assert_eq!(d.temps.shape, vec![b, t]);
            assert_eq!(d.rates.shape, vec![b, t]);
            assert_eq!(d.temps_fut.shape, vec![y]);
            assert_eq!(d.phi_fut.shape, vec![y, 4]);
            assert_eq!(d.eps2.shape, vec![b, m, 2]);
            assert_eq!(d.eps4.shape, vec![b, m, 4]);
            assert_eq!(d.truth.len(), b);
        }
    }

    #[test]
    fn deterministic_per_seed_and_distinct_across_seeds() {
        let a = generate(1, FactsSize::Small);
        let b = generate(1, FactsSize::Small);
        let c = generate(2, FactsSize::Small);
        assert_eq!(a.temps.data, b.temps.data);
        assert_ne!(a.temps.data, c.temps.data);
    }

    #[test]
    fn rates_follow_ground_truth_model() {
        let d = generate(11, FactsSize::Default);
        let (b, t, _, _) = FactsSize::Default.dims();
        for site in 0..b {
            let (a, t0) = d.truth[site];
            let mut err = 0.0;
            for step in 0..t {
                let temp = d.temps.data[site * t + step] as f64;
                let rate = d.rates.data[site * t + step] as f64;
                err += (rate - a * (temp - t0)).abs();
            }
            // Noise std is 0.15 => mean |error| ~ 0.12
            assert!(err / (t as f64) < 0.5, "site {site}: {}", err / t as f64);
        }
    }

    #[test]
    fn scenario_is_warming() {
        let d = generate(3, FactsSize::Default);
        let y = d.temps_fut.data.len();
        let early: f32 = d.temps_fut.data[..8].iter().sum::<f32>() / 8.0;
        let late: f32 = d.temps_fut.data[y - 8..].iter().sum::<f32>() / 8.0;
        assert!(late > early + 0.5, "scenario must warm: {early} -> {late}");
    }

    #[test]
    fn phi_columns_consistent_with_scenario() {
        let d = generate(5, FactsSize::Small);
        let y = d.temps_fut.data.len();
        for i in 0..y {
            assert_eq!(d.phi_fut.data[i * 4], 1.0);
            assert_eq!(d.phi_fut.data[i * 4 + 1], d.temps_fut.data[i]);
            let t = d.phi_fut.data[i * 4 + 1];
            assert!((d.phi_fut.data[i * 4 + 2] - t * t).abs() < 1e-4);
        }
    }
}
