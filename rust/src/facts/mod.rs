//! FACTS: the exemplar science workflow of Experiment 4.
//!
//! The Framework for Assessing Changes To Sea-level (paper §4) is modeled
//! as its mathematical core: a semi-empirical sea-level response model and
//! a polynomial emulator, fit on historical (temperature, sea-level-rate)
//! records and projected by Monte-Carlo posterior sampling over future
//! temperature scenarios (see `python/compile/model.py` — the compute runs
//! through the PJRT runtime, never through Python).
//!
//! Pieces:
//! * [`data`] — synthetic record generator with known ground truth
//!   (substitute for FACTS's ~21 GB input datasets; DESIGN.md §1).
//! * [`pipeline`] — the four steps (pre-process → fit → project →
//!   post-process) executed against the AOT artifacts.
//! * [`workflow_spec`]/[`measured_workflow`] — the 4-step DAG handed to
//!   the workflow engine, with real measured compute durations attached.

pub mod data;
pub mod pipeline;

use crate::api::task::{Payload, TaskDescription};
use crate::workflow::dag::{Step, WorkflowSpec};

/// Artifact size variants (must match `python/compile/aot.py::SIZES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactsSize {
    Small,
    Default,
    Large,
}

impl FactsSize {
    /// (B sites, T history steps, M samples/site, Y projection years).
    pub fn dims(self) -> (usize, usize, usize, usize) {
        match self {
            FactsSize::Small => (4, 32, 8, 32),
            FactsSize::Default => (16, 128, 16, 96),
            FactsSize::Large => (16, 128, 64, 96),
        }
    }

    pub fn suffix(self) -> &'static str {
        match self {
            FactsSize::Small => "small",
            FactsSize::Default => "default",
            FactsSize::Large => "large",
        }
    }

    pub fn artifact(self, step: &str) -> String {
        format!("{step}_{}", self.suffix())
    }
}

/// Reporting quantiles — must match `model.QUANTILES`.
pub const QUANTILES: [f64; 5] = [0.05, 0.17, 0.5, 0.83, 0.95];

/// Scale factor from measured artifact wall-time to simulated task work.
///
/// The AOT artifacts run reduced problem sizes: the full FACTS datasets
/// are ~21 GB (paper §4) against our ~100 KB synthetic records — a ratio
/// of ~2e5. One simulated FACTS step therefore represents `WORK_SCALE`
/// executions of the reduced kernel (a conservative 1.5e5), putting a step
/// at O(10-600 s) on the AWS reference core — the regime where the
/// paper's Fig 5 platform ordering (queue wait amortized, compute
/// dominant) is observable.
pub const WORK_SCALE: f64 = 150_000.0;

/// The paper's FACTS step requirements: "Each step requires 1 core, 2GB
/// of RAM" (§5.4).
fn facts_task(name: &str, artifact: String) -> TaskDescription {
    TaskDescription::executable(name, format!("facts-{name}"))
        .with_cpus(1)
        .with_mem_mb(2048)
        .with_payload(Payload::Compute(artifact))
}

/// The 4-step FACTS chain as a workflow spec with `Compute` payloads
/// (resolved to measured work by [`measured_workflow`]).
pub fn workflow_spec(size: FactsSize) -> WorkflowSpec {
    WorkflowSpec::new(format!("facts-{}", size.suffix()))
        .step(Step::new("pre-processing", facts_task("pre-processing",
                                                     size.artifact("preprocess"))))
        .step(Step::new("fitting", facts_task("fitting", size.artifact("fit_k2"))).after(0))
        .step(Step::new("projecting", facts_task("projecting",
                                                 size.artifact("project_se"))).after(1))
        .step(Step::new("post-processing", facts_task("post-processing",
                                                      size.artifact("postprocess"))).after(2))
}

/// Measured per-step wall times (seconds on this host) from a real
/// pipeline execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTimings {
    pub pre_s: f64,
    pub fit_s: f64,
    pub project_s: f64,
    pub post_s: f64,
}

impl StepTimings {
    pub fn total_s(&self) -> f64 {
        self.pre_s + self.fit_s + self.project_s + self.post_s
    }

    pub fn of_step(&self, step_idx: usize) -> f64 {
        match step_idx {
            0 => self.pre_s,
            1 => self.fit_s,
            2 => self.project_s,
            _ => self.post_s,
        }
    }
}

/// Resolve a FACTS workflow's `Compute` payloads into `Work` durations
/// using measured timings (× [`WORK_SCALE`]). The returned closure plugs
/// into `WorkflowEngine::execute_many`.
pub fn measured_workflow(
    timings: StepTimings,
) -> impl FnMut(usize, usize, TaskDescription) -> TaskDescription {
    move |_inst, step_idx, mut task| {
        if let Payload::Compute(_) = task.payload {
            task.payload = Payload::Work(timings.of_step(step_idx) * WORK_SCALE);
        }
        task
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_aot_variants() {
        assert_eq!(FactsSize::Small.dims(), (4, 32, 8, 32));
        assert_eq!(FactsSize::Default.dims(), (16, 128, 16, 96));
        assert_eq!(FactsSize::Large.dims(), (16, 128, 64, 96));
        assert_eq!(FactsSize::Default.artifact("fit_k2"), "fit_k2_default");
    }

    #[test]
    fn workflow_spec_is_a_valid_4_chain() {
        for size in [FactsSize::Small, FactsSize::Default, FactsSize::Large] {
            let w = workflow_spec(size);
            w.validate().unwrap();
            assert_eq!(w.depth().unwrap(), 4);
            for s in &w.steps {
                assert_eq!(s.task.cpus, 1);
                assert_eq!(s.task.mem_mb, 2048);
                assert!(matches!(s.task.payload, Payload::Compute(_)));
            }
        }
    }

    #[test]
    fn measured_workflow_resolves_compute() {
        let t = StepTimings { pre_s: 0.001, fit_s: 0.002, project_s: 0.003, post_s: 0.004 };
        let mut f = measured_workflow(t);
        let w = workflow_spec(FactsSize::Small);
        let out = f(0, 2, w.steps[2].task.clone());
        match out.payload {
            Payload::Work(s) => assert!((s - 0.003 * WORK_SCALE).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
        // Non-compute payloads pass through untouched.
        let plain = TaskDescription::executable("x", "x").with_payload(Payload::Sleep(1.0));
        assert_eq!(f(0, 0, plain.clone()).payload, plain.payload);
    }

    #[test]
    fn step_timings_accessors() {
        let t = StepTimings { pre_s: 1.0, fit_s: 2.0, project_s: 3.0, post_s: 4.0 };
        assert_eq!(t.total_s(), 10.0);
        assert_eq!(t.of_step(0), 1.0);
        assert_eq!(t.of_step(3), 4.0);
        assert_eq!(t.of_step(99), 4.0);
    }
}
