//! Function-as-a-Service platform simulator.
//!
//! The paper's Service Proxy "exposes a private interface to add new
//! managers like, for example, a Function as a Service manager" (§3.1).
//! This simulator is the platform behind that manager: a Lambda/Cloud-
//! Functions-style service with
//!
//! * a **concurrency limit** (account-level concurrent executions),
//! * **cold starts**: an invocation landing on no warm instance pays
//!   `cold_start_s`; instances stay warm for `keep_warm_s` after an
//!   invocation finishes,
//! * per-invocation duration scaled by the provider's `cpu_speed`
//!   (functions get one vCPU-equivalent slice).
//!
//! Deterministic given the seed, like the other substrates.

use super::event::{secs, to_secs, EventQueue, EventQueueKind};
use super::provider::PlatformProfile;
use crate::util::prng::Prng;

/// One function invocation (one Hydra task).
#[derive(Debug, Clone)]
pub struct Invocation {
    pub task_id: u64,
    /// Work in seconds on an AWS-reference core.
    pub work_s: f64,
    /// Fixed duration independent of platform speed.
    pub sleep_s: f64,
}

/// FaaS service parameters.
#[derive(Debug, Clone, Copy)]
pub struct FaasSpec {
    /// Maximum concurrent executions.
    pub concurrency: u32,
    /// Container/image cold-start cost (seconds).
    pub cold_start_s: f64,
    /// Warm-start dispatch cost (seconds).
    pub warm_start_s: f64,
    /// How long an idle instance stays warm.
    pub keep_warm_s: f64,
}

impl Default for FaasSpec {
    fn default() -> FaasSpec {
        FaasSpec { concurrency: 64, cold_start_s: 1.2, warm_start_s: 0.02, keep_warm_s: 300.0 }
    }
}

/// Per-invocation record (virtual seconds).
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub task_id: u64,
    pub started_s: f64,
    pub finished_s: f64,
    pub cold: bool,
}

#[derive(Debug, Clone)]
pub struct FaasReport {
    pub makespan_s: f64,
    pub invocations: Vec<InvocationRecord>,
    pub cold_starts: usize,
    pub peak_concurrency: u32,
}

enum Ev {
    Dispatch,
    Done { idx: usize },
}

/// Simulate a bulk of invocations against one FaaS service.
pub struct FaasSim {
    profile: PlatformProfile,
    spec: FaasSpec,
    invocations: Vec<Invocation>,
    #[allow(dead_code)]
    rng: Prng,
    queue_kind: EventQueueKind,
}

impl FaasSim {
    pub fn new(profile: PlatformProfile, spec: FaasSpec, seed: u64) -> FaasSim {
        FaasSim {
            profile,
            spec,
            invocations: Vec::new(),
            // hydra-lint: allow(prng-salt) — the sim's primary stream; substreams fork from it
            rng: Prng::new(seed),
            queue_kind: EventQueueKind::default(),
        }
    }

    /// Select the event-queue backing store (default: `Calendar`; see
    /// `sim::event` for the heap reference pattern).
    pub fn with_event_queue(mut self, kind: EventQueueKind) -> FaasSim {
        self.queue_kind = kind;
        self
    }

    pub fn submit(&mut self, invocations: Vec<Invocation>) {
        self.invocations.extend(invocations);
    }

    pub fn run(&mut self) -> FaasReport {
        let mut q: EventQueue<Ev> = EventQueue::with_kind(self.queue_kind);
        // API batch ingestion cost, as with the other services.
        let api = self.profile.api_batch_base_s
            + self.profile.api_per_object_s * self.invocations.len() as f64;
        q.schedule_at(secs(api), Ev::Dispatch);

        let mut next = 0usize;
        let mut running = 0u32;
        let mut peak = 0u32;
        let mut cold_starts = 0usize;
        // Pool of warm instances: each entry is the time it goes cold.
        let mut warm_until: Vec<f64> = Vec::new();
        let mut records: Vec<Option<InvocationRecord>> = vec![None; self.invocations.len()];

        while let Some((_, ev)) = q.pop() {
            match ev {
                Ev::Dispatch | Ev::Done { .. } => {
                    if let Ev::Done { idx } = ev {
                        running -= 1;
                        let now = to_secs(q.now());
                        let rec = records[idx].as_mut().unwrap();
                        rec.finished_s = now.max(rec.started_s);
                        // The instance that served it stays warm.
                        warm_until.push(now + self.spec.keep_warm_s);
                    }
                    // Dispatch as many pending invocations as concurrency
                    // allows.
                    while next < self.invocations.len() && running < self.spec.concurrency {
                        let now = to_secs(q.now());
                        // Reuse a warm instance if one is available.
                        let warm_slot = warm_until.iter().position(|&t| t > now);
                        let (start_cost, cold) = match warm_slot {
                            Some(i) => {
                                warm_until.swap_remove(i);
                                (self.spec.warm_start_s, false)
                            }
                            None => {
                                cold_starts += 1;
                                (self.spec.cold_start_s, true)
                            }
                        };
                        let inv = &self.invocations[next];
                        let run = inv.sleep_s + self.profile.payload_duration_s(inv.work_s, 1);
                        let started = now + start_cost;
                        records[next] = Some(InvocationRecord {
                            task_id: inv.task_id,
                            started_s: started,
                            finished_s: started + run,
                            cold,
                        });
                        q.schedule_in(secs(start_cost + run), Ev::Done { idx: next });
                        next += 1;
                        running += 1;
                        peak = peak.max(running);
                    }
                }
            }
        }

        FaasReport {
            makespan_s: to_secs(q.now()),
            invocations: records.into_iter().flatten().collect(),
            cold_starts,
            peak_concurrency: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::ProviderId;

    fn run(n: usize, work: f64, spec: FaasSpec) -> FaasReport {
        let profile = PlatformProfile::of(ProviderId::Aws);
        let mut sim = FaasSim::new(profile, spec, 1);
        sim.submit(
            (0..n as u64)
                .map(|i| Invocation { task_id: i, work_s: work, sleep_s: 0.0 })
                .collect(),
        );
        sim.run()
    }

    #[test]
    fn all_invocations_complete_in_order_windows() {
        let r = run(200, 1.0, FaasSpec::default());
        assert_eq!(r.invocations.len(), 200);
        for i in &r.invocations {
            assert!(i.finished_s >= i.started_s);
            assert!(i.finished_s <= r.makespan_s + 1e-9);
        }
    }

    #[test]
    fn concurrency_limit_respected() {
        let spec = FaasSpec { concurrency: 8, ..FaasSpec::default() };
        let r = run(100, 2.0, spec);
        assert!(r.peak_concurrency <= 8);
    }

    #[test]
    fn first_wave_is_cold_then_warm_reuse() {
        let spec = FaasSpec { concurrency: 16, ..FaasSpec::default() };
        let r = run(64, 1.0, spec);
        // 16 cold starts for the first wave; later invocations reuse.
        assert_eq!(r.cold_starts, 16, "{}", r.cold_starts);
        let warm = r.invocations.iter().filter(|i| !i.cold).count();
        assert_eq!(warm, 48);
    }

    #[test]
    fn keep_warm_expiry_forces_new_cold_starts() {
        // keep_warm shorter than the gap created by long runs => instances
        // go cold between waves.
        let spec = FaasSpec {
            concurrency: 4,
            keep_warm_s: 0.0, // expire immediately
            ..FaasSpec::default()
        };
        let r = run(12, 1.0, spec);
        assert_eq!(r.cold_starts, 12, "every invocation should be cold");
    }

    #[test]
    fn calendar_queue_matches_heap_queue() {
        // ISSUE 8: identical invocation schedule under both backends.
        let run_q = |k: EventQueueKind| {
            let profile = PlatformProfile::of(ProviderId::Aws);
            let mut sim = FaasSim::new(profile, FaasSpec::default(), 1).with_event_queue(k);
            sim.submit(
                (0..500)
                    .map(|i| Invocation { task_id: i, work_s: 0.5, sleep_s: 0.0 })
                    .collect(),
            );
            sim.run()
        };
        let (a, b) = (run_q(EventQueueKind::Calendar), run_q(EventQueueKind::Heap));
        assert_eq!(a.invocations.len(), b.invocations.len());
        for (x, y) in a.invocations.iter().zip(&b.invocations) {
            assert_eq!(x.task_id, y.task_id);
            assert_eq!(x.started_s.to_bits(), y.started_s.to_bits());
            assert_eq!(x.finished_s.to_bits(), y.finished_s.to_bits());
            assert_eq!(x.cold, y.cold);
        }
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.cold_starts, b.cold_starts);
        assert_eq!(a.peak_concurrency, b.peak_concurrency);
    }

    #[test]
    fn more_concurrency_is_faster() {
        let slow = run(128, 4.0, FaasSpec { concurrency: 8, ..FaasSpec::default() });
        let fast = run(128, 4.0, FaasSpec { concurrency: 64, ..FaasSpec::default() });
        assert!(fast.makespan_s < slow.makespan_s);
    }
}
