//! HPC platform simulator: Slurm-like batch queue + pilot-job agent.
//!
//! Stands in for ACCESS Bridges2 driven through RADICAL-Pilot (paper §3.1,
//! §5.3–5.4). The pilot abstraction is what Hydra's HPC Manager connector
//! targets: one batch *pilot job* acquires N whole nodes, waits in the
//! queue, boots an agent, and then executes bulk-submitted tasks on the
//! pilot's cores without further queue round-trips.
//!
//! Model:
//! * queue wait ~ lognormal(mean = `queue_wait_mean_s`, cv = `queue_wait_cv`)
//!   — the paper reports "short and consistent queuing time" for its runs.
//! * agent boot is a constant `pilot_boot_s`.
//! * the agent launches tasks through a serialized spawner costing
//!   `task_launch_s` per task (the RADICAL-Pilot executor), onto free cores
//!   greedily in FIFO order; a task holds `cores` cores for its duration.
//! * payload durations scale with the platform's `cpu_speed` (bare-metal
//!   EPYC on Bridges2: the Fig 5 advantage).
//!
//! # Scheduling cost (§Perf / DESIGN-note)
//!
//! The pilot is the HPC analogue of the Kubernetes free-capacity index:
//! the pilot's capacity is a *single* scalar (free cores across whole
//! nodes), so the index degenerates to a counter plus a FIFO cursor into
//! the submitted task list. [`PilotAgent`] keeps both; every simulator
//! event (agent-ready, launcher-free, task-done) is **O(1)** — there is no
//! per-event rescan of the task list, and a run processes O(T) events for
//! T tasks.

use super::event::{secs, to_secs, EventQueue};
use super::provider::PlatformProfile;
use crate::util::prng::Prng;

/// One executable task submitted onto the pilot. All-scalar and `Copy`:
/// the launch path reads specs in place, never cloning the bulk list.
#[derive(Debug, Clone, Copy)]
pub struct HpcTaskSpec {
    pub task_id: u64,
    pub cores: u32,
    /// Payload work in seconds on an AWS-reference core (0 = noop/sleep 0).
    pub work_s: f64,
    /// Fixed duration independent of platform speed (`sleep` tasks).
    pub sleep_s: f64,
}

impl HpcTaskSpec {
    pub fn noop(task_id: u64) -> HpcTaskSpec {
        HpcTaskSpec { task_id, cores: 1, work_s: 0.0, sleep_s: 0.0 }
    }
}

/// Pilot job resource request (whole nodes, as Bridges2 requires — the
/// paper notes it "does not allow acquiring less than 128 cores").
#[derive(Debug, Clone, Copy)]
pub struct PilotSpec {
    pub nodes: u32,
}

impl PilotSpec {
    pub fn cores(&self, profile: &PlatformProfile) -> u32 {
        self.nodes * profile.cores_per_node
    }
}

/// Per-task execution record (virtual seconds since pilot submission).
#[derive(Debug, Clone, PartialEq)]
pub struct HpcTaskRecord {
    pub task_id: u64,
    pub launched_s: f64,
    pub finished_s: f64,
    /// Whether the task exited non-zero (injected failures).
    pub failed: bool,
}

#[derive(Debug, Clone)]
pub struct HpcReport {
    pub queue_wait_s: f64,
    pub agent_ready_s: f64,
    /// Makespan from submission to last task completion (the TTX numerator
    /// for Experiment 3/4 on the HPC platform).
    pub makespan_s: f64,
    pub tasks: Vec<HpcTaskRecord>,
    pub events_processed: u64,
    pub peak_cores_busy: u32,
}

enum Ev {
    AgentReady,
    LauncherFree,
    TaskDone { idx: usize },
}

/// The agent's O(1) launch state: free-core counter + FIFO cursor +
/// serialized-launcher flag (see module docs).
struct PilotAgent {
    next: usize,
    free_cores: u32,
    total_cores: u32,
    launcher_free: bool,
    peak: u32,
}

impl PilotAgent {
    /// Launch the FIFO-head task if the launcher is idle and the head
    /// fits; otherwise wait for a TaskDone to free cores (head-of-line)
    /// or a LauncherFree to re-arm the spawner. O(1).
    fn try_launch(
        &mut self,
        q: &mut EventQueue<Ev>,
        profile: &PlatformProfile,
        tasks: &[HpcTaskSpec],
        fail_flags: &[bool],
        records: &mut [Option<HpcTaskRecord>],
    ) {
        if !self.launcher_free || self.next >= tasks.len() {
            return;
        }
        let t = tasks[self.next];
        let need = t.cores.min(self.total_cores); // oversized tasks clamp to pilot width
        if need > self.free_cores {
            return; // head-of-line: wait for a TaskDone to free cores
        }
        self.free_cores -= need;
        let busy = self.total_cores - self.free_cores;
        self.peak = self.peak.max(busy);
        let idx = self.next;
        self.next += 1;
        self.launcher_free = false;

        let launch_done = to_secs(q.now()) + profile.task_launch_s;
        let run = t.sleep_s + profile.payload_duration_s(t.work_s, need);
        records[idx] = Some(HpcTaskRecord {
            task_id: t.task_id,
            launched_s: launch_done,
            finished_s: launch_done + run, // finalized again at TaskDone
            failed: fail_flags[idx],
        });
        q.schedule_in(secs(profile.task_launch_s), Ev::LauncherFree);
        q.schedule_in(secs(profile.task_launch_s + run), Ev::TaskDone { idx });
    }
}

/// Simulate one pilot lifecycle executing `tasks`.
pub struct HpcSim {
    profile: PlatformProfile,
    pilot: PilotSpec,
    tasks: Vec<HpcTaskSpec>,
    rng: Prng,
    failure_rate: f64,
}

impl HpcSim {
    pub fn new(profile: PlatformProfile, pilot: PilotSpec, seed: u64) -> HpcSim {
        HpcSim { profile, pilot, tasks: Vec::new(), rng: Prng::new(seed), failure_rate: 0.0 }
    }

    /// Enable failure injection with per-task probability `p`.
    pub fn with_failure_rate(mut self, p: f64) -> HpcSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Bulk-submit task descriptions (the HPC Manager sends one bulk, as
    /// with the CaaS path).
    pub fn submit(&mut self, tasks: Vec<HpcTaskSpec>) {
        self.tasks.extend(tasks);
    }

    pub fn run(&mut self) -> HpcReport {
        let total_cores = self.pilot.cores(&self.profile);
        assert!(total_cores > 0, "pilot must request at least one node");
        let mut q: EventQueue<Ev> = EventQueue::new();

        let queue_wait = if self.profile.queue_wait_mean_s > 0.0 {
            self.rng
                .lognormal_mean_cv(self.profile.queue_wait_mean_s, self.profile.queue_wait_cv)
        } else {
            0.0
        };
        let agent_ready = queue_wait + self.profile.pilot_boot_s;
        q.schedule_at(secs(agent_ready), Ev::AgentReady);

        let fail_flags: Vec<bool> = (0..self.tasks.len())
            .map(|_| self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate))
            .collect();
        let mut records: Vec<Option<HpcTaskRecord>> = vec![None; self.tasks.len()];
        let mut agent = PilotAgent {
            next: 0,
            free_cores: total_cores,
            total_cores,
            launcher_free: false,
            peak: 0,
        };

        while let Some((_, ev)) = q.pop() {
            match ev {
                Ev::AgentReady | Ev::LauncherFree => {
                    agent.launcher_free = true;
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
                Ev::TaskDone { idx } => {
                    agent.free_cores += self.tasks[idx].cores.min(total_cores);
                    let rec = records[idx].as_mut().unwrap();
                    // Clamp against float rounding of the micros clock so
                    // finished >= launched holds exactly.
                    rec.finished_s = to_secs(q.now()).max(rec.launched_s);
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
            }
        }

        HpcReport {
            queue_wait_s: queue_wait,
            agent_ready_s: agent_ready,
            makespan_s: to_secs(q.now()),
            tasks: records.into_iter().flatten().collect(),
            events_processed: q.processed(),
            peak_cores_busy: agent.peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::{PlatformProfile, ProviderId};

    fn b2() -> PlatformProfile {
        PlatformProfile::of(ProviderId::Bridges2)
    }

    fn run_tasks(tasks: Vec<HpcTaskSpec>, nodes: u32, seed: u64) -> HpcReport {
        let mut sim = HpcSim::new(b2(), PilotSpec { nodes }, seed);
        sim.submit(tasks);
        sim.run()
    }

    #[test]
    fn all_tasks_complete() {
        let tasks: Vec<_> = (0..500).map(HpcTaskSpec::noop).collect();
        let r = run_tasks(tasks, 1, 1);
        assert_eq!(r.tasks.len(), 500);
        for t in &r.tasks {
            assert!(t.finished_s >= t.launched_s);
            assert!(t.launched_s >= r.agent_ready_s);
        }
    }

    #[test]
    fn queue_wait_short_and_consistent() {
        // Paper §5.3: short, consistent queue times. CV 0.15 around 45 s.
        let waits: Vec<f64> = (0..50)
            .map(|s| run_tasks(vec![HpcTaskSpec::noop(0)], 1, s).queue_wait_s)
            .collect();
        let sum: f64 = waits.iter().sum();
        let mean = sum / waits.len() as f64;
        assert!((mean - 45.0).abs() < 10.0, "mean queue wait {mean}");
        assert!(waits.iter().all(|w| *w > 10.0 && *w < 150.0));
    }

    #[test]
    fn cores_capacity_respected() {
        let tasks: Vec<_> = (0..300)
            .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 1.0, sleep_s: 0.0 })
            .collect();
        let r = run_tasks(tasks, 1, 3);
        assert!(r.peak_cores_busy <= 128);
        assert_eq!(r.tasks.len(), 300);
    }

    #[test]
    fn more_nodes_is_faster() {
        let mk = |nodes| {
            // Long enough tasks that cores, not the serialized launcher,
            // are the bottleneck.
            let tasks: Vec<_> = (0..512)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect();
            run_tasks(tasks, nodes, 7).makespan_s
        };
        let one = mk(1);
        let two = mk(2);
        assert!(two < one, "{two} !< {one}");
    }

    #[test]
    fn oversized_task_clamps_to_pilot_width() {
        // A 256-core task on a 128-core pilot runs clamped instead of
        // deadlocking the FIFO head.
        let spec = HpcTaskSpec { task_id: 0, cores: 256, work_s: 10.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 9);
        assert_eq!(r.tasks.len(), 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let t: Vec<_> = (0..100).map(HpcTaskSpec::noop).collect();
        let a = run_tasks(t.clone(), 2, 42);
        let b = run_tasks(t, 2, 42);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.queue_wait_s, b.queue_wait_s);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn bare_metal_speed_beats_cloud_reference() {
        // 110 s of AWS-reference work on one core should take ~10 s on
        // Bridges2 (cpu_speed 11).
        let spec = HpcTaskSpec { task_id: 0, cores: 1, work_s: 110.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 5);
        let t = &r.tasks[0];
        assert!(((t.finished_s - t.launched_s) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn event_count_scales_linearly_with_tasks() {
        // O(1) per event, O(T) events per run: AgentReady + per task one
        // LauncherFree + one TaskDone.
        for n in [100u64, 400] {
            let tasks: Vec<_> = (0..n).map(HpcTaskSpec::noop).collect();
            let r = run_tasks(tasks, 1, 11);
            assert_eq!(r.events_processed, 1 + 2 * n);
        }
    }
}
