//! HPC platform simulator: Slurm-like batch queue + pilot-job agents.
//!
//! Stands in for ACCESS Bridges2 driven through RADICAL-Pilot (paper §3.1,
//! §5.3–5.4). The pilot abstraction is what Hydra's HPC Manager connector
//! targets: a batch *pilot job* acquires N whole nodes, waits in the
//! queue, boots an agent, and then executes bulk-submitted tasks on the
//! pilot's cores without further queue round-trips. The paper's strong/
//! weak-scaling runs acquire **concurrent** pilots; this module models
//! both regimes:
//!
//! * [`HpcSim`] — one pilot lifecycle. The serial reference path: kept
//!   byte-for-byte stable, mirroring how `SchedulerKind::LinearScan`
//!   anchors the Kubernetes scheduler (ISSUE 5).
//! * [`MultiPilotSim`] — P concurrent pilots sharing one FIFO workload.
//!   Each pilot draws its own queue wait and boots its own agent; each
//!   task is placed on the **best-fit live pilot** (fewest free cores
//!   that still fit) through the shared
//!   [`CapacityIndex`](crate::sim::capacity::CapacityIndex), whose leaves
//!   are per-pilot free cores — O(log P) per placement. With `P == 1`
//!   the schedule degenerates to exactly the [`HpcSim`] schedule and the
//!   [`HpcTaskRecord`]s are **byte-identical** (enforced by
//!   `tests/pilot_equivalence.rs`).
//!
//! Shared model:
//! * queue wait ~ lognormal(mean = `queue_wait_mean_s`, cv = `queue_wait_cv`)
//!   — the paper reports "short and consistent queuing time" for its runs.
//! * agent boot is a constant `pilot_boot_s`.
//! * each agent launches tasks through a serialized spawner costing
//!   `task_launch_s` per task (the RADICAL-Pilot executor), onto free
//!   cores greedily in FIFO order; a task holds `cores` cores for its
//!   duration. A task wider than every pilot clamps to the widest pilot
//!   (single-pilot: to that pilot's width) instead of deadlocking the
//!   FIFO head.
//! * payload durations scale with the platform's `cpu_speed` (bare-metal
//!   EPYC on Bridges2: the Fig 5 advantage).
//!
//! # Scheduling cost (§Perf / DESIGN-note)
//!
//! In the single-pilot sim the capacity index degenerates to a counter
//! plus a FIFO cursor; the internal `PilotAgent` keeps both, making every
//! event (agent-ready, launcher-free, task-done) **O(1)**. The multi-pilot sim
//! keeps the FIFO cursor global and pays O(log P) per event for the
//! index query; both process O(T) events for T tasks. Launcher-busy
//! pilots are masked out of the index (leaf zeroed) so one query answers
//! "live, launcher idle, and fits" at once.

use super::capacity::{Cap, CapacityIndex};
use super::event::{secs, to_secs, EventQueue};
use super::provider::PlatformProfile;
use crate::util::prng::Prng;

/// One executable task submitted onto the pilot. All-scalar and `Copy`:
/// the launch path reads specs in place, never cloning the bulk list.
#[derive(Debug, Clone, Copy)]
pub struct HpcTaskSpec {
    pub task_id: u64,
    pub cores: u32,
    /// Payload work in seconds on an AWS-reference core (0 = noop/sleep 0).
    pub work_s: f64,
    /// Fixed duration independent of platform speed (`sleep` tasks).
    pub sleep_s: f64,
}

impl HpcTaskSpec {
    pub fn noop(task_id: u64) -> HpcTaskSpec {
        HpcTaskSpec { task_id, cores: 1, work_s: 0.0, sleep_s: 0.0 }
    }
}

/// Pilot job resource request (whole nodes, as Bridges2 requires — the
/// paper notes it "does not allow acquiring less than 128 cores").
#[derive(Debug, Clone, Copy)]
pub struct PilotSpec {
    pub nodes: u32,
}

impl PilotSpec {
    pub fn cores(&self, profile: &PlatformProfile) -> u32 {
        self.nodes * profile.cores_per_node
    }
}

/// Per-task execution record (virtual seconds since pilot submission).
#[derive(Debug, Clone, PartialEq)]
pub struct HpcTaskRecord {
    pub task_id: u64,
    pub launched_s: f64,
    pub finished_s: f64,
    /// Whether the task exited non-zero (injected failures).
    pub failed: bool,
}

#[derive(Debug, Clone)]
pub struct HpcReport {
    pub queue_wait_s: f64,
    pub agent_ready_s: f64,
    /// Makespan from submission to last task completion (the TTX numerator
    /// for Experiment 3/4 on the HPC platform).
    pub makespan_s: f64,
    pub tasks: Vec<HpcTaskRecord>,
    pub events_processed: u64,
    pub peak_cores_busy: u32,
}

enum Ev {
    AgentReady,
    LauncherFree,
    TaskDone { idx: usize },
}

/// The agent's O(1) launch state: free-core counter + FIFO cursor +
/// serialized-launcher flag (see module docs).
struct PilotAgent {
    next: usize,
    free_cores: u32,
    total_cores: u32,
    launcher_free: bool,
    peak: u32,
}

impl PilotAgent {
    /// Launch the FIFO-head task if the launcher is idle and the head
    /// fits; otherwise wait for a TaskDone to free cores (head-of-line)
    /// or a LauncherFree to re-arm the spawner. O(1).
    fn try_launch(
        &mut self,
        q: &mut EventQueue<Ev>,
        profile: &PlatformProfile,
        tasks: &[HpcTaskSpec],
        fail_flags: &[bool],
        records: &mut [Option<HpcTaskRecord>],
    ) {
        if !self.launcher_free || self.next >= tasks.len() {
            return;
        }
        let t = tasks[self.next];
        let need = t.cores.min(self.total_cores); // oversized tasks clamp to pilot width
        if need > self.free_cores {
            return; // head-of-line: wait for a TaskDone to free cores
        }
        self.free_cores -= need;
        let busy = self.total_cores - self.free_cores;
        self.peak = self.peak.max(busy);
        let idx = self.next;
        self.next += 1;
        self.launcher_free = false;

        let launch_done = to_secs(q.now()) + profile.task_launch_s;
        let run = t.sleep_s + profile.payload_duration_s(t.work_s, need);
        records[idx] = Some(HpcTaskRecord {
            task_id: t.task_id,
            launched_s: launch_done,
            finished_s: launch_done + run, // finalized again at TaskDone
            failed: fail_flags[idx],
        });
        q.schedule_in(secs(profile.task_launch_s), Ev::LauncherFree);
        q.schedule_in(secs(profile.task_launch_s + run), Ev::TaskDone { idx });
    }
}

/// Simulate one pilot lifecycle executing `tasks`.
///
/// The serial reference implementation: [`MultiPilotSim`] with one pilot
/// must reproduce this schedule byte for byte (the HPC analogue of
/// `SchedulerKind::LinearScan`).
pub struct HpcSim {
    profile: PlatformProfile,
    pilot: PilotSpec,
    tasks: Vec<HpcTaskSpec>,
    rng: Prng,
    failure_rate: f64,
}

impl HpcSim {
    pub fn new(profile: PlatformProfile, pilot: PilotSpec, seed: u64) -> HpcSim {
        HpcSim { profile, pilot, tasks: Vec::new(), rng: Prng::new(seed), failure_rate: 0.0 }
    }

    /// Enable failure injection with per-task probability `p`.
    pub fn with_failure_rate(mut self, p: f64) -> HpcSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Bulk-submit task descriptions (the HPC Manager sends one bulk, as
    /// with the CaaS path).
    pub fn submit(&mut self, tasks: Vec<HpcTaskSpec>) {
        self.tasks.extend(tasks);
    }

    pub fn run(&mut self) -> HpcReport {
        let total_cores = self.pilot.cores(&self.profile);
        assert!(total_cores > 0, "pilot must request at least one node");
        let mut q: EventQueue<Ev> = EventQueue::new();

        let queue_wait = if self.profile.queue_wait_mean_s > 0.0 {
            self.rng
                .lognormal_mean_cv(self.profile.queue_wait_mean_s, self.profile.queue_wait_cv)
        } else {
            0.0
        };
        let agent_ready = queue_wait + self.profile.pilot_boot_s;
        q.schedule_at(secs(agent_ready), Ev::AgentReady);

        let fail_flags: Vec<bool> = (0..self.tasks.len())
            .map(|_| self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate))
            .collect();
        let mut records: Vec<Option<HpcTaskRecord>> = vec![None; self.tasks.len()];
        let mut agent = PilotAgent {
            next: 0,
            free_cores: total_cores,
            total_cores,
            launcher_free: false,
            peak: 0,
        };

        while let Some((_, ev)) = q.pop() {
            match ev {
                Ev::AgentReady | Ev::LauncherFree => {
                    agent.launcher_free = true;
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
                Ev::TaskDone { idx } => {
                    agent.free_cores += self.tasks[idx].cores.min(total_cores);
                    let rec = records[idx].as_mut().unwrap();
                    // Clamp against float rounding of the micros clock so
                    // finished >= launched holds exactly.
                    rec.finished_s = to_secs(q.now()).max(rec.launched_s);
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
            }
        }

        HpcReport {
            queue_wait_s: queue_wait,
            agent_ready_s: agent_ready,
            makespan_s: to_secs(q.now()),
            tasks: records.into_iter().flatten().collect(),
            events_processed: q.processed(),
            peak_cores_busy: agent.peak,
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-pilot scheduling on the shared capacity index (ISSUE 5 tentpole)
// ---------------------------------------------------------------------------

/// Per-pilot outcome of a [`MultiPilotSim`] run: the lifecycle timings
/// plus the utilization accounting the HPC Manager reports per pilot.
#[derive(Debug, Clone, PartialEq)]
pub struct PilotStat {
    pub queue_wait_s: f64,
    pub agent_ready_s: f64,
    pub total_cores: u32,
    /// Tasks this pilot launched.
    pub tasks_executed: usize,
    pub peak_cores_busy: u32,
    /// Core-seconds of payload executed on this pilot (Σ cores × runtime,
    /// launch overhead excluded).
    pub busy_core_s: f64,
    /// `busy_core_s` over the pilot's live capacity
    /// (`total_cores × (makespan − agent_ready)`); 0 for a pilot that
    /// never went live before the run ended.
    pub utilization: f64,
}

/// Result of simulating P concurrent pilots over one bulk workload.
///
/// `tasks` carries the same [`HpcTaskRecord`]s as [`HpcReport`] — in
/// submission order, byte-identical to the serial reference when
/// `P == 1` — with the pilot assignment alongside in `pilot_of`.
#[derive(Debug, Clone)]
pub struct MultiPilotReport {
    /// Makespan from submission to the last task completion (for an
    /// empty workload: until the last pilot is staged). A pilot whose
    /// queue wait elapses after the workload has drained does not extend
    /// the makespan.
    pub makespan_s: f64,
    /// Per-task records, index-aligned with the submitted task list.
    pub tasks: Vec<HpcTaskRecord>,
    /// Pilot that executed each task, index-aligned with `tasks`.
    pub pilot_of: Vec<u32>,
    /// Per-pilot lifecycle + utilization stats, in pilot order.
    pub pilots: Vec<PilotStat>,
    pub events_processed: u64,
}

impl MultiPilotReport {
    /// Earliest agent-ready instant across pilots — the moment execution
    /// could first start (what the single-pilot `agent_ready_s` was; the
    /// workflow engine charges this one-off cost on the first wave only).
    pub fn first_agent_ready_s(&self) -> f64 {
        self.pilots.iter().map(|p| p.agent_ready_s).fold(f64::INFINITY, f64::min)
    }

    /// Total cores across all pilots.
    pub fn total_cores(&self) -> u32 {
        self.pilots.iter().map(|p| p.total_cores).sum()
    }
}

enum MpEv {
    /// A pilot's batch job started and its agent finished booting.
    PilotReady { pilot: usize },
    /// A pilot's serialized launcher finished spawning a task.
    LauncherFree { pilot: usize },
    /// A task completed on a pilot.
    TaskDone { pilot: usize, idx: usize },
}

/// Run-time state of one staged pilot.
struct PilotState {
    total_cores: u32,
    free_cores: u32,
    live: bool,
    launcher_free: bool,
    peak: u32,
    tasks_executed: usize,
    busy_core_s: f64,
    queue_wait_s: f64,
    agent_ready_s: f64,
}

/// Simulate P concurrent pilots executing one bulk-submitted workload.
///
/// Pilots stage independently (per-pilot queue wait + agent boot drawn
/// from the same model as [`HpcSim`], in pilot order — so with one pilot
/// the PRNG stream is consumed identically). Tasks launch in FIFO order;
/// the head task goes to the best-fit live pilot found through the
/// shared capacity index, or waits (head-of-line) until one fits.
///
/// `run` consumes the staged workload; construct a fresh sim per run.
pub struct MultiPilotSim {
    profile: PlatformProfile,
    specs: Vec<PilotSpec>,
    tasks: Vec<HpcTaskSpec>,
    rng: Prng,
    failure_rate: f64,
    // Run state (populated by `run`, queryable afterwards).
    pilots: Vec<PilotState>,
    index: CapacityIndex,
    next: usize,
    widest: u32,
}

impl MultiPilotSim {
    /// Heterogeneous pilots: one entry per pilot job to stage.
    pub fn new(profile: PlatformProfile, pilots: Vec<PilotSpec>, seed: u64) -> MultiPilotSim {
        assert!(!pilots.is_empty(), "at least one pilot required");
        MultiPilotSim {
            profile,
            specs: pilots,
            tasks: Vec::new(),
            rng: Prng::new(seed),
            failure_rate: 0.0,
            pilots: Vec::new(),
            index: CapacityIndex::zeroed(0),
            next: 0,
            widest: 0,
        }
    }

    /// `count` identical pilots (the common weak-scaling shape).
    pub fn uniform(
        profile: PlatformProfile,
        pilot: PilotSpec,
        count: u32,
        seed: u64,
    ) -> MultiPilotSim {
        assert!(count >= 1, "at least one pilot required");
        MultiPilotSim::new(profile, vec![pilot; count as usize], seed)
    }

    /// Enable failure injection with per-task probability `p`.
    pub fn with_failure_rate(mut self, p: f64) -> MultiPilotSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Bulk-submit task descriptions (one bulk for the whole pilot fleet;
    /// the connector shards the *transport*, not the schedule).
    pub fn submit(&mut self, tasks: Vec<HpcTaskSpec>) {
        self.tasks.extend(tasks);
    }

    /// Total free cores across pilots right now — after `run`, every
    /// reservation must have been returned (the core-conservation
    /// invariant surface for `tests/prop_invariants.rs`).
    pub fn free_capacity(&self) -> u32 {
        self.pilots.iter().map(|p| p.free_cores).sum()
    }

    /// Re-derive pilot `p`'s index leaf from its state: the leaf is the
    /// pilot's free cores *plus an eligibility bias of one* while the
    /// pilot is live with an idle launcher, and zero otherwise. The bias
    /// keeps zero-core demands from matching masked pilots (queries add
    /// one to the demand symmetrically), so a single O(log P) index query
    /// answers "live ∧ launcher idle ∧ fits".
    fn sync_slot(&mut self, p: usize) {
        let st = &self.pilots[p];
        let leaf = if st.live && st.launcher_free {
            st.free_cores.saturating_add(1)
        } else {
            0
        };
        self.index.set(p, Cap::cores(leaf));
    }

    /// Launch FIFO-head tasks while a live, launcher-idle pilot fits the
    /// head; stop on the first head that fits nowhere (head-of-line, as
    /// in the serial reference) or when the workload is drained.
    fn try_launch(
        &mut self,
        q: &mut EventQueue<MpEv>,
        records: &mut [Option<HpcTaskRecord>],
        pilot_of: &mut [u32],
        fail_flags: &[bool],
    ) {
        while self.next < self.tasks.len() {
            let t = self.tasks[self.next];
            // Oversized tasks clamp to the widest pilot (the multi-pilot
            // generalization of the serial path's clamp to pilot width).
            let need = t.cores.min(self.widest);
            let Some(slot) = self.index.best_fit(Cap::cores(need.saturating_add(1))) else {
                return;
            };
            let pilot = slot as usize;
            let idx = self.next;
            self.next += 1;
            let launch_done = to_secs(q.now()) + self.profile.task_launch_s;
            let run_s = t.sleep_s + self.profile.payload_duration_s(t.work_s, need);
            {
                let st = &mut self.pilots[pilot];
                st.free_cores -= need;
                st.peak = st.peak.max(st.total_cores - st.free_cores);
                st.launcher_free = false;
                st.tasks_executed += 1;
                st.busy_core_s += f64::from(need) * run_s;
            }
            self.sync_slot(pilot); // masked while the launcher spawns
            records[idx] = Some(HpcTaskRecord {
                task_id: t.task_id,
                launched_s: launch_done,
                finished_s: launch_done + run_s, // finalized again at TaskDone
                failed: fail_flags[idx],
            });
            pilot_of[idx] = slot;
            q.schedule_in(secs(self.profile.task_launch_s), MpEv::LauncherFree { pilot });
            q.schedule_in(
                secs(self.profile.task_launch_s + run_s),
                MpEv::TaskDone { pilot, idx },
            );
        }
    }

    /// Stage the pilots, run the workload to quiescence, and report.
    pub fn run(&mut self) -> MultiPilotReport {
        let mut q: EventQueue<MpEv> = EventQueue::new();
        let mut staged = Vec::with_capacity(self.specs.len());
        for spec in &self.specs {
            let total_cores = spec.cores(&self.profile);
            assert!(total_cores > 0, "pilot must request at least one node");
            // Pilot-order draws: with one pilot this consumes the PRNG
            // exactly like the serial reference.
            let queue_wait_s = if self.profile.queue_wait_mean_s > 0.0 {
                self.rng
                    .lognormal_mean_cv(self.profile.queue_wait_mean_s, self.profile.queue_wait_cv)
            } else {
                0.0
            };
            staged.push(PilotState {
                total_cores,
                free_cores: total_cores,
                live: false,
                launcher_free: false,
                peak: 0,
                tasks_executed: 0,
                busy_core_s: 0.0,
                queue_wait_s,
                agent_ready_s: queue_wait_s + self.profile.pilot_boot_s,
            });
        }
        self.pilots = staged;
        for (p, st) in self.pilots.iter().enumerate() {
            q.schedule_at(secs(st.agent_ready_s), MpEv::PilotReady { pilot: p });
        }
        self.widest = self.pilots.iter().map(|s| s.total_cores).max().unwrap_or(0);
        self.index = CapacityIndex::zeroed(self.pilots.len());
        self.next = 0;

        let fail_flags: Vec<bool> = (0..self.tasks.len())
            .map(|_| self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate))
            .collect();
        let mut records: Vec<Option<HpcTaskRecord>> = vec![None; self.tasks.len()];
        let mut pilot_of: Vec<u32> = vec![0; self.tasks.len()];
        // Last task-completion instant. The makespan ends here, not at the
        // final queue event: a pilot whose queue wait elapses after the
        // workload has drained must not inflate TTX (with one pilot the
        // last event *is* the last TaskDone, so this stays bit-identical
        // to the serial reference).
        let mut last_done_s = 0.0f64;

        while let Some((_, ev)) = q.pop() {
            match ev {
                MpEv::PilotReady { pilot } => {
                    let st = &mut self.pilots[pilot];
                    st.live = true;
                    st.launcher_free = true;
                    self.sync_slot(pilot);
                    self.try_launch(&mut q, &mut records, &mut pilot_of, &fail_flags);
                }
                MpEv::LauncherFree { pilot } => {
                    self.pilots[pilot].launcher_free = true;
                    self.sync_slot(pilot);
                    self.try_launch(&mut q, &mut records, &mut pilot_of, &fail_flags);
                }
                MpEv::TaskDone { pilot, idx } => {
                    let need = self.tasks[idx].cores.min(self.widest);
                    let st = &mut self.pilots[pilot];
                    st.free_cores += need;
                    debug_assert!(
                        st.free_cores <= st.total_cores,
                        "core conservation violated on pilot {pilot}"
                    );
                    self.sync_slot(pilot);
                    let rec = records[idx].as_mut().expect("done task was launched");
                    // Clamp against float rounding of the micros clock so
                    // finished >= launched holds exactly.
                    rec.finished_s = to_secs(q.now()).max(rec.launched_s);
                    // Events pop in time order, so the final assignment is
                    // the latest TaskDone (every launch's LauncherFree
                    // precedes its TaskDone, so this is the last task
                    // event overall).
                    last_done_s = to_secs(q.now());
                    self.try_launch(&mut q, &mut records, &mut pilot_of, &fail_flags);
                }
            }
        }

        // Empty workload: the run "ends" when the last pilot is staged,
        // exactly as the serial reference reports for zero tasks.
        let makespan_s = if self.tasks.is_empty() { to_secs(q.now()) } else { last_done_s };
        let pilots = self
            .pilots
            .iter()
            .map(|st| {
                let window = (makespan_s - st.agent_ready_s).max(0.0);
                let capacity = f64::from(st.total_cores) * window;
                PilotStat {
                    queue_wait_s: st.queue_wait_s,
                    agent_ready_s: st.agent_ready_s,
                    total_cores: st.total_cores,
                    tasks_executed: st.tasks_executed,
                    peak_cores_busy: st.peak,
                    busy_core_s: st.busy_core_s,
                    utilization: if capacity > 0.0 {
                        (st.busy_core_s / capacity).min(1.0)
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        let tasks: Vec<HpcTaskRecord> = records.into_iter().flatten().collect();
        debug_assert_eq!(tasks.len(), pilot_of.len(), "every submitted task must complete");
        MultiPilotReport {
            makespan_s,
            tasks,
            pilot_of,
            pilots,
            events_processed: q.processed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::{PlatformProfile, ProviderId};

    fn b2() -> PlatformProfile {
        PlatformProfile::of(ProviderId::Bridges2)
    }

    fn run_tasks(tasks: Vec<HpcTaskSpec>, nodes: u32, seed: u64) -> HpcReport {
        let mut sim = HpcSim::new(b2(), PilotSpec { nodes }, seed);
        sim.submit(tasks);
        sim.run()
    }

    #[test]
    fn all_tasks_complete() {
        let tasks: Vec<_> = (0..500).map(HpcTaskSpec::noop).collect();
        let r = run_tasks(tasks, 1, 1);
        assert_eq!(r.tasks.len(), 500);
        for t in &r.tasks {
            assert!(t.finished_s >= t.launched_s);
            assert!(t.launched_s >= r.agent_ready_s);
        }
    }

    #[test]
    fn queue_wait_short_and_consistent() {
        // Paper §5.3: short, consistent queue times. CV 0.15 around 45 s.
        let waits: Vec<f64> = (0..50)
            .map(|s| run_tasks(vec![HpcTaskSpec::noop(0)], 1, s).queue_wait_s)
            .collect();
        let sum: f64 = waits.iter().sum();
        let mean = sum / waits.len() as f64;
        assert!((mean - 45.0).abs() < 10.0, "mean queue wait {mean}");
        assert!(waits.iter().all(|w| *w > 10.0 && *w < 150.0));
    }

    #[test]
    fn cores_capacity_respected() {
        let tasks: Vec<_> = (0..300)
            .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 1.0, sleep_s: 0.0 })
            .collect();
        let r = run_tasks(tasks, 1, 3);
        assert!(r.peak_cores_busy <= 128);
        assert_eq!(r.tasks.len(), 300);
    }

    #[test]
    fn more_nodes_is_faster() {
        let mk = |nodes| {
            // Long enough tasks that cores, not the serialized launcher,
            // are the bottleneck.
            let tasks: Vec<_> = (0..512)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect();
            run_tasks(tasks, nodes, 7).makespan_s
        };
        let one = mk(1);
        let two = mk(2);
        assert!(two < one, "{two} !< {one}");
    }

    #[test]
    fn oversized_task_clamps_to_pilot_width() {
        // A 256-core task on a 128-core pilot runs clamped instead of
        // deadlocking the FIFO head.
        let spec = HpcTaskSpec { task_id: 0, cores: 256, work_s: 10.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 9);
        assert_eq!(r.tasks.len(), 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let t: Vec<_> = (0..100).map(HpcTaskSpec::noop).collect();
        let a = run_tasks(t.clone(), 2, 42);
        let b = run_tasks(t, 2, 42);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.queue_wait_s, b.queue_wait_s);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn bare_metal_speed_beats_cloud_reference() {
        // 110 s of AWS-reference work on one core should take ~10 s on
        // Bridges2 (cpu_speed 11).
        let spec = HpcTaskSpec { task_id: 0, cores: 1, work_s: 110.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 5);
        let t = &r.tasks[0];
        assert!(((t.finished_s - t.launched_s) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn event_count_scales_linearly_with_tasks() {
        // O(1) per event, O(T) events per run: AgentReady + per task one
        // LauncherFree + one TaskDone.
        for n in [100u64, 400] {
            let tasks: Vec<_> = (0..n).map(HpcTaskSpec::noop).collect();
            let r = run_tasks(tasks, 1, 11);
            assert_eq!(r.events_processed, 1 + 2 * n);
        }
    }

    // ---- multi-pilot (ISSUE 5 tentpole) ----------------------------------

    fn run_multi(
        tasks: Vec<HpcTaskSpec>,
        nodes: u32,
        pilots: u32,
        seed: u64,
    ) -> MultiPilotReport {
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes }, pilots, seed);
        sim.submit(tasks);
        sim.run()
    }

    #[test]
    fn single_pilot_reproduces_serial_reference() {
        // The full-matrix equivalence lives in tests/pilot_equivalence.rs;
        // this is the fast inline guard.
        let tasks: Vec<_> = (0..200)
            .map(|i| HpcTaskSpec {
                task_id: i,
                cores: 1 + (i as u32 % 5),
                work_s: 3.0,
                sleep_s: 0.0,
            })
            .collect();
        let serial = run_tasks(tasks.clone(), 2, 42);
        let multi = run_multi(tasks, 2, 1, 42);
        assert_eq!(serial.tasks, multi.tasks);
        assert_eq!(serial.events_processed, multi.events_processed);
        assert_eq!(serial.makespan_s, multi.makespan_s);
        assert_eq!(serial.queue_wait_s, multi.pilots[0].queue_wait_s);
        assert_eq!(serial.agent_ready_s, multi.pilots[0].agent_ready_s);
        assert_eq!(serial.peak_cores_busy, multi.pilots[0].peak_cores_busy);
        assert!(multi.pilot_of.iter().all(|&p| p == 0));
    }

    #[test]
    fn more_pilots_is_faster_weak_scaling() {
        // Core-bound workload: 4 concurrent pilots quadruple the fleet's
        // cores and must beat one pilot despite four queue waits.
        let mk = |pilots: u32| {
            let tasks: Vec<_> = (0..512)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect();
            run_multi(tasks, 1, pilots, 7).makespan_s
        };
        let one = mk(1);
        let four = mk(4);
        assert!(four < one, "{four} !< {one}");
    }

    #[test]
    fn every_pilot_contributes_under_load() {
        // 800 long tasks: far more than the fleet can drain before the
        // last pilot's queue wait elapses, so every pilot must launch.
        let tasks: Vec<_> = (0..800)
            .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 2000.0, sleep_s: 0.0 })
            .collect();
        let r = run_multi(tasks, 1, 4, 13);
        assert_eq!(r.tasks.len(), 800);
        assert_eq!(r.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(), 800);
        for (i, p) in r.pilots.iter().enumerate() {
            assert!(p.tasks_executed > 0, "pilot {i} idle");
            assert!(p.peak_cores_busy <= p.total_cores);
            assert!((0.0..=1.0).contains(&p.utilization), "pilot {i}: {}", p.utilization);
        }
        // pilot_of is consistent with the per-pilot counts.
        for (i, p) in r.pilots.iter().enumerate() {
            let n = r.pilot_of.iter().filter(|&&x| x == i as u32).count();
            assert_eq!(n, p.tasks_executed, "pilot {i}");
        }
    }

    #[test]
    fn oversized_task_clamps_to_widest_pilot_and_lands_there() {
        // Pilots of 1 and 2 nodes: a 300-core task clamps to 256 (the
        // widest pilot) and can only run there.
        let mut sim = MultiPilotSim::new(
            b2(),
            vec![PilotSpec { nodes: 1 }, PilotSpec { nodes: 2 }],
            9,
        );
        sim.submit(vec![HpcTaskSpec { task_id: 0, cores: 300, work_s: 10.0, sleep_s: 0.0 }]);
        let r = sim.run();
        assert_eq!(r.tasks.len(), 1);
        assert_eq!(r.pilot_of[0], 1, "must land on the 256-core pilot");
        assert_eq!(r.pilots[1].peak_cores_busy, 256, "clamped to the widest width");
        assert_eq!(sim.free_capacity(), 128 + 256, "all cores returned");
    }

    #[test]
    fn multi_pilot_deterministic_per_seed() {
        let t: Vec<_> = (0..300).map(HpcTaskSpec::noop).collect();
        let a = run_multi(t.clone(), 1, 8, 21);
        let b = run_multi(t, 1, 8, 21);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.pilot_of, b.pilot_of);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.events_processed, b.events_processed);
    }
}
