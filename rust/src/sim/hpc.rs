//! HPC platform simulator: Slurm-like batch queue + pilot-job agents.
//!
//! Stands in for ACCESS Bridges2 driven through RADICAL-Pilot (paper §3.1,
//! §5.3–5.4). The pilot abstraction is what Hydra's HPC Manager connector
//! targets: a batch *pilot job* acquires N whole nodes, waits in the
//! queue, boots an agent, and then executes bulk-submitted tasks on the
//! pilot's cores without further queue round-trips. The paper's strong/
//! weak-scaling runs acquire **concurrent** pilots; this module models
//! both regimes:
//!
//! * [`HpcSim`] — one pilot lifecycle. The serial reference path: kept
//!   byte-for-byte stable, mirroring how `SchedulerKind::LinearScan`
//!   anchors the Kubernetes scheduler (ISSUE 5).
//! * [`MultiPilotSim`] — P concurrent pilots sharing one FIFO workload.
//!   Each pilot draws its own queue wait and boots its own agent; each
//!   task is placed on the **best-fit live pilot** (fewest free cores
//!   that still fit) through the shared
//!   [`CapacityIndex`](crate::sim::capacity::CapacityIndex), whose leaves
//!   are per-pilot free cores — O(log P) per placement. With `P == 1`
//!   the schedule degenerates to exactly the [`HpcSim`] schedule and the
//!   [`HpcTaskRecord`]s are **byte-identical** (enforced by
//!   `tests/pilot_equivalence.rs`).
//!
//! Shared model:
//! * queue wait ~ lognormal(mean = `queue_wait_mean_s`, cv = `queue_wait_cv`)
//!   — the paper reports "short and consistent queuing time" for its runs.
//! * agent boot is a constant `pilot_boot_s`.
//! * each agent launches tasks through a serialized spawner costing
//!   `task_launch_s` per task (the RADICAL-Pilot executor), onto free
//!   cores greedily in FIFO order; a task holds `cores` cores for its
//!   duration. A task wider than every pilot clamps to the widest pilot
//!   (single-pilot: to that pilot's width) instead of deadlocking the
//!   FIFO head.
//! * payload durations scale with the platform's `cpu_speed` (bare-metal
//!   EPYC on Bridges2: the Fig 5 advantage).
//!
//! # Failure model (ISSUE 6)
//!
//! Two independent layers, both deterministic per seed:
//!
//! * **Task-level** injection (`with_failure_rate`): each task draws a
//!   failed flag from the main PRNG stream; the record carries
//!   `failed: true` but the schedule is unaffected (application-level
//!   failures, the knob the CaaS path already had).
//! * **Pilot-level** faults ([`FaultSpec`], `with_faults`, multi-pilot
//!   only): each pilot draws — from a *dedicated* PRNG stream seeded
//!   `seed ^ FAULT_STREAM_SALT`, so [`FaultSpec::none`] consumes nothing
//!   and the fault-free schedule stays byte-identical to the healthy
//!   reference — a materialization failure (the batch job is lost before
//!   its agent boots), an exponential MTBF kill offset, and a walltime
//!   expiry (measured from job start, i.e. queue-wait elapse). The
//!   earliest applicable instant becomes the pilot's `PilotDead` event:
//!   the dead pilot's leaf in the shared capacity index is zeroed, its
//!   in-flight tasks are rolled back (cores returned, records voided)
//!   and re-queued **at the FIFO head** in submission order for
//!   placement on surviving pilots — clamping now against the widest
//!   *live* pilot. A task killed more than `retry_budget` times is
//!   reported **abandoned**: never silently dropped, never duplicated.
//!   If the whole fleet dies the run ends as a partial report (completed
//!   records + abandoned ids partition the submission) instead of
//!   hanging.
//!
//! # Scheduling cost (§Perf / DESIGN-note)
//!
//! In the single-pilot sim the capacity index degenerates to a counter
//! plus a FIFO cursor; the internal `PilotAgent` keeps both, making every
//! event (agent-ready, launcher-free, task-done) **O(1)**. The multi-pilot sim
//! keeps the FIFO cursor global and pays O(log P) per event for the
//! index query; both process O(T) events for T tasks. Launcher-busy
//! pilots are masked out of the index (leaf zeroed) so one query answers
//! "live, launcher idle, and fits" at once.

use std::collections::VecDeque;

use super::capacity::{Cap, CapacityIndex};
use super::event::{secs, to_secs, EventQueue, EventQueueKind};
use super::provider::PlatformProfile;
use crate::util::prng::Prng;

/// One executable task submitted onto the pilot. All-scalar and `Copy`:
/// the launch path reads specs in place, never cloning the bulk list.
#[derive(Debug, Clone, Copy)]
pub struct HpcTaskSpec {
    pub task_id: u64,
    pub cores: u32,
    /// Payload work in seconds on an AWS-reference core (0 = noop/sleep 0).
    pub work_s: f64,
    /// Fixed duration independent of platform speed (`sleep` tasks).
    pub sleep_s: f64,
}

impl HpcTaskSpec {
    pub fn noop(task_id: u64) -> HpcTaskSpec {
        HpcTaskSpec { task_id, cores: 1, work_s: 0.0, sleep_s: 0.0 }
    }
}

/// Pilot job resource request (whole nodes, as Bridges2 requires — the
/// paper notes it "does not allow acquiring less than 128 cores").
#[derive(Debug, Clone, Copy)]
pub struct PilotSpec {
    pub nodes: u32,
}

impl PilotSpec {
    pub fn cores(&self, profile: &PlatformProfile) -> u32 {
        self.nodes * profile.cores_per_node
    }
}

/// Per-task execution record (virtual seconds since pilot submission).
#[derive(Debug, Clone, PartialEq)]
pub struct HpcTaskRecord {
    pub task_id: u64,
    pub launched_s: f64,
    pub finished_s: f64,
    /// Whether the task exited non-zero (injected failures).
    pub failed: bool,
}

#[derive(Debug, Clone)]
pub struct HpcReport {
    pub queue_wait_s: f64,
    pub agent_ready_s: f64,
    /// Makespan from submission to last task completion (the TTX numerator
    /// for Experiment 3/4 on the HPC platform).
    pub makespan_s: f64,
    pub tasks: Vec<HpcTaskRecord>,
    pub events_processed: u64,
    pub peak_cores_busy: u32,
}

enum Ev {
    AgentReady,
    LauncherFree,
    TaskDone { idx: usize },
}

/// The agent's O(1) launch state: free-core counter + FIFO cursor +
/// serialized-launcher flag (see module docs).
struct PilotAgent {
    next: usize,
    free_cores: u32,
    total_cores: u32,
    launcher_free: bool,
    peak: u32,
}

impl PilotAgent {
    /// Launch the FIFO-head task if the launcher is idle and the head
    /// fits; otherwise wait for a TaskDone to free cores (head-of-line)
    /// or a LauncherFree to re-arm the spawner. O(1).
    fn try_launch(
        &mut self,
        q: &mut EventQueue<Ev>,
        profile: &PlatformProfile,
        tasks: &[HpcTaskSpec],
        fail_flags: &[bool],
        records: &mut [Option<HpcTaskRecord>],
    ) {
        if !self.launcher_free || self.next >= tasks.len() {
            return;
        }
        let t = tasks[self.next];
        let need = t.cores.min(self.total_cores); // oversized tasks clamp to pilot width
        if need > self.free_cores {
            return; // head-of-line: wait for a TaskDone to free cores
        }
        self.free_cores -= need;
        let busy = self.total_cores - self.free_cores;
        self.peak = self.peak.max(busy);
        let idx = self.next;
        self.next += 1;
        self.launcher_free = false;

        let launch_done = to_secs(q.now()) + profile.task_launch_s;
        let run = t.sleep_s + profile.payload_duration_s(t.work_s, need);
        records[idx] = Some(HpcTaskRecord {
            task_id: t.task_id,
            launched_s: launch_done,
            finished_s: launch_done + run, // finalized again at TaskDone
            failed: fail_flags[idx],
        });
        q.schedule_in(secs(profile.task_launch_s), Ev::LauncherFree);
        q.schedule_in(secs(profile.task_launch_s + run), Ev::TaskDone { idx });
    }
}

/// Simulate one pilot lifecycle executing `tasks`.
///
/// The serial reference implementation: [`MultiPilotSim`] with one pilot
/// must reproduce this schedule byte for byte (the HPC analogue of
/// `SchedulerKind::LinearScan`).
pub struct HpcSim {
    profile: PlatformProfile,
    pilot: PilotSpec,
    tasks: Vec<HpcTaskSpec>,
    rng: Prng,
    failure_rate: f64,
    queue_kind: EventQueueKind,
}

impl HpcSim {
    pub fn new(profile: PlatformProfile, pilot: PilotSpec, seed: u64) -> HpcSim {
        HpcSim {
            profile,
            pilot,
            tasks: Vec::new(),
            // hydra-lint: allow(prng-salt) — the sim's primary stream; substreams fork from it
            rng: Prng::new(seed),
            failure_rate: 0.0,
            queue_kind: EventQueueKind::default(),
        }
    }

    /// Enable failure injection with per-task probability `p`.
    pub fn with_failure_rate(mut self, p: f64) -> HpcSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Select the event-queue backing store (default: `Calendar`; see
    /// `sim::event` for the heap reference pattern).
    pub fn with_event_queue(mut self, kind: EventQueueKind) -> HpcSim {
        self.queue_kind = kind;
        self
    }

    /// Bulk-submit task descriptions (the HPC Manager sends one bulk, as
    /// with the CaaS path).
    pub fn submit(&mut self, tasks: Vec<HpcTaskSpec>) {
        self.tasks.extend(tasks);
    }

    pub fn run(&mut self) -> HpcReport {
        let total_cores = self.pilot.cores(&self.profile);
        assert!(total_cores > 0, "pilot must request at least one node");
        let mut q: EventQueue<Ev> = EventQueue::with_kind(self.queue_kind);

        let queue_wait = if self.profile.queue_wait_mean_s > 0.0 {
            self.rng
                .lognormal_mean_cv(self.profile.queue_wait_mean_s, self.profile.queue_wait_cv)
        } else {
            0.0
        };
        let agent_ready = queue_wait + self.profile.pilot_boot_s;
        q.schedule_at(secs(agent_ready), Ev::AgentReady);

        let fail_flags: Vec<bool> = (0..self.tasks.len())
            .map(|_| self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate))
            .collect();
        let mut records: Vec<Option<HpcTaskRecord>> = vec![None; self.tasks.len()];
        let mut agent = PilotAgent {
            next: 0,
            free_cores: total_cores,
            total_cores,
            launcher_free: false,
            peak: 0,
        };

        while let Some((_, ev)) = q.pop() {
            match ev {
                Ev::AgentReady | Ev::LauncherFree => {
                    agent.launcher_free = true;
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
                Ev::TaskDone { idx } => {
                    agent.free_cores += self.tasks[idx].cores.min(total_cores);
                    let rec = records[idx].as_mut().unwrap();
                    // Clamp against float rounding of the micros clock so
                    // finished >= launched holds exactly.
                    rec.finished_s = to_secs(q.now()).max(rec.launched_s);
                    agent.try_launch(&mut q, &self.profile, &self.tasks, &fail_flags,
                                     &mut records);
                }
            }
        }

        HpcReport {
            queue_wait_s: queue_wait,
            agent_ready_s: agent_ready,
            makespan_s: to_secs(q.now()),
            tasks: records.into_iter().flatten().collect(),
            events_processed: q.processed(),
            peak_cores_busy: agent.peak,
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-pilot scheduling on the shared capacity index (ISSUE 5 tentpole)
// + pilot-fleet fault tolerance (ISSUE 6 tentpole)
// ---------------------------------------------------------------------------

/// Pilot-level fault model (ISSUE 6). Every knob is off at zero; the
/// stochastic draws come from a dedicated PRNG stream
/// (`seed ^ FAULT_STREAM_SALT`) so [`FaultSpec::none`] consumes nothing
/// from the schedule's stream and the fault-free multi-pilot schedule
/// stays byte-identical to the PR 5 reference
/// (`tests/pilot_equivalence.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Batch walltime limit in seconds, measured from job start (the
    /// queue-wait elapse) — a pilot whose walltime expires before its
    /// agent boots dies without materializing. 0 disables.
    pub walltime_s: f64,
    /// Mean time between pilot failures: each pilot draws an exponential
    /// kill offset from its agent-ready instant. 0 disables.
    pub mtbf_s: f64,
    /// Probability the pilot never materializes (batch job lost / agent
    /// fails to boot): it dies at its would-be agent-ready instant
    /// without executing anything. 0 disables.
    pub materialization_failure_p: f64,
    /// How many times a killed task may be re-queued before it is
    /// reported abandoned. 0 abandons on the first kill.
    pub retry_budget: u32,
    /// Deterministic kill for benches/tests: `(pilot, offset_s)` kills
    /// that pilot `offset_s` virtual seconds after its agent is ready,
    /// independent of the stochastic knobs.
    pub injected_kill: Option<(u32, f64)>,
}

impl FaultSpec {
    /// No faults: the multi-pilot schedule is byte-identical to a run
    /// without the fault machinery.
    pub fn none() -> FaultSpec {
        FaultSpec {
            walltime_s: 0.0,
            mtbf_s: 0.0,
            materialization_failure_p: 0.0,
            retry_budget: 3,
            injected_kill: None,
        }
    }

    /// True when every fault *source* is disabled (the retry budget is
    /// irrelevant without one).
    pub fn is_none(&self) -> bool {
        self.walltime_s == 0.0 // hydra-lint: allow(float-eq) — exact 0.0 is the disabled sentinel
            && self.mtbf_s == 0.0 // hydra-lint: allow(float-eq) — exact 0.0 sentinel
            && self.materialization_failure_p == 0.0 // hydra-lint: allow(float-eq) — sentinel
            && self.injected_kill.is_none()
    }

    /// Range-check every knob (surfaced through
    /// `ResourceRequest::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if !self.walltime_s.is_finite() || self.walltime_s < 0.0 {
            return Err(format!("walltime_s must be finite and >= 0, got {}", self.walltime_s));
        }
        if !self.mtbf_s.is_finite() || self.mtbf_s < 0.0 {
            return Err(format!("mtbf_s must be finite and >= 0, got {}", self.mtbf_s));
        }
        if !(0.0..=1.0).contains(&self.materialization_failure_p) {
            return Err(format!(
                "materialization_failure_p must be in [0, 1], got {}",
                self.materialization_failure_p
            ));
        }
        if let Some((_, off)) = self.injected_kill {
            if !off.is_finite() || off < 0.0 {
                return Err(format!(
                    "injected_kill offset must be finite and >= 0, got {off}"
                ));
            }
        }
        Ok(())
    }
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec::none()
    }
}

/// Salt for the dedicated fault stream: decorrelated from the schedule
/// stream for the same seed, stable across runs.
const FAULT_STREAM_SALT: u64 = 0xFA17_5EED_0D1E;

/// One re-queue wave: the tasks rolled back from a dead pilot and handed
/// to the FIFO head at `at_s`. The HPC Manager charges one resubmission
/// bulk per wave.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryWave {
    /// The pilot that died.
    pub pilot: u32,
    /// Virtual instant of the death / rollback.
    pub at_s: f64,
    /// Indices into the submitted task list, in submission order.
    pub tasks: Vec<usize>,
}

/// Per-pilot outcome of a [`MultiPilotSim`] run: the lifecycle timings
/// plus the utilization and fault accounting the HPC Manager reports
/// per pilot.
#[derive(Debug, Clone, PartialEq)]
pub struct PilotStat {
    pub queue_wait_s: f64,
    pub agent_ready_s: f64,
    pub total_cores: u32,
    /// Tasks this pilot ran to completion (rolled-back launches are
    /// subtracted again).
    pub tasks_executed: usize,
    pub peak_cores_busy: u32,
    /// Core-seconds of payload executed on this pilot (Σ cores × runtime,
    /// launch overhead excluded; rolled-back launches subtracted).
    pub busy_core_s: f64,
    /// `busy_core_s` over the pilot's live capacity
    /// (`total_cores × (lifetime end − agent_ready)`, where a dead
    /// pilot's lifetime ends at `died_at`); 0 for a pilot that never
    /// went live before the run ended.
    pub utilization: f64,
    /// Whether the pilot's agent ever came up (false: lost before
    /// agent-ready).
    pub materialized: bool,
    /// Virtual instant the pilot died, if it did.
    pub died_at: Option<f64>,
    /// Tasks rolled back from this pilot and re-queued at the FIFO head
    /// (abandonments not included).
    pub tasks_requeued: usize,
}

/// Result of simulating P concurrent pilots over one bulk workload.
///
/// `tasks` carries the same [`HpcTaskRecord`]s as [`HpcReport`] — in
/// submission order, byte-identical to the serial reference when
/// `P == 1` — with the pilot assignment alongside in `pilot_of`.
#[derive(Debug, Clone)]
pub struct MultiPilotReport {
    /// Makespan from submission to the last task completion (for an
    /// empty workload, or a faulty run that completed nothing: until the
    /// last processed event). A pilot whose queue wait elapses after the
    /// workload has drained does not extend the makespan.
    pub makespan_s: f64,
    /// Records of the *completed* tasks, in submission order — the full
    /// submission whenever no pilot-level fault fires.
    pub tasks: Vec<HpcTaskRecord>,
    /// Pilot that executed each task, index-aligned with `tasks`.
    pub pilot_of: Vec<u32>,
    /// Per-pilot lifecycle + utilization + fault stats, in pilot order.
    pub pilots: Vec<PilotStat>,
    pub events_processed: u64,
    /// Task ids reported abandoned: killed more than `retry_budget`
    /// times, or stranded when the whole fleet died. Disjoint from
    /// `tasks`; together they partition the submission exactly once.
    pub abandoned: Vec<u64>,
    /// One entry per dead-pilot rollback that re-queued at least one
    /// task, in death order.
    pub retry_waves: Vec<RetryWave>,
}

impl MultiPilotReport {
    /// Earliest agent-ready instant across pilots — the moment execution
    /// could first start (what the single-pilot `agent_ready_s` was; the
    /// workflow engine charges this one-off cost on the first wave only).
    pub fn first_agent_ready_s(&self) -> f64 {
        self.pilots.iter().map(|p| p.agent_ready_s).fold(f64::INFINITY, f64::min)
    }

    /// Total cores across all pilots.
    pub fn total_cores(&self) -> u32 {
        self.pilots.iter().map(|p| p.total_cores).sum()
    }
}

enum MpEv {
    /// A pilot's batch job started and its agent finished booting.
    PilotReady { pilot: usize },
    /// A pilot's serialized launcher finished spawning a task.
    LauncherFree { pilot: usize },
    /// A task completed on a pilot.
    TaskDone { pilot: usize, idx: usize },
    /// A pilot died (MTBF kill, walltime expiry, or materialization
    /// failure). Scheduled during staging, so on a time tie it pops
    /// before any task event of the same instant.
    PilotDead { pilot: usize },
}

/// Run-time state of one staged pilot.
struct PilotState {
    total_cores: u32,
    free_cores: u32,
    live: bool,
    launcher_free: bool,
    peak: u32,
    tasks_executed: usize,
    busy_core_s: f64,
    queue_wait_s: f64,
    agent_ready_s: f64,
    // Fault lifecycle (ISSUE 6).
    dead: bool,
    died_at: Option<f64>,
    was_live: bool,
    tasks_requeued: usize,
}

/// Per-task run state, bundled so the launch/rollback paths pass one
/// `&mut` instead of six.
struct TaskBook {
    records: Vec<Option<HpcTaskRecord>>,
    pilot_of: Vec<u32>,
    fail_flags: Vec<bool>,
    /// Pilot currently executing the task — the exactly-once guard: a
    /// `TaskDone` from any other pilot is stale (the launcher died and
    /// the task was re-queued) and is dropped.
    running_on: Vec<Option<usize>>,
    /// Core width the task was launched with. Deaths can narrow the
    /// fleet's widest live pilot, so the clamp is recorded at launch and
    /// used for the rollback / completion core-return — recomputing it
    /// later would break core conservation.
    launched_need: Vec<u32>,
    /// Times the task has been rolled back off a dead pilot.
    attempts: Vec<u32>,
    abandoned: Vec<bool>,
    /// Tasks resolved (completed or abandoned); the faulty-run early
    /// exit fires when this reaches the submission size.
    resolved: usize,
}

/// Simulate P concurrent pilots executing one bulk-submitted workload.
///
/// Pilots stage independently (per-pilot queue wait + agent boot drawn
/// from the same model as [`HpcSim`], in pilot order — so with one pilot
/// the PRNG stream is consumed identically). Tasks launch in FIFO order;
/// the head task goes to the best-fit live pilot found through the
/// shared capacity index, or waits (head-of-line) until one fits.
///
/// `run` consumes the staged workload; construct a fresh sim per run.
pub struct MultiPilotSim {
    profile: PlatformProfile,
    specs: Vec<PilotSpec>,
    tasks: Vec<HpcTaskSpec>,
    rng: Prng,
    seed: u64,
    failure_rate: f64,
    fault: FaultSpec,
    queue_kind: EventQueueKind,
    // Run state (populated by `run`, queryable afterwards).
    pilots: Vec<PilotState>,
    index: CapacityIndex,
    next: usize,
    /// Rolled-back tasks waiting at the FIFO head (consumed before the
    /// global cursor), in submission order.
    requeue: VecDeque<usize>,
    /// Widest live pilot — the oversized-task clamp target; deaths can
    /// narrow it mid-run.
    widest_live: u32,
}

impl MultiPilotSim {
    /// Heterogeneous pilots: one entry per pilot job to stage.
    pub fn new(profile: PlatformProfile, pilots: Vec<PilotSpec>, seed: u64) -> MultiPilotSim {
        assert!(!pilots.is_empty(), "at least one pilot required");
        MultiPilotSim {
            profile,
            specs: pilots,
            tasks: Vec::new(),
            // hydra-lint: allow(prng-salt) — the sim's primary stream; substreams fork from it
            rng: Prng::new(seed),
            seed,
            failure_rate: 0.0,
            fault: FaultSpec::none(),
            queue_kind: EventQueueKind::default(),
            pilots: Vec::new(),
            index: CapacityIndex::zeroed(0),
            next: 0,
            requeue: VecDeque::new(),
            widest_live: 0,
        }
    }

    /// `count` identical pilots (the common weak-scaling shape).
    pub fn uniform(
        profile: PlatformProfile,
        pilot: PilotSpec,
        count: u32,
        seed: u64,
    ) -> MultiPilotSim {
        assert!(count >= 1, "at least one pilot required");
        MultiPilotSim::new(profile, vec![pilot; count as usize], seed)
    }

    /// Enable failure injection with per-task probability `p`.
    pub fn with_failure_rate(mut self, p: f64) -> MultiPilotSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Enable the pilot-level fault model. [`FaultSpec::none`] is a true
    /// no-op: the dedicated fault stream is not even constructed, so the
    /// schedule stays byte-identical to the fault-free reference.
    pub fn with_faults(mut self, fault: FaultSpec) -> MultiPilotSim {
        self.fault = fault;
        self
    }

    /// Select the event-queue backing store (default: `Calendar`; see
    /// `sim::event` for the heap reference pattern).
    pub fn with_event_queue(mut self, kind: EventQueueKind) -> MultiPilotSim {
        self.queue_kind = kind;
        self
    }

    /// Bulk-submit task descriptions (one bulk for the whole pilot fleet;
    /// the connector shards the *transport*, not the schedule).
    pub fn submit(&mut self, tasks: Vec<HpcTaskSpec>) {
        self.tasks.extend(tasks);
    }

    /// Total free cores across pilots right now — after `run`, every
    /// reservation must have been returned (the core-conservation
    /// invariant surface for `tests/prop_invariants.rs`).
    pub fn free_capacity(&self) -> u32 {
        self.pilots.iter().map(|p| p.free_cores).sum()
    }

    /// Re-derive pilot `p`'s index leaf from its state: the leaf is the
    /// pilot's free cores *plus an eligibility bias of one* while the
    /// pilot is live with an idle launcher, and zero otherwise. The bias
    /// keeps zero-core demands from matching masked pilots (queries add
    /// one to the demand symmetrically), so a single O(log P) index query
    /// answers "live ∧ launcher idle ∧ fits". A dead pilot is never live
    /// again, so its leaf stays zero from `PilotDead` onward.
    fn sync_slot(&mut self, p: usize) {
        let st = &self.pilots[p];
        let leaf = if st.live && st.launcher_free {
            st.free_cores.saturating_add(1)
        } else {
            0
        };
        self.index.set(p, Cap::cores(leaf));
    }

    /// Launch FIFO-head tasks while a live, launcher-idle pilot fits the
    /// head; stop on the first head that fits nowhere (head-of-line, as
    /// in the serial reference) or when the workload is drained. The
    /// head is the oldest re-queued task if any (rolled back off a dead
    /// pilot), else the global cursor.
    fn try_launch(&mut self, q: &mut EventQueue<MpEv>, book: &mut TaskBook) {
        while !self.requeue.is_empty() || self.next < self.tasks.len() {
            let from_requeue = !self.requeue.is_empty();
            let idx = if from_requeue { *self.requeue.front().unwrap() } else { self.next };
            let t = self.tasks[idx];
            // Oversized tasks clamp to the widest *live* pilot (the
            // multi-pilot generalization of the serial path's clamp to
            // pilot width; deaths can narrow the fleet).
            let need = t.cores.min(self.widest_live);
            let Some(slot) = self.index.best_fit(Cap::cores(need.saturating_add(1))) else {
                return;
            };
            if from_requeue {
                self.requeue.pop_front();
            } else {
                self.next += 1;
            }
            let pilot = slot as usize;
            let launch_done = to_secs(q.now()) + self.profile.task_launch_s;
            let run_s = t.sleep_s + self.profile.payload_duration_s(t.work_s, need);
            {
                let st = &mut self.pilots[pilot];
                st.free_cores -= need;
                st.peak = st.peak.max(st.total_cores - st.free_cores);
                st.launcher_free = false;
                st.tasks_executed += 1;
                st.busy_core_s += f64::from(need) * run_s;
            }
            self.sync_slot(pilot); // masked while the launcher spawns
            book.records[idx] = Some(HpcTaskRecord {
                task_id: t.task_id,
                launched_s: launch_done,
                finished_s: launch_done + run_s, // finalized again at TaskDone
                failed: book.fail_flags[idx],
            });
            book.pilot_of[idx] = slot;
            book.running_on[idx] = Some(pilot);
            book.launched_need[idx] = need;
            q.schedule_in(secs(self.profile.task_launch_s), MpEv::LauncherFree { pilot });
            q.schedule_in(
                secs(self.profile.task_launch_s + run_s),
                MpEv::TaskDone { pilot, idx },
            );
        }
    }

    /// Stage the pilots, run the workload to quiescence, and report.
    pub fn run(&mut self) -> MultiPilotReport {
        let faults_on = !self.fault.is_none();
        // Dedicated fault stream: constructed only when a fault source is
        // enabled, so FaultSpec::none() consumes nothing anywhere.
        let mut frng =
            if faults_on { Some(Prng::new(self.seed ^ FAULT_STREAM_SALT)) } else { None };
        let mut q: EventQueue<MpEv> = EventQueue::with_kind(self.queue_kind);
        let mut staged = Vec::with_capacity(self.specs.len());
        let mut deaths: Vec<Option<f64>> = Vec::with_capacity(self.specs.len());
        let mut boots: Vec<bool> = Vec::with_capacity(self.specs.len());
        for (p, spec) in self.specs.iter().enumerate() {
            let total_cores = spec.cores(&self.profile);
            assert!(total_cores > 0, "pilot must request at least one node");
            // Pilot-order draws: with one pilot this consumes the PRNG
            // exactly like the serial reference.
            let queue_wait_s = if self.profile.queue_wait_mean_s > 0.0 {
                self.rng
                    .lognormal_mean_cv(self.profile.queue_wait_mean_s, self.profile.queue_wait_cv)
            } else {
                0.0
            };
            let agent_ready_s = queue_wait_s + self.profile.pilot_boot_s;
            // Fault draws in pilot order, one per enabled knob regardless
            // of the other knobs' outcomes, so no pilot's fate shifts
            // another pilot's draws.
            let (mat_fail, kill_after_s) = match frng.as_mut() {
                Some(r) => (
                    self.fault.materialization_failure_p > 0.0
                        && r.bool_with_p(self.fault.materialization_failure_p),
                    if self.fault.mtbf_s > 0.0 {
                        Some(r.exponential(self.fault.mtbf_s))
                    } else {
                        None
                    },
                ),
                None => (false, None),
            };
            let death = if mat_fail {
                Some(agent_ready_s)
            } else {
                let mut d = f64::INFINITY;
                if let Some(k) = kill_after_s {
                    d = d.min(agent_ready_s + k);
                }
                if self.fault.walltime_s > 0.0 {
                    // Walltime runs from job start; expiry before the
                    // agent boots kills the pilot pre-materialization.
                    d = d.min(queue_wait_s + self.fault.walltime_s);
                }
                if let Some((ip, off)) = self.fault.injected_kill {
                    if ip as usize == p {
                        d = d.min(agent_ready_s + off);
                    }
                }
                if d.is_finite() { Some(d) } else { None }
            };
            deaths.push(death);
            boots.push(!mat_fail);
            staged.push(PilotState {
                total_cores,
                free_cores: total_cores,
                live: false,
                launcher_free: false,
                peak: 0,
                tasks_executed: 0,
                busy_core_s: 0.0,
                queue_wait_s,
                agent_ready_s,
                dead: false,
                died_at: None,
                was_live: false,
                tasks_requeued: 0,
            });
        }
        self.pilots = staged;
        for (p, st) in self.pilots.iter().enumerate() {
            if boots[p] {
                q.schedule_at(secs(st.agent_ready_s), MpEv::PilotReady { pilot: p });
            }
        }
        // Deaths scheduled after the readies: on a PilotReady/PilotDead
        // time tie the pilot goes live first, then dies; and a PilotDead
        // always outranks same-instant task events (staging seq < task
        // seq).
        for (p, d) in deaths.iter().enumerate() {
            if let Some(d) = d {
                q.schedule_at(secs(*d), MpEv::PilotDead { pilot: p });
            }
        }
        self.widest_live = self.pilots.iter().map(|s| s.total_cores).max().unwrap_or(0);
        self.index = CapacityIndex::zeroed(self.pilots.len());
        self.next = 0;
        self.requeue.clear();

        let n = self.tasks.len();
        let fail_flags: Vec<bool> = (0..n)
            .map(|_| self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate))
            .collect();
        let mut book = TaskBook {
            records: vec![None; n],
            pilot_of: vec![0; n],
            fail_flags,
            running_on: vec![None; n],
            launched_need: vec![0; n],
            attempts: vec![0; n],
            abandoned: vec![false; n],
            resolved: 0,
        };
        let mut waves: Vec<RetryWave> = Vec::new();
        // Last task-completion instant. The makespan ends here, not at the
        // final queue event: a pilot whose queue wait elapses after the
        // workload has drained must not inflate TTX (with one pilot the
        // last event *is* the last TaskDone, so this stays bit-identical
        // to the serial reference).
        let mut last_done_s = 0.0f64;

        while let Some((_, ev)) = q.pop() {
            match ev {
                MpEv::PilotReady { pilot } => {
                    let st = &mut self.pilots[pilot];
                    if st.dead {
                        continue; // died before materializing (early walltime)
                    }
                    st.live = true;
                    st.launcher_free = true;
                    st.was_live = true;
                    self.sync_slot(pilot);
                    self.try_launch(&mut q, &mut book);
                }
                MpEv::LauncherFree { pilot } => {
                    if self.pilots[pilot].dead {
                        continue;
                    }
                    self.pilots[pilot].launcher_free = true;
                    self.sync_slot(pilot);
                    self.try_launch(&mut q, &mut book);
                }
                MpEv::TaskDone { pilot, idx } => {
                    if book.running_on[idx] != Some(pilot) {
                        // Stale completion: the launching pilot died and
                        // the task was rolled back (and possibly re-run
                        // elsewhere). Exactly-once: drop it.
                        continue;
                    }
                    book.running_on[idx] = None;
                    let need = book.launched_need[idx];
                    let st = &mut self.pilots[pilot];
                    st.free_cores += need;
                    debug_assert!(
                        st.free_cores <= st.total_cores,
                        "core conservation violated on pilot {pilot}"
                    );
                    self.sync_slot(pilot);
                    let rec = book.records[idx].as_mut().expect("done task was launched");
                    // Clamp against float rounding of the micros clock so
                    // finished >= launched holds exactly.
                    rec.finished_s = to_secs(q.now()).max(rec.launched_s);
                    // Events pop in time order, so the final assignment is
                    // the latest TaskDone (every launch's LauncherFree
                    // precedes its TaskDone, so this is the last task
                    // event overall).
                    last_done_s = to_secs(q.now());
                    book.resolved += 1;
                    self.try_launch(&mut q, &mut book);
                }
                MpEv::PilotDead { pilot } => {
                    if self.pilots[pilot].dead {
                        continue;
                    }
                    let now_s = to_secs(q.now());
                    {
                        let st = &mut self.pilots[pilot];
                        st.dead = true;
                        st.live = false;
                        st.launcher_free = false;
                        st.died_at = Some(now_s);
                    }
                    self.sync_slot(pilot); // zero the dead pilot's leaf
                    self.widest_live = self
                        .pilots
                        .iter()
                        .filter(|s| !s.dead)
                        .map(|s| s.total_cores)
                        .max()
                        .unwrap_or(0);
                    // Roll back every in-flight task of the dead pilot in
                    // submission order: return its cores, void its
                    // record, and either hand it to the FIFO head or
                    // abandon it when its retry budget is spent.
                    let mut wave: Vec<usize> = Vec::new();
                    for idx in 0..n {
                        if book.running_on[idx] != Some(pilot) {
                            continue;
                        }
                        book.running_on[idx] = None;
                        book.records[idx] = None;
                        let t = self.tasks[idx];
                        let need = book.launched_need[idx];
                        let run_s =
                            t.sleep_s + self.profile.payload_duration_s(t.work_s, need);
                        let st = &mut self.pilots[pilot];
                        st.free_cores += need;
                        st.busy_core_s -= f64::from(need) * run_s;
                        st.tasks_executed -= 1;
                        book.attempts[idx] += 1;
                        if book.attempts[idx] > self.fault.retry_budget {
                            book.abandoned[idx] = true;
                            book.resolved += 1;
                        } else {
                            st.tasks_requeued += 1;
                            wave.push(idx);
                        }
                    }
                    debug_assert_eq!(
                        self.pilots[pilot].free_cores,
                        self.pilots[pilot].total_cores,
                        "dead pilot {pilot} must return every core"
                    );
                    for &idx in wave.iter().rev() {
                        self.requeue.push_front(idx);
                    }
                    if !wave.is_empty() {
                        waves.push(RetryWave { pilot: pilot as u32, at_s: now_s, tasks: wave });
                    }
                    self.try_launch(&mut q, &mut book);
                }
            }
            if faults_on && n > 0 && book.resolved == n {
                // Faulty runs can leave stale events behind (dead pilots'
                // TaskDones, later deaths); once every task is resolved
                // the schedule is final. Fault-free runs drain the queue
                // exactly as the PR 5 reference did.
                break;
            }
        }

        // A fleet that died entirely leaves unplaced tasks behind: report
        // them abandoned (partial run) rather than silently dropping them.
        if faults_on {
            for idx in 0..n {
                if book.records[idx].is_none() && !book.abandoned[idx] {
                    book.abandoned[idx] = true;
                }
            }
        }
        let mut tasks_out: Vec<HpcTaskRecord> = Vec::with_capacity(n);
        let mut pilot_out: Vec<u32> = Vec::with_capacity(n);
        let mut abandoned_ids: Vec<u64> = Vec::new();
        for (idx, rec) in book.records.into_iter().enumerate() {
            match rec {
                Some(r) => {
                    tasks_out.push(r);
                    pilot_out.push(book.pilot_of[idx]);
                }
                None => abandoned_ids.push(self.tasks[idx].task_id),
            }
        }
        debug_assert!(
            faults_on || abandoned_ids.is_empty(),
            "every submitted task must complete on a healthy fleet"
        );

        // Empty workload: the run "ends" when the last pilot is staged,
        // exactly as the serial reference reports for zero tasks. A
        // faulty run that completed nothing ends at its last processed
        // event (the final pilot death).
        let makespan_s = if self.tasks.is_empty() || tasks_out.is_empty() {
            to_secs(q.now())
        } else {
            last_done_s
        };
        let pilots = self
            .pilots
            .iter()
            .map(|st| {
                // A dead pilot's capacity window closes at its death.
                let window_end = st.died_at.map_or(makespan_s, |d| d.min(makespan_s));
                let window = (window_end - st.agent_ready_s).max(0.0);
                let capacity = f64::from(st.total_cores) * window;
                PilotStat {
                    queue_wait_s: st.queue_wait_s,
                    agent_ready_s: st.agent_ready_s,
                    total_cores: st.total_cores,
                    tasks_executed: st.tasks_executed,
                    peak_cores_busy: st.peak,
                    busy_core_s: st.busy_core_s,
                    utilization: if capacity > 0.0 {
                        (st.busy_core_s / capacity).clamp(0.0, 1.0)
                    } else {
                        0.0
                    },
                    materialized: st.was_live,
                    died_at: st.died_at,
                    tasks_requeued: st.tasks_requeued,
                }
            })
            .collect();
        MultiPilotReport {
            makespan_s,
            tasks: tasks_out,
            pilot_of: pilot_out,
            pilots,
            events_processed: q.processed(),
            abandoned: abandoned_ids,
            retry_waves: waves,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::{PlatformProfile, ProviderId};

    fn b2() -> PlatformProfile {
        PlatformProfile::of(ProviderId::Bridges2)
    }

    fn run_tasks(tasks: Vec<HpcTaskSpec>, nodes: u32, seed: u64) -> HpcReport {
        let mut sim = HpcSim::new(b2(), PilotSpec { nodes }, seed);
        sim.submit(tasks);
        sim.run()
    }

    #[test]
    fn calendar_queue_matches_heap_queue_serial_and_multipilot() {
        // ISSUE 8: both event-queue backends must produce bit-identical
        // pilot schedules (same pop order => same PRNG consumption).
        let tasks: Vec<_> = (0..800).map(HpcTaskSpec::noop).collect();
        let serial = |k: EventQueueKind| {
            let mut sim = HpcSim::new(b2(), PilotSpec { nodes: 1 }, 9).with_event_queue(k);
            sim.submit(tasks.clone());
            sim.run()
        };
        let (a, b) = (serial(EventQueueKind::Calendar), serial(EventQueueKind::Heap));
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.events_processed, b.events_processed);

        let multi = |k: EventQueueKind| {
            let mut sim =
                MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 4, 9).with_event_queue(k);
            sim.submit(tasks.clone());
            sim.run()
        };
        let (a, b) = (multi(EventQueueKind::Calendar), multi(EventQueueKind::Heap));
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.pilot_of, b.pilot_of);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    }

    #[test]
    fn all_tasks_complete() {
        let tasks: Vec<_> = (0..500).map(HpcTaskSpec::noop).collect();
        let r = run_tasks(tasks, 1, 1);
        assert_eq!(r.tasks.len(), 500);
        for t in &r.tasks {
            assert!(t.finished_s >= t.launched_s);
            assert!(t.launched_s >= r.agent_ready_s);
        }
    }

    #[test]
    fn queue_wait_short_and_consistent() {
        // Paper §5.3: short, consistent queue times. CV 0.15 around 45 s.
        let waits: Vec<f64> = (0..50)
            .map(|s| run_tasks(vec![HpcTaskSpec::noop(0)], 1, s).queue_wait_s)
            .collect();
        let sum: f64 = waits.iter().sum();
        let mean = sum / waits.len() as f64;
        assert!((mean - 45.0).abs() < 10.0, "mean queue wait {mean}");
        assert!(waits.iter().all(|w| *w > 10.0 && *w < 150.0));
    }

    #[test]
    fn cores_capacity_respected() {
        let tasks: Vec<_> = (0..300)
            .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 1.0, sleep_s: 0.0 })
            .collect();
        let r = run_tasks(tasks, 1, 3);
        assert!(r.peak_cores_busy <= 128);
        assert_eq!(r.tasks.len(), 300);
    }

    #[test]
    fn more_nodes_is_faster() {
        let mk = |nodes| {
            // Long enough tasks that cores, not the serialized launcher,
            // are the bottleneck.
            let tasks: Vec<_> = (0..512)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect();
            run_tasks(tasks, nodes, 7).makespan_s
        };
        let one = mk(1);
        let two = mk(2);
        assert!(two < one, "{two} !< {one}");
    }

    #[test]
    fn oversized_task_clamps_to_pilot_width() {
        // A 256-core task on a 128-core pilot runs clamped instead of
        // deadlocking the FIFO head.
        let spec = HpcTaskSpec { task_id: 0, cores: 256, work_s: 10.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 9);
        assert_eq!(r.tasks.len(), 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let t: Vec<_> = (0..100).map(HpcTaskSpec::noop).collect();
        let a = run_tasks(t.clone(), 2, 42);
        let b = run_tasks(t, 2, 42);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.queue_wait_s, b.queue_wait_s);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn bare_metal_speed_beats_cloud_reference() {
        // 110 s of AWS-reference work on one core should take ~10 s on
        // Bridges2 (cpu_speed 11).
        let spec = HpcTaskSpec { task_id: 0, cores: 1, work_s: 110.0, sleep_s: 0.0 };
        let r = run_tasks(vec![spec], 1, 5);
        let t = &r.tasks[0];
        assert!(((t.finished_s - t.launched_s) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn event_count_scales_linearly_with_tasks() {
        // O(1) per event, O(T) events per run: AgentReady + per task one
        // LauncherFree + one TaskDone.
        for n in [100u64, 400] {
            let tasks: Vec<_> = (0..n).map(HpcTaskSpec::noop).collect();
            let r = run_tasks(tasks, 1, 11);
            assert_eq!(r.events_processed, 1 + 2 * n);
        }
    }

    // ---- multi-pilot (ISSUE 5 tentpole) ----------------------------------

    fn run_multi(
        tasks: Vec<HpcTaskSpec>,
        nodes: u32,
        pilots: u32,
        seed: u64,
    ) -> MultiPilotReport {
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes }, pilots, seed);
        sim.submit(tasks);
        sim.run()
    }

    #[test]
    fn single_pilot_reproduces_serial_reference() {
        // The full-matrix equivalence lives in tests/pilot_equivalence.rs;
        // this is the fast inline guard.
        let tasks: Vec<_> = (0..200)
            .map(|i| HpcTaskSpec {
                task_id: i,
                cores: 1 + (i as u32 % 5),
                work_s: 3.0,
                sleep_s: 0.0,
            })
            .collect();
        let serial = run_tasks(tasks.clone(), 2, 42);
        let multi = run_multi(tasks, 2, 1, 42);
        assert_eq!(serial.tasks, multi.tasks);
        assert_eq!(serial.events_processed, multi.events_processed);
        assert_eq!(serial.makespan_s, multi.makespan_s);
        assert_eq!(serial.queue_wait_s, multi.pilots[0].queue_wait_s);
        assert_eq!(serial.agent_ready_s, multi.pilots[0].agent_ready_s);
        assert_eq!(serial.peak_cores_busy, multi.pilots[0].peak_cores_busy);
        assert!(multi.pilot_of.iter().all(|&p| p == 0));
    }

    #[test]
    fn more_pilots_is_faster_weak_scaling() {
        // Core-bound workload: 4 concurrent pilots quadruple the fleet's
        // cores and must beat one pilot despite four queue waits.
        let mk = |pilots: u32| {
            let tasks: Vec<_> = (0..512)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect();
            run_multi(tasks, 1, pilots, 7).makespan_s
        };
        let one = mk(1);
        let four = mk(4);
        assert!(four < one, "{four} !< {one}");
    }

    #[test]
    fn every_pilot_contributes_under_load() {
        // 800 long tasks: far more than the fleet can drain before the
        // last pilot's queue wait elapses, so every pilot must launch.
        let tasks: Vec<_> = (0..800)
            .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 2000.0, sleep_s: 0.0 })
            .collect();
        let r = run_multi(tasks, 1, 4, 13);
        assert_eq!(r.tasks.len(), 800);
        assert_eq!(r.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(), 800);
        for (i, p) in r.pilots.iter().enumerate() {
            assert!(p.tasks_executed > 0, "pilot {i} idle");
            assert!(p.peak_cores_busy <= p.total_cores);
            assert!((0.0..=1.0).contains(&p.utilization), "pilot {i}: {}", p.utilization);
        }
        // pilot_of is consistent with the per-pilot counts.
        for (i, p) in r.pilots.iter().enumerate() {
            let n = r.pilot_of.iter().filter(|&&x| x == i as u32).count();
            assert_eq!(n, p.tasks_executed, "pilot {i}");
        }
    }

    #[test]
    fn oversized_task_clamps_to_widest_pilot_and_lands_there() {
        // Pilots of 1 and 2 nodes: a 300-core task clamps to 256 (the
        // widest pilot) and can only run there.
        let mut sim = MultiPilotSim::new(
            b2(),
            vec![PilotSpec { nodes: 1 }, PilotSpec { nodes: 2 }],
            9,
        );
        sim.submit(vec![HpcTaskSpec { task_id: 0, cores: 300, work_s: 10.0, sleep_s: 0.0 }]);
        let r = sim.run();
        assert_eq!(r.tasks.len(), 1);
        assert_eq!(r.pilot_of[0], 1, "must land on the 256-core pilot");
        assert_eq!(r.pilots[1].peak_cores_busy, 256, "clamped to the widest width");
        assert_eq!(sim.free_capacity(), 128 + 256, "all cores returned");
    }

    #[test]
    fn multi_pilot_deterministic_per_seed() {
        let t: Vec<_> = (0..300).map(HpcTaskSpec::noop).collect();
        let a = run_multi(t.clone(), 1, 8, 21);
        let b = run_multi(t, 1, 8, 21);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.pilot_of, b.pilot_of);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.events_processed, b.events_processed);
    }

    // ---- pilot-fleet fault tolerance (ISSUE 6 tentpole) ------------------

    #[test]
    fn fault_spec_none_is_inert_inline() {
        // The full 3-seed matrix lives in tests/pilot_equivalence.rs;
        // this is the fast inline guard that the machinery is a no-op.
        let tasks: Vec<_> = (0..200)
            .map(|i| HpcTaskSpec {
                task_id: i,
                cores: 1 + (i as u32 % 9),
                work_s: 5.0,
                sleep_s: 0.0,
            })
            .collect();
        let a = run_multi(tasks.clone(), 1, 4, 99);
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 4, 99)
            .with_faults(FaultSpec::none());
        sim.submit(tasks);
        let b = sim.run();
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.pilot_of, b.pilot_of);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.events_processed, b.events_processed);
        assert!(b.abandoned.is_empty());
        assert!(b.retry_waves.is_empty());
        assert!(b.pilots.iter().all(|p| p.died_at.is_none() && p.materialized));
    }

    #[test]
    fn injected_kill_requeues_exactly_once_on_the_survivor() {
        // 2 pilots, long tasks, pilot 0 killed mid-run: every task must
        // complete exactly once, the kill's rollback must restore the
        // dead pilot's cores, and nothing is abandoned (budget 3 covers
        // the single kill).
        let n = 300u64;
        let fault = FaultSpec { injected_kill: Some((0, 50.0)), ..FaultSpec::none() };
        let mut sim =
            MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 2, 33).with_faults(fault);
        sim.submit(
            (0..n)
                .map(|i| HpcTaskSpec { task_id: i, cores: 4, work_s: 2000.0, sleep_s: 0.0 })
                .collect(),
        );
        let r = sim.run();
        assert!(r.abandoned.is_empty(), "survivor must absorb every retry");
        let mut ids: Vec<u64> = r.tasks.iter().map(|t| t.task_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "exactly-once completion set");
        assert!(r.pilots[0].died_at.is_some(), "pilot 0 must die");
        assert!(r.pilots[0].tasks_requeued > 0, "mid-run kill must roll back tasks");
        assert!(r.pilots[1].died_at.is_none());
        assert_eq!(r.retry_waves.len(), 1);
        assert_eq!(r.retry_waves[0].pilot, 0);
        assert_eq!(r.retry_waves[0].tasks.len(), r.pilots[0].tasks_requeued);
        // pilot_of counts stay consistent with per-pilot tallies.
        for (i, p) in r.pilots.iter().enumerate() {
            let cnt = r.pilot_of.iter().filter(|&&x| x == i as u32).count();
            assert_eq!(cnt, p.tasks_executed, "pilot {i}");
        }
        assert_eq!(sim.free_capacity(), 256, "all cores returned, dead pilot included");
    }

    #[test]
    fn walltime_expiry_reports_partial_run_without_hanging() {
        // Walltime far shorter than any task: both pilots expire with
        // work in flight and budget 0 abandons everything — completed
        // and abandoned must still partition the submission exactly.
        let n = 100u64;
        let fault = FaultSpec { walltime_s: 40.0, retry_budget: 0, ..FaultSpec::none() };
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 2, 5).with_faults(fault);
        sim.submit(
            (0..n)
                .map(|i| HpcTaskSpec { task_id: i, cores: 1, work_s: 2000.0, sleep_s: 0.0 })
                .collect(),
        );
        let r = sim.run();
        assert!(r.tasks.is_empty(), "40 s walltime cannot finish 180 s tasks");
        let mut ab = r.abandoned.clone();
        ab.sort_unstable();
        ab.dedup();
        assert_eq!(ab.len() as u64, n, "no duplicates, nothing dropped");
        assert!(r.pilots.iter().all(|p| p.died_at.is_some()));
        assert_eq!(sim.free_capacity(), 256);
        assert!(r.makespan_s > 0.0, "partial run still reports when it ended");
    }

    #[test]
    fn certain_materialization_failure_abandons_everything() {
        let fault = FaultSpec { materialization_failure_p: 1.0, ..FaultSpec::none() };
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 3, 8).with_faults(fault);
        sim.submit((0..50).map(HpcTaskSpec::noop).collect());
        let r = sim.run();
        assert!(r.tasks.is_empty());
        assert_eq!(r.abandoned.len(), 50);
        assert!(r.pilots.iter().all(|p| p.died_at.is_some() && !p.materialized));
        assert_eq!(r.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(), 0);
        assert!(r.retry_waves.is_empty(), "nothing launched, nothing to re-queue");
    }

    #[test]
    fn retry_budget_zero_abandons_on_first_kill() {
        // Single pilot, killed 5 s after agent-ready with ~5.7 s tasks in
        // flight: nothing completes, budget 0 abandons the in-flight
        // tasks and the fleet death strands the rest.
        let fault =
            FaultSpec { injected_kill: Some((0, 5.0)), retry_budget: 0, ..FaultSpec::none() };
        let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 1, 4).with_faults(fault);
        sim.submit(
            (0..10)
                .map(|i| HpcTaskSpec { task_id: i, cores: 32, work_s: 2000.0, sleep_s: 0.0 })
                .collect(),
        );
        let r = sim.run();
        assert!(r.tasks.is_empty());
        assert_eq!(r.abandoned.len(), 10);
        assert!(r.retry_waves.is_empty(), "budget 0 never re-queues");
        assert_eq!(r.pilots[0].tasks_requeued, 0);
        assert_eq!(sim.free_capacity(), 128);
    }

    #[test]
    fn mtbf_kills_resolve_every_task_exactly_once() {
        // Stochastic kills with a generous budget: however the deaths
        // land, completed + abandoned must partition the submission.
        let n = 400u64;
        let fault = FaultSpec { mtbf_s: 200.0, retry_budget: 5, ..FaultSpec::none() };
        let mut sim =
            MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 4, 21).with_faults(fault);
        sim.submit(
            (0..n)
                .map(|i| HpcTaskSpec { task_id: i, cores: 2, work_s: 500.0, sleep_s: 0.0 })
                .collect(),
        );
        let r = sim.run();
        let mut ids: Vec<u64> = r.tasks.iter().map(|t| t.task_id).collect();
        ids.extend(&r.abandoned);
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "exactly-once partition");
        assert_eq!(sim.free_capacity(), 512, "core conservation under kills");
        let requeued: usize = r.pilots.iter().map(|p| p.tasks_requeued).sum();
        let waved: usize = r.retry_waves.iter().map(|w| w.tasks.len()).sum();
        assert_eq!(requeued, waved, "wave log matches per-pilot tallies");
    }
}
