//! Discrete-event simulation core: virtual clock + ordered event queue.
//!
//! Every platform substrate (Kubernetes clusters, HPC batch queues, FaaS
//! services) runs on this engine. Virtual time is decoupled from wall
//! time on purpose: the paper's platform-side metrics (TPT, TTX) are
//! *simulated* here, while Hydra's broker-side metric (OVH) is measured in
//! real wall-clock time — see DESIGN.md §1 for the substitution argument.
//!
//! # Two queue kinds (ISSUE 8 tentpole)
//!
//! [`EventQueue`] orders pending events by `(time, insertion seq)` and can
//! be backed by either of two stores, selected with [`EventQueueKind`]:
//!
//! * [`EventQueueKind::Calendar`] (the default) — a calendar/bucket queue:
//!   events hash into "day" buckets of a fixed time `width`, each bucket
//!   kept sorted by `(time, seq)`, and a cursor walks the days in order.
//!   Schedule and pop are **O(1) amortized**: the bucket count doubles
//!   (and the day width is re-derived from the *observed* event horizon,
//!   `span / live events`) whenever occupancy exceeds two events per
//!   bucket, and shrinks when the queue drains, so buckets stay near-empty
//!   and a pop touches a constant number of days in expectation. Sparse
//!   schedules that would make the cursor crawl fall back to a direct
//!   minimum scan after one bucket lap, which also re-anchors the cursor.
//! * [`EventQueueKind::Heap`] — the original `BinaryHeap`, **O(log n)**
//!   per operation. Kept as the *reference implementation*: the calendar
//!   queue must reproduce its pop order byte for byte.
//!
//! This is the same landed pattern as `SchedulerKind::LinearScan` (the
//! linear-scan placement reference for the segment-tree index) and the
//! serial `HpcSim` (the pilots=1 reference for `MultiPilotSim`): the slow,
//! obviously-correct implementation stays in-tree and equivalence suites
//! (`tests/queue_equivalence.rs`, run by name in CI tier-1) lock the fast
//! path to it — same `(time, seq)` pop order, same tie-breaking, same
//! past-clamping, same `now`/`processed` bookkeeping. Both backends share
//! this wrapper's clock, sequence counter, and clamping, so the contract
//! can only diverge in *ordering*, which is exactly what the suite pins.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Virtual time in microseconds since simulation start.
pub type SimTime = u64;

pub const MICROS: u64 = 1;
pub const MILLIS: u64 = 1_000;
pub const SECONDS: u64 = 1_000_000;

/// Convert seconds (f64) to SimTime, saturating at zero.
pub fn secs(s: f64) -> SimTime {
    if s <= 0.0 {
        0
    } else {
        (s * SECONDS as f64).round() as SimTime
    }
}

/// Convert SimTime to seconds.
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / SECONDS as f64
}

/// Which backing store orders the pending events. Both kinds implement
/// the identical `(time, seq)` contract; they differ only in cost. See
/// the module docs for the reference-implementation pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EventQueueKind {
    /// Calendar/bucket queue: O(1) amortized schedule/pop (default).
    #[default]
    Calendar,
    /// Binary heap: O(log n) per operation; the byte-identical reference.
    Heap,
}

struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> Scheduled<E> {
    /// The total order both backends agree on: earliest time first, ties
    /// by insertion order.
    fn key(&self) -> (SimTime, u64) {
        (self.at, self.seq)
    }
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        // Ties break by insertion order (seq) for determinism.
        other.key().cmp(&self.key())
    }
}

/// Initial bucket count (power of two) and day width for a calendar that
/// has not yet observed enough events to size itself.
const MIN_BUCKETS: usize = 16;
const INITIAL_WIDTH: SimTime = MILLIS;

/// The calendar store. Bucket `(-at / width) mod nbuckets` holds every
/// event of day `at / width`, sorted ascending by `(at, seq)`, so a
/// bucket's front is its minimum. Events of later "years" (days that
/// alias the same bucket) sit further back in the same bucket and are
/// skipped by the day check in `pop`.
struct Calendar<E> {
    buckets: Vec<VecDeque<Scheduled<E>>>,
    /// `buckets.len() - 1`; the length is a power of two.
    mask: u64,
    /// Virtual time span of one day bucket (>= 1 µs).
    width: SimTime,
    /// Day the pop cursor is in. Never behind `now / width`.
    cur_day: u64,
    len: usize,
}

impl<E> Calendar<E> {
    fn new() -> Calendar<E> {
        Calendar {
            buckets: (0..MIN_BUCKETS).map(|_| VecDeque::new()).collect(),
            mask: MIN_BUCKETS as u64 - 1,
            width: INITIAL_WIDTH,
            cur_day: 0,
            len: 0,
        }
    }

    fn bucket_of(&self, at: SimTime) -> usize {
        ((at / self.width) & self.mask) as usize
    }

    /// Insert keeping the bucket sorted by `(at, seq)`. Indexed binary
    /// search (VecDeque indexing is O(1)); the memmove cost of the insert
    /// is bounded by the bucket size, which resizing keeps ~O(1).
    fn schedule(&mut self, s: Scheduled<E>, now: SimTime) {
        if self.len + 1 > self.buckets.len() * 2 {
            self.rebuild(self.len + 1, now);
        }
        let b = self.bucket_of(s.at);
        let bucket = &mut self.buckets[b];
        let (mut lo, mut hi) = (0usize, bucket.len());
        while lo < hi {
            let mid = (lo + hi) / 2;
            if bucket[mid].key() < s.key() {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        bucket.insert(lo, s);
        self.len += 1;
    }

    /// Pop the globally-earliest event. Walks day windows from the
    /// cursor; every event is >= `now` (the wrapper clamps), so the first
    /// day with a front inside its window holds the minimum, and the
    /// sorted bucket's front is it. After one full lap without a hit the
    /// schedule is sparse relative to the day width: fall back to a
    /// direct scan of the bucket minima and re-anchor the cursor there.
    fn pop(&mut self, now: SimTime) -> Option<Scheduled<E>> {
        if self.len == 0 {
            return None;
        }
        if self.buckets.len() > MIN_BUCKETS && self.len * 8 < self.buckets.len() {
            self.rebuild(self.len, now);
        }
        for _ in 0..self.buckets.len() {
            let b = (self.cur_day & self.mask) as usize;
            if let Some(front) = self.buckets[b].front() {
                if front.at / self.width == self.cur_day {
                    self.len -= 1;
                    return self.buckets[b].pop_front();
                }
            }
            self.cur_day += 1;
        }
        let mut best: Option<(SimTime, u64, usize)> = None;
        for (i, bucket) in self.buckets.iter().enumerate() {
            if let Some(front) = bucket.front() {
                let better = match best {
                    None => true,
                    Some((at, seq, _)) => front.key() < (at, seq),
                };
                if better {
                    best = Some((front.at, front.seq, i));
                }
            }
        }
        let (at, _, i) = best.expect("len > 0 implies a nonempty bucket");
        self.cur_day = at / self.width;
        self.len -= 1;
        self.buckets[i].pop_front()
    }

    /// Earliest pending time without popping. O(buckets); only used by
    /// the wrapper's `next_time` peek, never on the hot event loop.
    fn peek_min(&self) -> Option<SimTime> {
        self.buckets
            .iter()
            .filter_map(|b| b.front())
            .map(Scheduled::key)
            .min()
            .map(|(at, _)| at)
    }

    /// Re-size to ~2 buckets per live event and re-derive the day width
    /// from the observed horizon (remaining span / live events ≈ the mean
    /// inter-event gap), then redistribute. O(len + buckets); amortized
    /// O(1) per operation via the doubling/halving triggers.
    fn rebuild(&mut self, target_len: usize, now: SimTime) {
        let mut slots: Vec<Scheduled<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            slots.extend(b.drain(..));
        }
        let (mut lo, mut hi) = (SimTime::MAX, 0);
        for s in &slots {
            lo = lo.min(s.at);
            hi = hi.max(s.at);
        }
        if slots.is_empty() {
            lo = now;
            hi = now;
        }
        let n = (target_len.max(1) * 2).next_power_of_two().max(MIN_BUCKETS);
        self.width = ((hi - lo) / target_len.max(1) as u64).max(1);
        self.mask = n as u64 - 1;
        self.buckets = (0..n).map(|_| VecDeque::new()).collect();
        self.cur_day = now / self.width;
        for s in slots {
            let b = self.bucket_of(s.at);
            self.buckets[b].push_back(s);
        }
        for b in &mut self.buckets {
            b.make_contiguous().sort_unstable_by_key(Scheduled::key);
        }
    }
}

enum Backend<E> {
    Heap(BinaryHeap<Scheduled<E>>),
    Calendar(Calendar<E>),
}

/// An event queue with a virtual clock.
///
/// The owning simulator defines the event payload `E` and drives the loop:
/// `while let Some((t, e)) = q.pop() { ... q.schedule_at(...) ... }`.
/// The backing store defaults to the calendar queue; construct with
/// [`EventQueue::with_kind`] to pin the heap reference (see module docs).
pub struct EventQueue<E> {
    backend: Backend<E>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue::with_kind(EventQueueKind::default())
    }

    /// Construct with an explicit backing store.
    pub fn with_kind(kind: EventQueueKind) -> EventQueue<E> {
        let backend = match kind {
            EventQueueKind::Heap => Backend::Heap(BinaryHeap::new()),
            EventQueueKind::Calendar => Backend::Calendar(Calendar::new()),
        };
        EventQueue { backend, now: 0, seq: 0, processed: 0 }
    }

    /// Which backing store this queue runs on.
    pub fn kind(&self) -> EventQueueKind {
        match self.backend {
            Backend::Heap(_) => EventQueueKind::Heap,
            Backend::Calendar(_) => EventQueueKind::Calendar,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events dispatched so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len,
        }
    }

    /// Schedule `event` at absolute virtual time `at`. Scheduling in the
    /// past is clamped to `now` (the event fires "immediately"). The
    /// clamp lives here, shared by both backends, so no backend ever
    /// holds an event earlier than `now` — the invariant the calendar's
    /// cursor walk relies on.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let s = Scheduled { at, seq: self.seq, event };
        self.seq += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(s),
            Backend::Calendar(c) => c.schedule(s, self.now),
        }
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = match &mut self.backend {
            Backend::Heap(h) => h.pop()?,
            Backend::Calendar(c) => {
                let now = self.now;
                c.pop(now)?
            }
        };
        debug_assert!(s.at >= self.now, "virtual time went backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.event))
    }

    /// Peek at the next event time without advancing.
    pub fn next_time(&self) -> Option<SimTime> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|s| s.at),
            Backend::Calendar(c) => c.peek_min(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [EventQueueKind; 2] = [EventQueueKind::Calendar, EventQueueKind::Heap];

    #[test]
    fn default_kind_is_calendar() {
        let q: EventQueue<()> = EventQueue::new();
        assert_eq!(q.kind(), EventQueueKind::Calendar);
        assert_eq!(EventQueueKind::default(), EventQueueKind::Calendar);
        let h: EventQueue<()> = EventQueue::with_kind(EventQueueKind::Heap);
        assert_eq!(h.kind(), EventQueueKind::Heap);
    }

    #[test]
    fn pops_in_time_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(30, "c");
            q.schedule_at(10, "a");
            q.schedule_at(20, "b");
            let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
            assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(5, 1);
            q.schedule_at(5, 2);
            q.schedule_at(5, 3);
            let evs: Vec<i32> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(evs, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(100, ());
            q.schedule_at(50, ());
            assert_eq!(q.now(), 0);
            q.pop();
            assert_eq!(q.now(), 50, "{kind:?}");
            q.pop();
            assert_eq!(q.now(), 100, "{kind:?}");
        }
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(100, "later");
            q.pop();
            q.schedule_at(10, "past"); // clamped to 100
            let (t, e) = q.pop().unwrap();
            assert_eq!((t, e), (100, "past"), "{kind:?}");
        }
    }

    #[test]
    fn schedule_in_is_relative() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(40, ());
            q.pop();
            q.schedule_in(5, ());
            assert_eq!(q.next_time(), Some(45), "{kind:?}");
        }
    }

    #[test]
    fn unit_conversions_roundtrip() {
        assert_eq!(secs(1.5), 1_500_000);
        assert_eq!(secs(-3.0), 0);
        assert!((to_secs(secs(12.25)) - 12.25).abs() < 1e-9);
        assert_eq!(MILLIS * 1000, SECONDS);
        assert_eq!(MICROS * 1000, MILLIS);
    }

    #[test]
    fn processed_counts_dispatches() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..10u64 {
                q.schedule_at(i, i);
            }
            while q.pop().is_some() {}
            assert_eq!(q.processed(), 10, "{kind:?}");
            assert!(q.is_empty(), "{kind:?}");
        }
    }

    #[test]
    fn calendar_survives_growth_and_drain() {
        // Push enough to force several rebuilds, then drain through the
        // shrink path; order must stay exactly (time, seq).
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar);
        let n = 10_000u64;
        for i in 0..n {
            // Deliberately adversarial spread: clustered lows + far highs.
            let at = if i % 3 == 0 { i } else { i * 1_000_003 };
            q.schedule_at(at, i);
        }
        assert_eq!(q.len(), n as usize);
        let mut last = (0u64, 0u64);
        let mut seen = 0;
        let mut expected: Vec<(SimTime, u64)> = (0..n)
            .map(|i| (if i % 3 == 0 { i } else { i * 1_000_003 }, i))
            .collect();
        expected.sort_unstable();
        while let Some((t, i)) = q.pop() {
            assert!((t, i) >= last, "order violated at {t}/{i}");
            assert_eq!((t, i), expected[seen], "diverged from sorted reference");
            last = (t, i);
            seen += 1;
        }
        assert_eq!(seen, n as usize);
        assert!(q.is_empty());
    }

    #[test]
    fn calendar_sparse_schedule_uses_direct_search() {
        // Events much further apart than a year of initial-width days:
        // the cursor lap fails and the direct-search fallback must find
        // each next event without walking the gap day by day.
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar);
        for i in (0..64u64).rev() {
            q.schedule_at(i * 10 * SECONDS * MIN_BUCKETS as u64, i);
        }
        for i in 0..64u64 {
            let (t, e) = q.pop().unwrap();
            assert_eq!(e, i);
            assert_eq!(t, i * 10 * SECONDS * MIN_BUCKETS as u64);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn same_timestamp_mass_preserves_fifo() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..5_000u64 {
                q.schedule_at(7, i);
            }
            for i in 0..5_000u64 {
                assert_eq!(q.pop(), Some((7, i)), "{kind:?}");
            }
        }
    }
}
