//! Discrete-event simulation core: virtual clock + ordered event queue.
//!
//! Every platform substrate (Kubernetes clusters, HPC batch queues, VM
//! provisioning) runs on this engine. Virtual time is decoupled from wall
//! time on purpose: the paper's platform-side metrics (TPT, TTX) are
//! *simulated* here, while Hydra's broker-side metric (OVH) is measured in
//! real wall-clock time — see DESIGN.md §1 for the substitution argument.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in microseconds since simulation start.
pub type SimTime = u64;

pub const MICROS: u64 = 1;
pub const MILLIS: u64 = 1_000;
pub const SECONDS: u64 = 1_000_000;

/// Convert seconds (f64) to SimTime, saturating at zero.
pub fn secs(s: f64) -> SimTime {
    if s <= 0.0 {
        0
    } else {
        (s * SECONDS as f64).round() as SimTime
    }
}

/// Convert SimTime to seconds.
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / SECONDS as f64
}

struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        // Ties break by insertion order (seq) for determinism.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// An event queue with a virtual clock.
///
/// The owning simulator defines the event payload `E` and drives the loop:
/// `while let Some((t, e)) = q.pop() { ... q.schedule_at(...) ... }`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events dispatched so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute virtual time `at`. Scheduling in the
    /// past is clamped to `now` (the event fires "immediately").
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        self.heap.push(Scheduled { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "virtual time went backwards");
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.event))
    }

    /// Peek at the next event time without advancing.
    pub fn next_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5, 1);
        q.schedule_at(5, 2);
        q.schedule_at(5, 3);
        let evs: Vec<i32> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(evs, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(100, ());
        q.schedule_at(50, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 50);
        q.pop();
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(100, "later");
        q.pop();
        q.schedule_at(10, "past"); // clamped to 100
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (100, "past"));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(40, ());
        q.pop();
        q.schedule_in(5, ());
        assert_eq!(q.next_time(), Some(45));
    }

    #[test]
    fn unit_conversions_roundtrip() {
        assert_eq!(secs(1.5), 1_500_000);
        assert_eq!(secs(-3.0), 0);
        assert!((to_secs(secs(12.25)) - 12.25).abs() < 1e-9);
        assert_eq!(MILLIS * 1000, SECONDS);
        assert_eq!(MICROS * 1000, MILLIS);
    }

    #[test]
    fn processed_counts_dispatches() {
        let mut q = EventQueue::new();
        for i in 0..10u64 {
            q.schedule_at(i, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 10);
        assert!(q.is_empty());
    }
}
