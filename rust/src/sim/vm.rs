//! VM / cluster-node provisioning model.
//!
//! Covers the resource-acquisition phase that precedes workload execution:
//! Hydra's CaaS Manager "can instantiate new clusters on each cloud
//! provider from the requirements specified via the resource.VM object"
//! (paper §3.2). Provisioning latency is right-skewed in practice, so we
//! draw per-node times from a lognormal around the profile's mean; a
//! cluster is ready when its slowest node is up (nodes provision in
//! parallel), plus a control-plane bring-up constant for managed
//! Kubernetes (EKS/AKS) clusters.

use super::provider::{PlatformKind, PlatformProfile};
use crate::util::prng::Prng;

/// Control-plane bring-up for managed Kubernetes (simulated constant).
const CONTROL_PLANE_S: f64 = 35.0;

/// Outcome of provisioning one cluster.
#[derive(Debug, Clone)]
pub struct ProvisionReport {
    /// Per-node readiness times (seconds from request).
    pub node_ready_s: Vec<f64>,
    /// When the whole cluster is usable.
    pub ready_s: f64,
}

/// Provision `nodes` VMs (or accept an HPC allocation, which has no VM
/// provisioning — its latency lives in the batch queue instead).
pub fn provision_cluster(profile: &PlatformProfile, nodes: u32, rng: &mut Prng) -> ProvisionReport {
    if profile.kind == PlatformKind::Hpc || profile.provision_mean_s <= 0.0 {
        return ProvisionReport { node_ready_s: vec![0.0; nodes as usize], ready_s: 0.0 };
    }
    let node_ready_s: Vec<f64> = (0..nodes)
        .map(|_| rng.lognormal_mean_cv(profile.provision_mean_s, profile.provision_cv))
        .collect();
    let slowest = node_ready_s.iter().cloned().fold(0.0f64, f64::max);
    ProvisionReport { node_ready_s, ready_s: CONTROL_PLANE_S + slowest }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::{PlatformProfile, ProviderId};

    #[test]
    fn cloud_provisioning_positive_and_scales_with_nodes() {
        let p = PlatformProfile::of(ProviderId::Aws);
        let mut rng = Prng::new(1);
        let one = provision_cluster(&p, 1, &mut rng);
        assert_eq!(one.node_ready_s.len(), 1);
        assert!(one.ready_s > CONTROL_PLANE_S);
        // More nodes => max of more draws => stochastically larger. Check
        // the deterministic property instead: ready >= every node.
        let mut rng = Prng::new(2);
        let many = provision_cluster(&p, 16, &mut rng);
        for n in &many.node_ready_s {
            assert!(many.ready_s >= *n);
        }
    }

    #[test]
    fn hpc_has_no_vm_provisioning() {
        let p = PlatformProfile::of(ProviderId::Bridges2);
        let mut rng = Prng::new(3);
        let r = provision_cluster(&p, 4, &mut rng);
        assert_eq!(r.ready_s, 0.0);
        assert!(r.node_ready_s.iter().all(|t| *t == 0.0));
    }

    #[test]
    fn mean_matches_profile() {
        let p = PlatformProfile::of(ProviderId::Jetstream2);
        let mut rng = Prng::new(4);
        let mut sum = 0.0;
        let n = 2000;
        for _ in 0..n {
            sum += provision_cluster(&p, 1, &mut rng).node_ready_s[0];
        }
        let mean = sum / n as f64;
        assert!((mean - p.provision_mean_s).abs() < p.provision_mean_s * 0.1, "mean {mean}");
    }
}
