//! Platform substrates: deterministic simulators standing in for the live
//! cloud/HPC testbeds of the paper's evaluation (see DESIGN.md §1).
//!
//! * [`event`] — discrete-event engine (virtual clock + ordered queue;
//!   calendar/bucket store by default with the binary heap kept as the
//!   byte-identical reference — `EventQueueKind`). Every substrate below
//!   inherits the queue through the shared `EventQueue<E>` API.
//! * [`provider`] — calibrated per-platform profiles (JET2, CHI, AWS,
//!   Azure, Bridges2).
//! * [`capacity`] — shared segment-tree free-capacity index (per-node
//!   leaves for the Kubernetes scheduler, per-pilot leaves for the HPC
//!   multi-pilot scheduler).
//! * [`kubernetes`] — cluster/pod lifecycle + scheduler (EKS/AKS stand-in).
//! * [`hpc`] — batch queue + pilot agents, single- and multi-pilot
//!   (Bridges2 + RADICAL-Pilot stand-in).
//! * [`faas`] — function-as-a-service (cold/warm starts, concurrency cap).
//! * [`vm`] — VM/cluster provisioning latencies.

pub mod capacity;
pub mod event;
pub mod faas;
pub mod hpc;
pub mod kubernetes;
pub mod provider;
pub mod vm;
