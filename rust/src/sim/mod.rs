//! Platform substrates: deterministic simulators standing in for the live
//! cloud/HPC testbeds of the paper's evaluation (see DESIGN.md §1).
//!
//! * [`event`] — discrete-event engine (virtual clock + ordered queue).
//! * [`provider`] — calibrated per-platform profiles (JET2, CHI, AWS,
//!   Azure, Bridges2).
//! * [`kubernetes`] — cluster/pod lifecycle + scheduler (EKS/AKS stand-in).
//! * [`hpc`] — batch queue + pilot agent (Bridges2 + RADICAL-Pilot stand-in).
//! * [`faas`] — function-as-a-service (cold/warm starts, concurrency cap).
//! * [`vm`] — VM/cluster provisioning latencies.

pub mod event;
pub mod faas;
pub mod hpc;
pub mod kubernetes;
pub mod provider;
pub mod vm;
