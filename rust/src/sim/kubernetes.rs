//! Kubernetes cluster simulator.
//!
//! Stands in for EKS/AKS and the custom Kubernetes images the paper deploys
//! on Jetstream2/Chameleon (§5, Table 1). The model reproduces the cost
//! structure that the paper's TPT metric measures — *prepare + execute +
//! tear down the task execution environments*:
//!
//! * **API server**: a bulk submission costs `api_batch_base + n·api_per_object`
//!   (Hydra submits pods "in a single batch" precisely to amortize this).
//! * **Scheduler**: a single control loop binds pods FIFO at
//!   `sched_per_pod` seconds per bind; a pod that does not fit blocks the
//!   queue head until capacity frees (single-queue approximation of
//!   kube-scheduler).
//! * **Kubelet**: each node's kubelet creates pod sandboxes *serially*
//!   (containerd serializes sandbox ops); a bound pod reserves its
//!   resources from bind but only starts containers once its sandbox is
//!   up. This per-pod serialized cost is what makes SCPP (one sandbox per
//!   task) pay the ≈ +9% TPT premium over MCPP that §5.1 reports.
//!   Containers then start concurrently; each start costs
//!   `effective_start_s(busy_vcpus)` — the contention model that produces
//!   the per-provider strong-scaling curves of Fig 2 (bottom).
//! * **Teardown**: after the last container exits, the pod holds its
//!   resources for `pod_teardown` before they free.
//!
//! Container payloads run for `payload_duration_s(work, cpus)` of virtual
//! time (zero for the paper's noop tasks). Everything is deterministic
//! given the seed.
//!
//! # Scheduler index (§Perf / DESIGN-note)
//!
//! The original implementation re-scanned every node linearly for the
//! head-of-queue pod on every scheduler tick, making a placement or
//! teardown event O(P·N) over a run (P pods, N nodes). The scheduler now
//! maintains a [`CapacityIndex`] (the shared segment tree of
//! [`sim::capacity`](crate::sim::capacity), extracted from this module in
//! ISSUE 5) whose leaves are the per-node free (cpu, gpu, mem) triples:
//!
//! * `reserve` / `release` — **O(log N)** exact leaf updates.
//! * `first_fit` — maxima-pruned descent to the lowest-indexed node that
//!   satisfies all three constraints, i.e. the *same node the linear scan
//!   would pick* (determinism is preserved by construction and enforced
//!   by `indexed_scheduler_matches_linear_scan` below). **O(log N)**
//!   expected; see the capacity module docs for the worst-case caveat.
//!
//! The seed's linear scan is kept as [`SchedulerKind::LinearScan`] — the
//! reference implementation for equivalence tests and the baseline that
//! `bench_quick` measures the index against. The segment tree's own
//! reference-checked unit tests live with the shared index in
//! `sim::capacity`.

use super::capacity::{Cap, CapacityIndex};
use super::event::{secs, to_secs, EventQueue, EventQueueKind, SimTime};
use super::provider::PlatformProfile;
use crate::util::prng::Prng;

/// Resource demand of one container (one Hydra task). All-scalar and
/// `Copy` on purpose: the pod-start path iterates containers without
/// cloning the pod's container list (§Perf).
#[derive(Debug, Clone, Copy)]
pub struct ContainerSpec {
    pub task_id: u64,
    pub cpus: u32,
    pub gpus: u32,
    pub mem_mb: u64,
    /// Payload work in seconds on an AWS-reference core (0 = noop).
    pub work_s: f64,
    /// Fixed duration independent of platform speed (Experiment 3B's
    /// `sleep` tasks).
    pub sleep_s: f64,
}

impl ContainerSpec {
    pub fn noop(task_id: u64) -> ContainerSpec {
        ContainerSpec { task_id, cpus: 1, gpus: 0, mem_mb: 256, work_s: 0.0, sleep_s: 0.0 }
    }
}

/// A pod: one or more containers scheduled as a unit (MCPP groups many
/// containers per pod; SCPP uses exactly one).
#[derive(Debug, Clone)]
pub struct PodSpec {
    pub id: u64,
    pub containers: Vec<ContainerSpec>,
}

impl PodSpec {
    pub fn cpus(&self) -> u32 {
        self.containers.iter().map(|c| c.cpus).sum()
    }

    pub fn gpus(&self) -> u32 {
        self.containers.iter().map(|c| c.gpus).sum()
    }

    pub fn mem_mb(&self) -> u64 {
        self.containers.iter().map(|c| c.mem_mb).sum()
    }
}

/// Cluster shape (uniform nodes, as in the paper's experiments).
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub vcpus_per_node: u32,
    pub gpus_per_node: u32,
    pub mem_mb_per_node: u64,
}

impl ClusterSpec {
    pub fn uniform(nodes: u32, vcpus_per_node: u32) -> ClusterSpec {
        ClusterSpec {
            nodes,
            vcpus_per_node,
            gpus_per_node: 0,
            mem_mb_per_node: 4096 * vcpus_per_node as u64,
        }
    }

    pub fn with_gpus(mut self, gpus_per_node: u32) -> ClusterSpec {
        self.gpus_per_node = gpus_per_node;
        self
    }

    pub fn total_vcpus(&self) -> u32 {
        self.nodes * self.vcpus_per_node
    }
}

/// Per-task execution record (virtual timestamps, seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRecord {
    pub task_id: u64,
    pub pod_id: u64,
    pub node: u32,
    /// When the pod was bound to a node.
    pub scheduled_s: f64,
    /// When the container entered Running (after start cost).
    pub started_s: f64,
    /// When the container exited.
    pub finished_s: f64,
    /// Whether the container exited non-zero (injected failures).
    pub failed: bool,
}

/// Result of simulating one workload on one cluster.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Virtual makespan: submission until the last pod teardown completes.
    /// This is the paper's TPT for the noop workloads.
    pub makespan_s: f64,
    pub tasks: Vec<TaskRecord>,
    pub pods_completed: usize,
    pub failed_tasks: usize,
    pub events_processed: u64,
    /// Peak number of concurrently-running containers (schedulability probe).
    pub peak_running: usize,
}

/// Which placement search the scheduler control loop uses. Both pick the
/// *identical* node (lowest index that fits); they differ only in search
/// cost. `Indexed` is the default; `LinearScan` is the seed reference kept
/// for equivalence testing and as the `bench_quick` baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Segment-tree free-capacity index: O(log N) per placement/teardown.
    Indexed,
    /// The original per-tick scan over all nodes: O(N) per tick.
    LinearScan,
}

/// Kubelet-side per-node state. Free capacity lives in the shared
/// [`CapacityIndex`] (single source of truth for both scheduler kinds).
#[derive(Debug, Clone, Copy)]
struct NodeState {
    busy_cpus: u32,
    /// When this node's kubelet is free to create the next pod sandbox
    /// (sandbox creation is serialized per node).
    kubelet_free: SimTime,
}

struct PodState {
    spec: PodSpec,
    /// Resource totals, computed once at submission instead of re-summing
    /// the container list on every scheduler tick (§Perf).
    need_cpus: u32,
    need_gpus: u32,
    need_mem: u64,
    node: Option<u32>,
    remaining: usize,
    scheduled_at: SimTime,
}

enum Ev {
    /// API server finished persisting a submission batch.
    ApiDone { first_pod: usize, count: usize },
    /// Scheduler control-loop tick.
    SchedTick,
    /// Pod sandbox ready; start containers.
    PodReady { pod: usize },
    /// One container exited.
    ContainerDone { pod: usize, cpus: u32 },
    /// Pod teardown complete; free resources.
    PodGone { pod: usize },
}

/// The simulator. Construct, `submit` one or more batches, then `run`.
pub struct KubernetesSim {
    profile: PlatformProfile,
    nodes: Vec<NodeState>,
    index: CapacityIndex,
    scheduler: SchedulerKind,
    pods: Vec<PodState>,
    queue: EventQueue<Ev>,
    pending: std::collections::VecDeque<usize>,
    sched_busy: bool,
    rng: Prng,
    records: Vec<TaskRecord>,
    completed: usize,
    failed: usize,
    /// Probability that a container exits non-zero (failure injection).
    failure_rate: f64,
    running_containers: usize,
    peak_running: usize,
}

impl KubernetesSim {
    pub fn new(profile: PlatformProfile, cluster: ClusterSpec, seed: u64) -> KubernetesSim {
        let nodes = (0..cluster.nodes)
            .map(|_| NodeState { busy_cpus: 0, kubelet_free: 0 })
            .collect();
        let index = CapacityIndex::uniform(
            cluster.nodes as usize,
            Cap::new(cluster.vcpus_per_node, cluster.gpus_per_node, cluster.mem_mb_per_node),
        );
        KubernetesSim {
            profile,
            nodes,
            index,
            scheduler: SchedulerKind::Indexed,
            pods: Vec::new(),
            queue: EventQueue::new(),
            pending: std::collections::VecDeque::new(),
            sched_busy: false,
            // hydra-lint: allow(prng-salt) — the sim's primary stream; substreams fork from it
            rng: Prng::new(seed),
            records: Vec::new(),
            completed: 0,
            failed: 0,
            failure_rate: 0.0,
            running_containers: 0,
            peak_running: 0,
        }
    }

    /// Select the placement search implementation (default: `Indexed`).
    pub fn with_scheduler(mut self, kind: SchedulerKind) -> KubernetesSim {
        self.scheduler = kind;
        self
    }

    /// Select the event-queue backing store (default: `Calendar`; the
    /// `Heap` reference is what `bench_scale` and the queue microbench
    /// in `bench_quick` measure the calendar against). Must be called
    /// before the first `submit`.
    pub fn with_event_queue(mut self, kind: EventQueueKind) -> KubernetesSim {
        assert!(
            self.pods.is_empty() && self.queue.is_empty(),
            "event-queue kind must be selected before submitting"
        );
        self.queue = EventQueue::with_kind(kind);
        self
    }

    /// Enable failure injection: each container independently exits
    /// non-zero with probability `p` (exercises the broker's failure /
    /// graceful-termination path, paper §3.2).
    pub fn with_failure_rate(mut self, p: f64) -> KubernetesSim {
        self.failure_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Submit a batch of pods through the (simulated) API server at
    /// virtual time `at_s`. Takes the pods by value: the broker hands its
    /// prepared `Vec<PodSpec>` over without cloning (§Perf).
    pub fn submit(&mut self, pods: Vec<PodSpec>, at_s: f64) {
        let first_pod = self.pods.len();
        let count = pods.len();
        self.pods.reserve(count);
        for spec in pods {
            let remaining = spec.containers.len();
            let (mut c, mut g, mut m) = (0u32, 0u32, 0u64);
            for cont in &spec.containers {
                c += cont.cpus;
                g += cont.gpus;
                m += cont.mem_mb;
            }
            self.pods.push(PodState {
                spec,
                need_cpus: c,
                need_gpus: g,
                need_mem: m,
                node: None,
                remaining,
                scheduled_at: 0,
            });
        }
        let api_latency = self.profile.api_batch_base_s
            + self.profile.api_per_object_s * count as f64;
        self.queue
            .schedule_at(secs(at_s) + secs(api_latency), Ev::ApiDone { first_pod, count });
    }

    /// Whether any node could *ever* fit this pod (capacity check against
    /// an empty node).
    pub fn schedulable(&self, pod: &PodSpec, cluster: &ClusterSpec) -> bool {
        pod.cpus() <= cluster.vcpus_per_node
            && pod.gpus() <= cluster.gpus_per_node
            && pod.mem_mb() <= cluster.mem_mb_per_node
    }

    /// Total free (cpus, gpus, mem_mb) across all nodes right now.
    /// Schedulability probe; also the invariant surface for the
    /// teardown-frees-capacity tests.
    pub fn free_capacity(&self) -> (u32, u32, u64) {
        let free = self.index.total_free();
        (free.cpus, free.gpus, free.mem)
    }

    fn find_node(&self, pod: usize) -> Option<u32> {
        let p = &self.pods[pod];
        let need = Cap::new(p.need_cpus, p.need_gpus, p.need_mem);
        match self.scheduler {
            SchedulerKind::Indexed => self.index.first_fit(need),
            SchedulerKind::LinearScan => self.index.first_fit_linear(need),
        }
    }

    fn kick_scheduler(&mut self) {
        if !self.sched_busy && !self.pending.is_empty() {
            self.sched_busy = true;
            self.queue
                .schedule_in(secs(self.profile.sched_per_pod_s), Ev::SchedTick);
        }
    }

    /// Run to quiescence, returning the report.
    pub fn run(&mut self) -> SimReport {
        while let Some((_, ev)) = self.queue.pop() {
            match ev {
                Ev::ApiDone { first_pod, count } => {
                    for p in first_pod..first_pod + count {
                        self.pending.push_back(p);
                    }
                    self.kick_scheduler();
                }
                Ev::SchedTick => {
                    self.sched_busy = false;
                    if let Some(&pod) = self.pending.front() {
                        if let Some(node) = self.find_node(pod) {
                            self.pending.pop_front();
                            self.bind(pod, node);
                            self.kick_scheduler();
                        }
                        // else: head-of-line blocked; a PodGone will re-kick.
                    }
                }
                Ev::PodReady { pod } => self.start_containers(pod),
                Ev::ContainerDone { pod, cpus } => {
                    self.running_containers -= 1;
                    // Container slots free at exit; pod bookkeeping frees at
                    // teardown (sandbox holds mem until deleted).
                    if let Some(node) = self.pods[pod].node {
                        self.nodes[node as usize].busy_cpus =
                            self.nodes[node as usize].busy_cpus.saturating_sub(cpus);
                    }
                    self.pods[pod].remaining -= 1;
                    if self.pods[pod].remaining == 0 {
                        self.queue
                            .schedule_in(secs(self.profile.pod_teardown_s), Ev::PodGone { pod });
                    }
                }
                Ev::PodGone { pod } => {
                    let node = self.pods[pod].node.expect("torn-down pod was bound") as usize;
                    let freed = Cap::new(
                        self.pods[pod].need_cpus,
                        self.pods[pod].need_gpus,
                        self.pods[pod].need_mem,
                    );
                    self.index.release(node, freed);
                    self.completed += 1;
                    self.kick_scheduler();
                }
            }
        }
        SimReport {
            makespan_s: to_secs(self.queue.now()),
            tasks: std::mem::take(&mut self.records),
            pods_completed: self.completed,
            failed_tasks: self.failed,
            events_processed: self.queue.processed(),
            peak_running: self.peak_running,
        }
    }

    fn bind(&mut self, pod: usize, node: u32) {
        let now = self.queue.now();
        let need = Cap::new(
            self.pods[pod].need_cpus,
            self.pods[pod].need_gpus,
            self.pods[pod].need_mem,
        );
        self.index.reserve(node as usize, need);
        // Serialized sandbox creation: the kubelet works one sandbox at a
        // time while the pod's reservation is already held — the SCPP
        // per-task premium.
        let n = &mut self.nodes[node as usize];
        let ready_at = n.kubelet_free.max(now) + secs(self.profile.pod_overhead_s);
        n.kubelet_free = ready_at;
        self.pods[pod].node = Some(node);
        self.pods[pod].scheduled_at = now;
        self.queue.schedule_at(ready_at, Ev::PodReady { pod });
    }

    fn start_containers(&mut self, pod: usize) {
        let node_idx = self.pods[pod].node.unwrap() as usize;
        let scheduled_s = to_secs(self.pods[pod].scheduled_at);
        let pod_id = self.pods[pod].spec.id;
        let n_containers = self.pods[pod].spec.containers.len();
        // Containers that share a pod share its sandbox, network namespace
        // and image mounts: starting k containers inside one sandbox is
        // cheaper per container than k separate sandboxes. This is the
        // platform-side half of the paper's SCPP premium ("larger
        // overheads of per-pod initialization, scheduling, and
        // termination", §5.1).
        let intra_pod_discount = if n_containers > 1 { 0.80 } else { 1.0 };
        for ci in 0..n_containers {
            // `ContainerSpec` is `Copy`: no per-pod clone of the container
            // list on the start path (§Perf).
            let c = self.pods[pod].spec.containers[ci];
            // Contention is evaluated against the node occupancy at start
            // time: the more vCPUs already busy, the slower the hypervisor
            // brings the next container up.
            let busy = self.nodes[node_idx].busy_cpus;
            self.nodes[node_idx].busy_cpus += c.cpus;
            self.running_containers += 1;
            self.peak_running = self.peak_running.max(self.running_containers);
            let base = self.profile.effective_start_s(busy + c.cpus) * intra_pod_discount;
            let start_cost = self
                .rng
                .normal_trunc(base, base * self.profile.container_start_cv, base * 0.2);
            let run = c.sleep_s + self.profile.payload_duration_s(c.work_s, c.cpus);
            let started = to_secs(self.queue.now()) + start_cost;
            let finished = started + run;
            let failed = self.failure_rate > 0.0 && self.rng.bool_with_p(self.failure_rate);
            if failed {
                self.failed += 1;
            }
            self.records.push(TaskRecord {
                task_id: c.task_id,
                pod_id,
                node: node_idx as u32,
                scheduled_s,
                started_s: started,
                finished_s: finished,
                failed,
            });
            self.queue
                .schedule_in(secs(start_cost + run), Ev::ContainerDone { pod, cpus: c.cpus });
        }
    }
}

/// Convenience: simulate one batch of pods on a fresh cluster.
pub fn simulate_batch(
    profile: &PlatformProfile,
    cluster: ClusterSpec,
    pods: Vec<PodSpec>,
    seed: u64,
) -> SimReport {
    let mut sim = KubernetesSim::new(profile.clone(), cluster, seed);
    sim.submit(pods, 0.0);
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::ProviderId;

    fn noop_pods(n: usize, per_pod: usize) -> Vec<PodSpec> {
        let mut task = 0u64;
        (0..n)
            .map(|i| PodSpec {
                id: i as u64,
                containers: (0..per_pod)
                    .map(|_| {
                        task += 1;
                        ContainerSpec::noop(task)
                    })
                    .collect(),
            })
            .collect()
    }

    /// Heterogeneous pods: varying cpus/mem, a few gpus — stresses the
    /// multi-dimension index search.
    fn hetero_pods(n: usize) -> Vec<PodSpec> {
        (0..n)
            .map(|i| {
                let mut c = ContainerSpec::noop(i as u64 + 1);
                c.cpus = 1 + (i as u32 % 4);
                c.mem_mb = 128 + (i as u64 % 7) * 256;
                c.gpus = if i % 11 == 0 { 1 } else { 0 };
                c.work_s = (i % 3) as f64 * 0.5;
                PodSpec { id: i as u64, containers: vec![c] }
            })
            .collect()
    }

    fn profile() -> PlatformProfile {
        PlatformProfile::of(ProviderId::Aws)
    }

    #[test]
    fn all_tasks_complete_exactly_once() {
        let pods = noop_pods(40, 4);
        let r = simulate_batch(&profile(), ClusterSpec::uniform(1, 16), pods, 1);
        assert_eq!(r.pods_completed, 40);
        assert_eq!(r.tasks.len(), 160);
        let mut ids: Vec<u64> = r.tasks.iter().map(|t| t.task_id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 160);
    }

    #[test]
    fn task_timestamps_ordered() {
        let r = simulate_batch(&profile(), ClusterSpec::uniform(1, 8), noop_pods(20, 2), 2);
        for t in &r.tasks {
            assert!(t.scheduled_s >= 0.0);
            assert!(t.started_s >= t.scheduled_s);
            assert!(t.finished_s >= t.started_s);
            assert!(t.finished_s <= r.makespan_s + 1e-9);
        }
    }

    #[test]
    fn capacity_never_exceeded() {
        // 8 vCPU node, pods of 4 cpus => at most 2 pods' containers run
        // concurrently; probe via peak_running with 1-cpu containers.
        let pods = noop_pods(30, 4); // each pod needs 4 cpus
        let r = simulate_batch(&profile(), ClusterSpec::uniform(1, 8), pods, 3);
        assert!(r.peak_running <= 8, "peak {} > capacity", r.peak_running);
    }

    #[test]
    fn more_vcpus_is_faster_strong_scaling() {
        let mk = |v: u32| {
            simulate_batch(&profile(), ClusterSpec::uniform(1, v), noop_pods(200, 1), 4).makespan_s
        };
        let t4 = mk(4);
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t8 < t4, "{t8} !< {t4}");
        assert!(t16 < t8, "{t16} !< {t8}");
    }

    #[test]
    fn scpp_pays_tpt_premium_over_mcpp() {
        // Same 120 tasks: 120 single-container pods vs 15 eight-container
        // pods (both pack the 16-vCPU node fully). SCPP creates 8x more
        // sandboxes through the serialized kubelet => larger TPT; §5.1
        // reports ~+9%, we accept a loose band around it.
        let scpp = simulate_batch(&profile(), ClusterSpec::uniform(1, 16), noop_pods(120, 1), 5);
        let mcpp = simulate_batch(&profile(), ClusterSpec::uniform(1, 16), noop_pods(15, 8), 5);
        let ratio = scpp.makespan_s / mcpp.makespan_s;
        assert!(ratio > 1.0, "SCPP {} !> MCPP {}", scpp.makespan_s, mcpp.makespan_s);
        assert!(ratio < 1.6, "premium implausibly large: {ratio}");
    }

    #[test]
    fn contention_shapes_provider_ordering_at_16() {
        let run = |id: ProviderId| {
            simulate_batch(
                &PlatformProfile::of(id),
                ClusterSpec::uniform(1, 16),
                noop_pods(300, 1),
                7,
            )
            .makespan_s
        };
        let jet2 = run(ProviderId::Jetstream2);
        let azure = run(ProviderId::Azure);
        let chi = run(ProviderId::Chameleon);
        assert!(azure < jet2, "Fig2: azure {azure} outperforms jet2 {jet2} at 16 vCPUs");
        assert!(chi > azure, "chameleon worst: {chi} vs {azure}");
    }

    #[test]
    fn payload_work_extends_makespan() {
        let mut pods = noop_pods(10, 1);
        let r0 = simulate_batch(&profile(), ClusterSpec::uniform(1, 4), pods.clone(), 9);
        for p in &mut pods {
            p.containers[0].work_s = 10.0;
        }
        let r1 = simulate_batch(&profile(), ClusterSpec::uniform(1, 4), pods, 9);
        assert!(r1.makespan_s > r0.makespan_s + 5.0);
    }

    #[test]
    fn gpu_pods_respect_gpu_capacity() {
        let mut pods = noop_pods(6, 1);
        for p in &mut pods {
            p.containers[0].gpus = 2;
            p.containers[0].work_s = 1.0;
        }
        let cluster = ClusterSpec::uniform(1, 16).with_gpus(4);
        // Only 2 pods can hold GPUs at once; all must still complete.
        let r = simulate_batch(&profile(), cluster, pods, 11);
        assert_eq!(r.pods_completed, 6);
    }

    #[test]
    fn determinism_same_seed() {
        let a = simulate_batch(&profile(), ClusterSpec::uniform(2, 8), noop_pods(50, 2), 42);
        let b = simulate_batch(&profile(), ClusterSpec::uniform(2, 8), noop_pods(50, 2), 42);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn multi_batch_submission() {
        let mut sim = KubernetesSim::new(profile(), ClusterSpec::uniform(1, 8), 13);
        sim.submit(noop_pods(10, 1), 0.0);
        sim.submit(
            noop_pods(10, 1)
                .into_iter()
                .map(|mut p| {
                    p.id += 100;
                    p.containers[0].task_id += 1000;
                    p
                })
                .collect(),
            5.0,
        );
        let r = sim.run();
        assert_eq!(r.pods_completed, 20);
        assert!(r.makespan_s >= 5.0);
    }

    // ---- scheduler-index coverage (§Perf tentpole) ------------------------

    fn run_with(
        kind: SchedulerKind,
        cluster: ClusterSpec,
        pods: Vec<PodSpec>,
        seed: u64,
    ) -> SimReport {
        let mut sim = KubernetesSim::new(profile(), cluster, seed).with_scheduler(kind);
        sim.submit(pods, 0.0);
        sim.run()
    }

    #[test]
    fn indexed_scheduler_matches_linear_scan_on_1k_tasks() {
        // The acceptance equivalence: identical TaskRecord timings (exact
        // f64 equality — both paths consume the PRNG in the same order and
        // perform the same arithmetic) on a 1K-task heterogeneous workload
        // over a multi-node cluster.
        let cluster = ClusterSpec::uniform(8, 16).with_gpus(2);
        let a = run_with(SchedulerKind::Indexed, cluster, hetero_pods(1000), 77);
        let b = run_with(SchedulerKind::LinearScan, cluster, hetero_pods(1000), 77);
        assert_eq!(a.tasks.len(), 1000);
        assert_eq!(a.tasks, b.tasks, "scheduler index changed placement or timing");
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.peak_running, b.peak_running);
    }

    #[test]
    fn indexed_scheduler_matches_linear_scan_with_mcpp_pods() {
        let cluster = ClusterSpec::uniform(4, 16);
        let a = run_with(SchedulerKind::Indexed, cluster, noop_pods(200, 4), 5);
        let b = run_with(SchedulerKind::LinearScan, cluster, noop_pods(200, 4), 5);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn teardown_frees_all_capacity() {
        // Invariant: after running to quiescence every reservation has been
        // released — the index must read full capacity again.
        let cluster = ClusterSpec::uniform(6, 8).with_gpus(4);
        for kind in [SchedulerKind::Indexed, SchedulerKind::LinearScan] {
            let mut sim = KubernetesSim::new(profile(), cluster, 21).with_scheduler(kind);
            sim.submit(hetero_pods(300), 0.0);
            let r = sim.run();
            assert_eq!(r.pods_completed, 300);
            let (c, g, m) = sim.free_capacity();
            assert_eq!(c, cluster.nodes * cluster.vcpus_per_node, "cpus leaked ({kind:?})");
            assert_eq!(g, cluster.nodes * cluster.gpus_per_node, "gpus leaked ({kind:?})");
            assert_eq!(m, cluster.nodes as u64 * cluster.mem_mb_per_node, "mem leaked ({kind:?})");
        }
    }

    #[test]
    fn fresh_cluster_reports_full_free_capacity() {
        let cluster = ClusterSpec::uniform(5, 4).with_gpus(1);
        let sim = KubernetesSim::new(profile(), cluster, 0);
        assert_eq!(sim.free_capacity(), (20, 5, 5 * cluster.mem_mb_per_node));
    }

    #[test]
    fn placement_deterministic_across_seeds() {
        // Per seed: bit-identical reruns. Across seeds: node assignment
        // sequence is a pure function of the (deterministic) event order,
        // so every run stays internally consistent and complete.
        let cluster = ClusterSpec::uniform(4, 8).with_gpus(1);
        for seed in [1u64, 7, 42, 1337] {
            let a = run_with(SchedulerKind::Indexed, cluster, hetero_pods(120), seed);
            let b = run_with(SchedulerKind::Indexed, cluster, hetero_pods(120), seed);
            assert_eq!(a.tasks.len(), 120, "seed {seed}: tasks lost");
            assert_eq!(a.tasks, b.tasks, "seed {seed} not reproducible");
            assert_eq!(a.events_processed, b.events_processed);
            for t in &a.tasks {
                assert!(t.node < cluster.nodes);
            }
        }
    }

    // The segment tree's direct unit coverage (first-fit vs reference
    // scan under churn) moved with the index to `sim::capacity` (ISSUE 5
    // satellite); the scheduler-level equivalence tests above still lock
    // this module's use of it.

    #[test]
    fn calendar_queue_matches_heap_queue_end_to_end() {
        // ISSUE 8: the queue-level equivalence suite proves identical pop
        // order; this locks the consequence at the simulator layer —
        // identical TaskRecords (exact f64s: same pop order means the
        // PRNG is consumed in the same order) under both backing stores.
        let cluster = ClusterSpec::uniform(8, 16).with_gpus(2);
        let run_q = |qkind: EventQueueKind| {
            let mut sim = KubernetesSim::new(profile(), cluster, 77).with_event_queue(qkind);
            sim.submit(hetero_pods(1000), 0.0);
            sim.run()
        };
        let cal = run_q(EventQueueKind::Calendar);
        let heap = run_q(EventQueueKind::Heap);
        assert_eq!(cal.tasks.len(), 1000);
        assert_eq!(cal.tasks, heap.tasks, "calendar queue changed the schedule");
        assert_eq!(cal.events_processed, heap.events_processed);
        assert_eq!(cal.makespan_s, heap.makespan_s);
        assert_eq!(cal.peak_running, heap.peak_running);
    }
}
