//! Platform profiles: the calibrated constants behind each simulated
//! provider.
//!
//! The original evaluation ran on live allocations (Jetstream2, Chameleon,
//! AWS, Azure, Bridges2). Those are unavailable here, so each platform is a
//! deterministic simulator parameterized by this profile. The constants are
//! calibrated so the *relationships* the paper reports hold (see DESIGN.md
//! §4 "expected shapes"):
//!
//! * Fig 2 (bottom): Jetstream2 has the lowest per-container cost at small
//!   vCPU counts (its vCPUs pin to physical cores); Azure has the flattest
//!   contention curve (hypervisor optimizations) and overtakes Jetstream2
//!   at 16 vCPUs; Chameleon has the steepest contention curve (least
//!   optimized hypervisor); SCPP costs ≈ +9% TPT via the per-pod sandbox.
//! * Fig 5: per-core compute speed Jetstream2 ≈ 2.5× AWS; Bridges2 ≈ 5×
//!   Jetstream2 end-to-end (per-core speed, no virtualization overhead, and
//!   128-core nodes), i.e. ≈ 10× AWS.
//! * Exp 3A: HPC queue waits were "short and consistent" in the paper's
//!   runs — mean 45 s with low variance.

use std::fmt;

/// The platforms of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProviderId {
    Jetstream2,
    Chameleon,
    Aws,
    Azure,
    Bridges2,
}

impl ProviderId {
    pub const ALL: [ProviderId; 5] = [
        ProviderId::Jetstream2,
        ProviderId::Chameleon,
        ProviderId::Aws,
        ProviderId::Azure,
        ProviderId::Bridges2,
    ];

    /// The four cloud providers of Experiments 1–2.
    pub const CLOUDS: [ProviderId; 4] = [
        ProviderId::Jetstream2,
        ProviderId::Chameleon,
        ProviderId::Aws,
        ProviderId::Azure,
    ];

    pub fn short_name(self) -> &'static str {
        match self {
            ProviderId::Jetstream2 => "JET2",
            ProviderId::Chameleon => "CHI",
            ProviderId::Aws => "AWS",
            ProviderId::Azure => "AZURE",
            ProviderId::Bridges2 => "BRIDGES2",
        }
    }

    pub fn parse(s: &str) -> Option<ProviderId> {
        match s.to_ascii_lowercase().as_str() {
            "jet2" | "jetstream2" => Some(ProviderId::Jetstream2),
            "chi" | "chameleon" => Some(ProviderId::Chameleon),
            "aws" => Some(ProviderId::Aws),
            "azure" => Some(ProviderId::Azure),
            "bridges2" | "b2" | "hpc" => Some(ProviderId::Bridges2),
            _ => None,
        }
    }
}

impl fmt::Display for ProviderId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.short_name())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlatformKind {
    Cloud,
    Hpc,
}

/// How guest vCPUs map onto host silicon (paper §5.1 uses this to explain
/// Jetstream2's baseline advantage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuPinning {
    PhysicalCore,
    Thread,
    /// Bare-metal HPC nodes: no hypervisor at all.
    BareMetal,
}

/// Calibrated constants for one platform. All times in seconds.
#[derive(Debug, Clone)]
pub struct PlatformProfile {
    pub id: ProviderId,
    pub kind: PlatformKind,
    pub pinning: CpuPinning,

    // --- Kubernetes control plane (cloud platforms) -----------------------
    /// Base latency of one bulk API submission call.
    pub api_batch_base_s: f64,
    /// Marginal API-server cost per object in a bulk submission.
    pub api_per_object_s: f64,
    /// Scheduler dequeue-and-bind time per pod.
    pub sched_per_pod_s: f64,
    /// Mean container start (sandbox + image-cached container boot).
    pub container_start_s: f64,
    /// Coefficient of variation of container start.
    pub container_start_cv: f64,
    /// Extra per-pod sandbox setup; the SCPP ≈ +9% TPT effect — SCPP pays
    /// this once per *task*, MCPP amortizes it across the pod's containers.
    pub pod_overhead_s: f64,
    pub pod_teardown_s: f64,
    /// Contention slope: effective per-container cost multiplier is
    /// `1 + contention * (busy_vcpus - 1)` — the hypervisor-quality knob
    /// behind the strong-scaling differences in Fig 2 (bottom).
    pub contention: f64,

    // --- compute ----------------------------------------------------------
    /// Relative per-core execution speed for task payloads (AWS vCPU = 1.0).
    pub cpu_speed: f64,
    pub cores_per_node: u32,

    // --- provisioning -----------------------------------------------------
    /// Mean VM/cluster-node provisioning latency.
    pub provision_mean_s: f64,
    pub provision_cv: f64,

    // --- HPC batch system (HPC platforms) ----------------------------------
    pub queue_wait_mean_s: f64,
    pub queue_wait_cv: f64,
    /// Pilot-job agent bootstrap once the batch job starts.
    pub pilot_boot_s: f64,
    /// Per-task launch overhead inside the pilot (RADICAL-Pilot executor).
    pub task_launch_s: f64,
}

impl PlatformProfile {
    /// The calibrated profile for a provider (see module docs for the
    /// paper-facing rationale of each constant).
    pub fn of(id: ProviderId) -> PlatformProfile {
        match id {
            ProviderId::Jetstream2 => PlatformProfile {
                id,
                kind: PlatformKind::Cloud,
                pinning: CpuPinning::PhysicalCore,
                api_batch_base_s: 0.050,
                api_per_object_s: 0.0018,
                sched_per_pod_s: 0.004,
                container_start_s: 0.90, // physical-core pinning: fastest baseline
                container_start_cv: 0.10,
                pod_overhead_s: 0.105,
                pod_teardown_s: 0.30,
                contention: 0.050,
                cpu_speed: 2.5, // EPYC-Milan physical cores (Fig 5: 2.5x AWS)
                cores_per_node: 16,
                provision_mean_s: 95.0,
                provision_cv: 0.15,
                queue_wait_mean_s: 0.0,
                queue_wait_cv: 0.0,
                pilot_boot_s: 0.0,
                task_launch_s: 0.0,
            },
            ProviderId::Chameleon => PlatformProfile {
                id,
                kind: PlatformKind::Cloud,
                pinning: CpuPinning::Thread,
                api_batch_base_s: 0.060,
                api_per_object_s: 0.0022,
                sched_per_pod_s: 0.005,
                container_start_s: 1.30, // Haswell vCPUs on threads
                container_start_cv: 0.14,
                pod_overhead_s: 0.150,
                pod_teardown_s: 0.35,
                contention: 0.065, // least optimized hypervisor: worst scaling
                cpu_speed: 0.9,
                cores_per_node: 16,
                provision_mean_s: 120.0,
                provision_cv: 0.20,
                queue_wait_mean_s: 0.0,
                queue_wait_cv: 0.0,
                pilot_boot_s: 0.0,
                task_launch_s: 0.0,
            },
            ProviderId::Aws => PlatformProfile {
                id,
                kind: PlatformKind::Cloud,
                pinning: CpuPinning::Thread,
                api_batch_base_s: 0.045,
                api_per_object_s: 0.0016,
                sched_per_pod_s: 0.004,
                container_start_s: 1.25, // Xeon vCPUs on threads
                container_start_cv: 0.12,
                pod_overhead_s: 0.140,
                pod_teardown_s: 0.32,
                contention: 0.020,
                cpu_speed: 1.0, // the Fig 5 reference point
                cores_per_node: 16,
                provision_mean_s: 180.0, // EKS node groups are slow to come up
                provision_cv: 0.15,
                queue_wait_mean_s: 0.0,
                queue_wait_cv: 0.0,
                pilot_boot_s: 0.0,
                task_launch_s: 0.0,
            },
            ProviderId::Azure => PlatformProfile {
                id,
                kind: PlatformKind::Cloud,
                pinning: CpuPinning::Thread,
                api_batch_base_s: 0.048,
                api_per_object_s: 0.0017,
                sched_per_pod_s: 0.004,
                container_start_s: 1.20,
                container_start_cv: 0.11,
                // AKS hypervisor/containerd optimizations: cheapest sandbox
                // ops of the four clouds — with 16 busy vCPUs the node is
                // kubelet-bound, which is where Azure overtakes Jetstream2
                // in Fig 2 (bottom).
                pod_overhead_s: 0.085,
                pod_teardown_s: 0.31,
                contention: 0.005, // hypervisor optimizations: flattest curve
                cpu_speed: 1.1,
                cores_per_node: 16,
                provision_mean_s: 200.0,
                provision_cv: 0.18,
                queue_wait_mean_s: 0.0,
                queue_wait_cv: 0.0,
                pilot_boot_s: 0.0,
                task_launch_s: 0.0,
            },
            ProviderId::Bridges2 => PlatformProfile {
                id,
                kind: PlatformKind::Hpc,
                pinning: CpuPinning::BareMetal,
                api_batch_base_s: 0.0,
                api_per_object_s: 0.0,
                sched_per_pod_s: 0.0,
                container_start_s: 0.0,
                container_start_cv: 0.0,
                pod_overhead_s: 0.0,
                pod_teardown_s: 0.0,
                contention: 0.0, // bare metal
                cpu_speed: 11.0, // Fig 5: ~10x AWS, ~5x Jetstream2 end-to-end
                cores_per_node: 128,
                provision_mean_s: 0.0,
                provision_cv: 0.0,
                queue_wait_mean_s: 45.0, // "short and consistent" queue times
                queue_wait_cv: 0.15,
                pilot_boot_s: 30.0,
                task_launch_s: 0.01, // pilot executor bulk-spawn rate (~100 tasks/s)
            },
        }
    }

    /// Effective per-container start cost when `busy` of the node's vCPUs
    /// are occupied: the contention model behind Fig 2's strong-scaling
    /// curves.
    pub fn effective_start_s(&self, busy_vcpus: u32) -> f64 {
        self.container_start_s * (1.0 + self.contention * busy_vcpus.saturating_sub(1) as f64)
    }

    /// Virtual duration of a task payload that needs `work_s` seconds on an
    /// AWS-reference core, using `cpus` cores on this platform.
    pub fn payload_duration_s(&self, work_s: f64, cpus: u32) -> f64 {
        let cpus = cpus.max(1) as f64;
        work_s / (self.cpu_speed * cpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_constructible_and_sane() {
        for id in ProviderId::ALL {
            let p = PlatformProfile::of(id);
            assert_eq!(p.id, id);
            assert!(p.cpu_speed > 0.0);
            assert!(p.cores_per_node > 0);
            match p.kind {
                PlatformKind::Cloud => {
                    assert!(p.container_start_s > 0.0);
                    assert!(p.queue_wait_mean_s == 0.0);
                }
                PlatformKind::Hpc => {
                    assert!(p.queue_wait_mean_s > 0.0);
                    assert!(p.pilot_boot_s > 0.0);
                }
            }
        }
    }

    #[test]
    fn jet2_fastest_baseline_at_low_vcpus() {
        // Fig 2 bottom: Jetstream2 beats the other clouds at 4 vCPUs.
        let at4: Vec<(ProviderId, f64)> = ProviderId::CLOUDS
            .iter()
            .map(|&id| (id, PlatformProfile::of(id).effective_start_s(4)))
            .collect();
        let jet2 = at4.iter().find(|(id, _)| *id == ProviderId::Jetstream2).unwrap().1;
        for (id, v) in &at4 {
            if *id != ProviderId::Jetstream2 {
                assert!(jet2 < *v, "JET2 {jet2} !< {id} {v}");
            }
        }
    }

    #[test]
    fn azure_overtakes_jet2_at_16_vcpus() {
        // Fig 2 bottom: Azure "consistently outperforms Jetstream2 with 16 vCPUs".
        let jet2 = PlatformProfile::of(ProviderId::Jetstream2).effective_start_s(16);
        let azure = PlatformProfile::of(ProviderId::Azure).effective_start_s(16);
        assert!(azure < jet2, "azure {azure} !< jet2 {jet2}");
    }

    #[test]
    fn chameleon_scales_worst() {
        // Fig 2 bottom: Chameleon shows the worst scaling.
        for id in [ProviderId::Jetstream2, ProviderId::Aws, ProviderId::Azure] {
            let chi = PlatformProfile::of(ProviderId::Chameleon);
            let other = PlatformProfile::of(id);
            let chi_growth = chi.effective_start_s(16) / chi.effective_start_s(1);
            let o_growth = other.effective_start_s(16) / other.effective_start_s(1);
            assert!(chi_growth > o_growth, "{id}");
        }
    }

    #[test]
    fn fig5_speed_ratios() {
        let aws = PlatformProfile::of(ProviderId::Aws).cpu_speed;
        let jet2 = PlatformProfile::of(ProviderId::Jetstream2).cpu_speed;
        let b2 = PlatformProfile::of(ProviderId::Bridges2).cpu_speed;
        assert!((jet2 / aws - 2.5).abs() < 0.1, "JET2 ~ 2.5x AWS");
        assert!(b2 / aws >= 8.0 && b2 / aws <= 12.5, "B2 ~ 10x AWS");
        assert!(b2 / jet2 >= 3.5 && b2 / jet2 <= 5.5, "B2 ~ 5x JET2 (incl. node effects)");
    }

    #[test]
    fn payload_duration_scales_with_cores_and_speed() {
        let b2 = PlatformProfile::of(ProviderId::Bridges2);
        let aws = PlatformProfile::of(ProviderId::Aws);
        assert!(b2.payload_duration_s(100.0, 1) < aws.payload_duration_s(100.0, 1));
        assert!((aws.payload_duration_s(100.0, 4) - 25.0).abs() < 1e-9);
        // zero cpus clamps to 1
        assert!((aws.payload_duration_s(10.0, 0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn provider_parse_roundtrip() {
        for id in ProviderId::ALL {
            assert_eq!(ProviderId::parse(id.short_name()), Some(id));
            assert_eq!(ProviderId::parse(&id.short_name().to_lowercase()), Some(id));
        }
        assert_eq!(ProviderId::parse("jetstream2"), Some(ProviderId::Jetstream2));
        assert!(ProviderId::parse("gcp").is_none());
    }
}
