//! Shared free-capacity index: a segment tree over per-slot free
//! resources.
//!
//! Extracted from `sim::kubernetes` (where it indexed per-*node* free
//! capacity, PR 1) and generalized so the HPC multi-pilot scheduler can
//! index per-*pilot* free cores through the same structure (ISSUE 5
//! tentpole). A "slot" is whatever the owning simulator places work on —
//! a Kubernetes node or a live pilot job.
//!
//! Each leaf holds one slot's free [`Cap`] (cpus, gpus, mem); every
//! internal vertex stores the *per-dimension maxima* of its subtree, so a
//! subtree whose maxima cannot satisfy a demand is pruned wholesale.
//! Operations:
//!
//! * [`reserve`](CapacityIndex::reserve) / [`release`](CapacityIndex::release)
//!   / [`set`](CapacityIndex::set) — update one leaf and recompute maxima
//!   along the root path: **O(log N)** exact. `set` is also how owners
//!   mask a slot outright — the fault-tolerant pilot fleet (ISSUE 6)
//!   zeroes a dead pilot's leaf with `set(p, Cap::ZERO)` so no
//!   placement query can ever land on it again.
//! * [`first_fit`](CapacityIndex::first_fit) — in-order descent pruned by
//!   subtree maxima; returns the lowest-indexed slot satisfying all three
//!   constraints, i.e. the *same slot a linear scan would pick*
//!   (determinism preserved by construction, enforced by the churn test
//!   below and the kubernetes equivalence suites). **O(log N)** expected;
//!   the adversarial worst case where a subtree's per-dimension maxima
//!   come from different leaves degrades toward O(N) — never worse than
//!   the scan it replaces.
//! * [`best_fit`](CapacityIndex::best_fit) — the fitting slot with the
//!   *fewest* free cpus (ties: lowest index), found by a maxima-pruned
//!   search with a perfect-fit early exit. Multi-pilot placement uses
//!   this to pack tasks onto the fullest pilot that still fits, keeping
//!   wide pilots free for wide tasks. **O(log N)** expected for the
//!   mostly-uniform slot populations the simulators produce; worst case
//!   O(N), same caveat as `first_fit`.
//!
//! The seed's linear scans ([`first_fit_linear`](CapacityIndex::first_fit_linear),
//! [`best_fit_linear`](CapacityIndex::best_fit_linear)) are kept as the
//! reference implementations the unit tests check the tree against.

/// Free capacity of one slot: the three resource dimensions the
/// simulators schedule on. One-dimensional users (the pilot index: free
/// cores only) build leaves with [`Cap::cores`], leaving gpus/mem zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cap {
    pub cpus: u32,
    pub gpus: u32,
    pub mem: u64,
}

impl Cap {
    /// The all-zero capacity (an empty or masked slot).
    pub const ZERO: Cap = Cap { cpus: 0, gpus: 0, mem: 0 };

    pub fn new(cpus: u32, gpus: u32, mem: u64) -> Cap {
        Cap { cpus, gpus, mem }
    }

    /// A one-dimensional capacity: `cpus` cores, no gpus, no memory.
    pub fn cores(cpus: u32) -> Cap {
        Cap { cpus, gpus: 0, mem: 0 }
    }

    /// Whether this free capacity satisfies `need` in every dimension.
    pub fn fits(self, need: Cap) -> bool {
        self.cpus >= need.cpus && self.gpus >= need.gpus && self.mem >= need.mem
    }
}

/// Segment tree over per-slot free capacities (see module docs).
#[derive(Debug, Clone)]
pub struct CapacityIndex {
    /// Number of real slots (leaves beyond `n` are zero-capacity padding).
    n: usize,
    /// Leaf capacity: smallest power of two >= max(n, 1). The tree arrays
    /// have length `2 * size`; leaf i lives at `size + i`.
    size: usize,
    cpus: Vec<u32>,
    gpus: Vec<u32>,
    mem: Vec<u64>,
}

impl CapacityIndex {
    /// An index of `n` slots, every leaf starting at `leaf` free capacity.
    pub fn uniform(n: usize, leaf: Cap) -> CapacityIndex {
        let size = n.max(1).next_power_of_two();
        let mut idx = CapacityIndex {
            n,
            size,
            cpus: vec![0; 2 * size],
            gpus: vec![0; 2 * size],
            mem: vec![0; 2 * size],
        };
        for i in 0..n {
            idx.cpus[size + i] = leaf.cpus;
            idx.gpus[size + i] = leaf.gpus;
            idx.mem[size + i] = leaf.mem;
        }
        for i in (1..size).rev() {
            idx.pull(i);
        }
        idx
    }

    /// An index of `n` slots starting empty — the multi-pilot scheduler
    /// opens a slot (via [`set`](CapacityIndex::set)) only once its pilot
    /// agent is live.
    pub fn zeroed(n: usize) -> CapacityIndex {
        CapacityIndex::uniform(n, Cap::ZERO)
    }

    /// Number of real slots.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Recompute vertex `i`'s maxima from its two children.
    fn pull(&mut self, i: usize) {
        self.cpus[i] = self.cpus[2 * i].max(self.cpus[2 * i + 1]);
        self.gpus[i] = self.gpus[2 * i].max(self.gpus[2 * i + 1]);
        self.mem[i] = self.mem[2 * i].max(self.mem[2 * i + 1]);
    }

    /// Update the root path above leaf `slot`: O(log N).
    fn bubble_up(&mut self, slot: usize) {
        let mut i = (self.size + slot) / 2;
        while i >= 1 {
            self.pull(i);
            if i == 1 {
                break;
            }
            i /= 2;
        }
    }

    /// Subtract `take` from slot's free capacity (placement).
    pub fn reserve(&mut self, slot: usize, take: Cap) {
        let leaf = self.size + slot;
        self.cpus[leaf] -= take.cpus;
        self.gpus[leaf] -= take.gpus;
        self.mem[leaf] -= take.mem;
        self.bubble_up(slot);
    }

    /// Return `give` to slot's free capacity (teardown).
    pub fn release(&mut self, slot: usize, give: Cap) {
        let leaf = self.size + slot;
        self.cpus[leaf] += give.cpus;
        self.gpus[leaf] += give.gpus;
        self.mem[leaf] += give.mem;
        self.bubble_up(slot);
    }

    /// Point-assign slot's free capacity (a pilot going live, or a slot
    /// masked to [`Cap::ZERO`] while its launcher is busy).
    pub fn set(&mut self, slot: usize, free: Cap) {
        let leaf = self.size + slot;
        self.cpus[leaf] = free.cpus;
        self.gpus[leaf] = free.gpus;
        self.mem[leaf] = free.mem;
        self.bubble_up(slot);
    }

    /// Lowest-indexed slot satisfying all three demands, via pruned
    /// in-order descent. Exact first-fit: a leaf's "maxima" are its actual
    /// free capacities, so the leaf test is precise and internal vertices
    /// only prune.
    pub fn first_fit(&self, need: Cap) -> Option<u32> {
        if self.n == 0 {
            return None;
        }
        self.search(1, need)
    }

    fn search(&self, i: usize, need: Cap) -> Option<u32> {
        if self.cpus[i] < need.cpus || self.gpus[i] < need.gpus || self.mem[i] < need.mem {
            return None;
        }
        if i >= self.size {
            let slot = i - self.size;
            return if slot < self.n { Some(slot as u32) } else { None };
        }
        self.search(2 * i, need).or_else(|| self.search(2 * i + 1, need))
    }

    /// Reference first-fit: scan every leaf in order (the seed behavior).
    pub fn first_fit_linear(&self, need: Cap) -> Option<u32> {
        (0..self.n).find(|&i| self.free_of(i).fits(need)).map(|i| i as u32)
    }

    /// The fitting slot with the fewest free cpus (best fit on the cpu
    /// dimension; ties break toward the lowest index). Prunes subtrees
    /// whose maxima cannot fit `need` and exits early on a perfect fit
    /// (`free cpus == need.cpus`). See module docs for the cost bounds.
    pub fn best_fit(&self, need: Cap) -> Option<u32> {
        if self.n == 0 {
            return None;
        }
        let mut best: Option<(u32, u32)> = None; // (free cpus, slot)
        self.best_search(1, need, &mut best);
        best.map(|(_, slot)| slot)
    }

    fn best_search(&self, i: usize, need: Cap, best: &mut Option<(u32, u32)>) {
        if self.cpus[i] < need.cpus || self.gpus[i] < need.gpus || self.mem[i] < need.mem {
            return;
        }
        if let Some((free, _)) = *best {
            if free == need.cpus {
                return; // perfect fit already found; nothing can beat it
            }
        }
        if i >= self.size {
            let slot = i - self.size;
            if slot >= self.n {
                return; // zero-capacity padding leaf
            }
            let free = self.cpus[i];
            let better = match *best {
                None => true,
                // Strict `<` + left-first descent keeps ties on the
                // lowest slot index (deterministic placement).
                Some((best_free, _)) => free < best_free,
            };
            if better {
                *best = Some((free, slot as u32));
            }
            return;
        }
        self.best_search(2 * i, need, best);
        self.best_search(2 * i + 1, need, best);
    }

    /// Reference best-fit: scan every leaf (the test oracle for
    /// [`best_fit`](CapacityIndex::best_fit)).
    pub fn best_fit_linear(&self, need: Cap) -> Option<u32> {
        let mut best: Option<(u32, u32)> = None;
        for i in 0..self.n {
            let free = self.free_of(i);
            if !free.fits(need) {
                continue;
            }
            let better = match best {
                None => true,
                Some((best_free, _)) => free.cpus < best_free,
            };
            if better {
                best = Some((free.cpus, i as u32));
            }
        }
        best.map(|(_, slot)| slot)
    }

    /// Current free capacity of one slot.
    pub fn free_of(&self, slot: usize) -> Cap {
        let leaf = self.size + slot;
        Cap { cpus: self.cpus[leaf], gpus: self.gpus[leaf], mem: self.mem[leaf] }
    }

    /// Total free capacity across all slots (invariant surface for the
    /// teardown-frees-capacity tests).
    pub fn total_free(&self) -> Cap {
        let mut total = Cap::ZERO;
        for i in 0..self.n {
            let f = self.free_of(i);
            total.cpus += f.cpus;
            total.gpus += f.gpus;
            total.mem += f.mem;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn first_fit_agrees_with_scan_under_churn() {
        // Ported from the inline segment-tree coverage in sim::kubernetes
        // (ISSUE 5 satellite): the tree against the reference scan across
        // a randomized reserve/release workload.
        let mut idx = CapacityIndex::uniform(13, Cap::new(16, 2, 4096));
        let mut rng = Prng::new(99);
        let mut held: Vec<(usize, Cap)> = Vec::new();
        for step in 0..2000 {
            let need = Cap::new(
                rng.range_u64(1, 16) as u32,
                if step % 5 == 0 { rng.range_u64(0, 2) as u32 } else { 0 },
                rng.range_u64(64, 4096),
            );
            assert_eq!(
                idx.first_fit(need),
                idx.first_fit_linear(need),
                "divergence at step {step}"
            );
            if let Some(n) = idx.first_fit(need) {
                idx.reserve(n as usize, need);
                held.push((n as usize, need));
            }
            if held.len() > 8 {
                let (n, cap) = held.remove(0);
                idx.release(n, cap);
            }
        }
        for (n, cap) in held {
            idx.release(n, cap);
        }
        assert_eq!(idx.total_free(), Cap::new(13 * 16, 13 * 2, 13 * 4096));
    }

    #[test]
    fn best_fit_agrees_with_scan_under_churn() {
        // Same churn shape, one-dimensional leaves (the pilot index use
        // case): the pruned best-fit must match the linear oracle exactly,
        // including tie-breaks.
        let mut idx = CapacityIndex::zeroed(9);
        let mut rng = Prng::new(7);
        // Open slots at heterogeneous widths, as staged pilots would.
        for i in 0..9 {
            idx.set(i, Cap::cores(64 * (1 + (i as u32 % 3))));
        }
        let mut held: Vec<(usize, Cap)> = Vec::new();
        for step in 0..3000 {
            let need = Cap::cores(rng.range_u64(1, 128) as u32);
            assert_eq!(
                idx.best_fit(need),
                idx.best_fit_linear(need),
                "divergence at step {step}"
            );
            if let Some(n) = idx.best_fit(need) {
                idx.reserve(n as usize, need);
                held.push((n as usize, need));
            }
            if held.len() > 6 {
                let (n, cap) = held.remove(0);
                idx.release(n, cap);
            }
        }
        for (n, cap) in held {
            idx.release(n, cap);
        }
        assert_eq!(idx.total_free().cpus, (0..9u32).map(|i| 64 * (1 + i % 3)).sum());
    }

    #[test]
    fn best_fit_packs_fullest_slot_and_breaks_ties_low() {
        let mut idx = CapacityIndex::uniform(4, Cap::cores(32));
        idx.reserve(1, Cap::cores(20)); // slot 1: 12 free
        idx.reserve(3, Cap::cores(24)); // slot 3: 8 free
        assert_eq!(idx.best_fit(Cap::cores(8)), Some(3), "fewest free cpus wins");
        assert_eq!(idx.best_fit(Cap::cores(10)), Some(1));
        assert_eq!(idx.best_fit(Cap::cores(16)), Some(0), "tie between 0 and 2 breaks low");
        assert_eq!(idx.best_fit(Cap::cores(33)), None);
        // first_fit would have picked slot 0 for all of these.
        assert_eq!(idx.first_fit(Cap::cores(8)), Some(0));
    }

    #[test]
    fn set_masks_and_reopens_slots() {
        let mut idx = CapacityIndex::zeroed(3);
        assert_eq!(idx.best_fit(Cap::cores(1)), None, "no slot is live yet");
        idx.set(1, Cap::cores(128));
        assert_eq!(idx.best_fit(Cap::cores(1)), Some(1));
        assert_eq!(idx.first_fit(Cap::cores(1)), Some(1));
        idx.set(1, Cap::ZERO); // masked (launcher busy)
        assert_eq!(idx.best_fit(Cap::cores(1)), None);
        idx.set(1, Cap::cores(100));
        assert_eq!(idx.free_of(1), Cap::cores(100));
        assert_eq!(idx.total_free(), Cap::cores(100));
    }

    #[test]
    fn empty_and_padding_leaves_never_match() {
        let idx = CapacityIndex::uniform(0, Cap::cores(16));
        assert!(idx.is_empty());
        assert_eq!(idx.first_fit(Cap::ZERO), None);
        assert_eq!(idx.best_fit(Cap::ZERO), None);
        // 5 slots pad to 8 leaves; a zero demand must still resolve to a
        // real slot, never a padding leaf.
        let idx = CapacityIndex::uniform(5, Cap::ZERO);
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.first_fit(Cap::ZERO), Some(0));
        assert_eq!(idx.best_fit(Cap::ZERO), Some(0));
        assert_eq!(idx.best_fit(Cap::cores(1)), None);
    }

    #[test]
    fn multi_dimension_constraints_all_enforced() {
        let mut idx = CapacityIndex::uniform(4, Cap::new(16, 2, 4096));
        idx.reserve(0, Cap::new(0, 2, 0)); // gpus exhausted on slot 0
        idx.reserve(1, Cap::new(0, 0, 4000)); // mem nearly exhausted on slot 1
        assert_eq!(idx.first_fit(Cap::new(1, 1, 64)), Some(2));
        assert_eq!(idx.first_fit(Cap::new(1, 0, 128)), Some(0));
        assert_eq!(idx.best_fit(Cap::new(1, 0, 128)), Some(0), "ties on cpus break low");
        idx.release(0, Cap::new(0, 2, 0));
        assert_eq!(idx.first_fit(Cap::new(1, 1, 64)), Some(0));
    }
}
