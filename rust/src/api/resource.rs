//! Resource API: what the user asks each provider for.
//!
//! Mirrors the paper's `Resource` class (§3.2): per-provider methods let
//! users pick the service type (container service vs batch system), the
//! amount of resources, and service-specific properties.

use crate::sim::hpc::PilotSpec;
use crate::sim::kubernetes::ClusterSpec;
use crate::sim::provider::{PlatformKind, PlatformProfile, ProviderId};

pub use crate::broker::data::{ProviderFaultSpec, RetryPolicy};
pub use crate::sim::hpc::FaultSpec;

/// The service level the resources are acquired through.
///
/// `#[non_exhaustive]`: the manager layer is an open interface (see
/// `broker::manager`) — the next service kind lands as a new variant plus
/// one `ManagerFactory::create` arm, without breaking downstream code.
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceKind {
    /// Container-as-a-Service: a (multi-node) Kubernetes cluster
    /// (EKS / AKS / custom image on the NSF clouds).
    Caas,
    /// HPC batch system driven through a pilot (RADICAL-Pilot connector).
    Batch,
    /// Function-as-a-Service: a Lambda/Cloud-Functions-style service with
    /// an account-level concurrency limit (the paper's §3.1 extensibility
    /// example, wired end to end).
    Faas,
}

/// A resource request against one provider.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceRequest {
    pub provider: ProviderId,
    pub service: ServiceKind,
    pub nodes: u32,
    /// vCPUs per node (CaaS). For Batch requests the platform's
    /// `cores_per_node` is authoritative (Bridges2 hands out whole nodes).
    pub vcpus_per_node: u32,
    pub gpus_per_node: u32,
    pub mem_mb_per_node: u64,
    /// Maximum concurrent function executions (FaaS only; 0 elsewhere).
    pub concurrency: u32,
    /// Number of concurrent pilot jobs to stage (Batch only, validated
    /// >= 1; always 1 for other services). The HPC Manager schedules the
    /// workload across all of them on the shared capacity index, so
    /// `nodes` is the size of *each* pilot, not of the fleet.
    pub pilots: u32,
    /// Per-pilot node widths for a heterogeneous fleet (Batch only).
    /// Empty: every pilot gets `nodes` nodes. Non-empty: must have one
    /// entry per pilot, each >= 1 (see [`ResourceRequest::with_pilot_nodes`]).
    pub pilot_nodes: Vec<u32>,
    /// Pilot-level fault model (Batch only; must stay
    /// [`FaultSpec::none`] elsewhere). Validated by
    /// [`FaultSpec::validate`].
    pub fault: FaultSpec,
    /// Per-task failure-injection probability in [0, 1] (the knob the
    /// CaaS manager already had, now uniform across services).
    pub task_failure_rate: f64,
    /// Provider control-plane fault model (any service kind): outage
    /// window, transient submit errors, byte throttling. Validated by
    /// [`ProviderFaultSpec::validate`]; `none()` consumes no PRNG state.
    pub provider_fault: ProviderFaultSpec,
    /// Retry/backoff policy for fallible provider submits. The default
    /// policy with a `none()` fault spec is a strict no-op.
    pub retry: RetryPolicy,
}

impl ResourceRequest {
    /// A Kubernetes cluster on a cloud provider.
    pub fn kubernetes(provider: ProviderId, nodes: u32, vcpus_per_node: u32) -> ResourceRequest {
        ResourceRequest {
            provider,
            service: ServiceKind::Caas,
            nodes,
            vcpus_per_node,
            gpus_per_node: 0,
            mem_mb_per_node: 4096 * vcpus_per_node as u64,
            concurrency: 0,
            pilots: 1,
            pilot_nodes: Vec::new(),
            fault: FaultSpec::none(),
            task_failure_rate: 0.0,
            provider_fault: ProviderFaultSpec::none(),
            retry: RetryPolicy::default(),
        }
    }

    /// A pilot on an HPC platform (whole nodes).
    pub fn pilot(provider: ProviderId, nodes: u32) -> ResourceRequest {
        ResourceRequest::hpc(provider, nodes, 1)
    }

    /// `pilots` concurrent pilot jobs of `nodes` whole nodes each on an
    /// HPC platform — the paper's strong/weak-scaling shape (§5.3–5.4);
    /// `pilots == 1` is [`ResourceRequest::pilot`].
    pub fn hpc(provider: ProviderId, nodes: u32, pilots: u32) -> ResourceRequest {
        let profile = PlatformProfile::of(provider);
        ResourceRequest {
            provider,
            service: ServiceKind::Batch,
            nodes,
            vcpus_per_node: profile.cores_per_node,
            gpus_per_node: 0,
            mem_mb_per_node: 2048 * profile.cores_per_node as u64,
            concurrency: 0,
            pilots,
            pilot_nodes: Vec::new(),
            fault: FaultSpec::none(),
            task_failure_rate: 0.0,
            provider_fault: ProviderFaultSpec::none(),
            retry: RetryPolicy::default(),
        }
    }

    /// A function service on a cloud provider: the service manages the
    /// instances, the user picks only the concurrency limit (account-level
    /// concurrent executions).
    pub fn faas(provider: ProviderId, concurrency: u32) -> ResourceRequest {
        ResourceRequest {
            provider,
            service: ServiceKind::Faas,
            // The service owns the nodes; one logical "node" keeps the
            // generic `nodes >= 1` invariant satisfied.
            nodes: 1,
            vcpus_per_node: 1,
            gpus_per_node: 0,
            mem_mb_per_node: 2048,
            concurrency,
            pilots: 1,
            pilot_nodes: Vec::new(),
            fault: FaultSpec::none(),
            task_failure_rate: 0.0,
            provider_fault: ProviderFaultSpec::none(),
            retry: RetryPolicy::default(),
        }
    }

    pub fn with_gpus_per_node(mut self, gpus: u32) -> Self {
        self.gpus_per_node = gpus;
        self
    }

    /// Stage `pilots` concurrent pilot jobs (Batch requests; validated
    /// >= 1, and rejected on other service kinds unless it stays 1).
    pub fn with_pilots(mut self, pilots: u32) -> Self {
        self.pilots = pilots;
        self
    }

    pub fn with_mem_mb_per_node(mut self, mem: u64) -> Self {
        self.mem_mb_per_node = mem;
        self
    }

    /// Heterogeneous fleet: one pilot per entry, each `widths[i]` whole
    /// nodes (Batch requests). Overrides the uniform `nodes × pilots`
    /// shape — `pilots` is set to the fleet size and `nodes` to the
    /// widest pilot so the uniform accessors stay meaningful.
    pub fn with_pilot_nodes(mut self, widths: &[u32]) -> Self {
        self.pilot_nodes = widths.to_vec();
        self.pilots = widths.len() as u32;
        self.nodes = widths.iter().copied().max().unwrap_or(self.nodes);
        self
    }

    /// Pilot-level fault model (Batch requests; see [`FaultSpec`]).
    pub fn with_faults(mut self, fault: FaultSpec) -> Self {
        self.fault = fault;
        self
    }

    /// Per-task failure-injection probability in [0, 1].
    pub fn with_task_failure_rate(mut self, p: f64) -> Self {
        self.task_failure_rate = p;
        self
    }

    /// Provider control-plane fault model (see [`ProviderFaultSpec`]).
    pub fn with_provider_faults(mut self, fault: ProviderFaultSpec) -> Self {
        self.provider_fault = fault;
        self
    }

    /// Retry/backoff policy for fallible provider submits.
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// The pilot fleet this request stages: one [`PilotSpec`] per pilot,
    /// heterogeneous when `pilot_nodes` is set, else `pilots` uniform
    /// pilots of `nodes` nodes each.
    pub fn pilot_fleet(&self) -> Vec<PilotSpec> {
        if self.pilot_nodes.is_empty() {
            vec![PilotSpec { nodes: self.nodes }; self.pilots as usize]
        } else {
            self.pilot_nodes.iter().map(|&nodes| PilotSpec { nodes }).collect()
        }
    }

    pub fn total_vcpus(&self) -> u32 {
        self.nodes * self.vcpus_per_node
    }

    /// Validate the request against the provider's platform kind and
    /// simulated allocation limits.
    pub fn validate(&self) -> Result<(), String> {
        let profile = PlatformProfile::of(self.provider);
        if self.nodes == 0 {
            return Err(format!("{}: nodes must be >= 1", self.provider));
        }
        match (self.service, profile.kind) {
            (ServiceKind::Caas, PlatformKind::Hpc) => {
                return Err(format!("{}: CaaS service is not offered on HPC", self.provider));
            }
            (ServiceKind::Batch, PlatformKind::Cloud) => {
                return Err(format!("{}: batch service is not offered on clouds", self.provider));
            }
            (ServiceKind::Faas, PlatformKind::Hpc) => {
                return Err(format!("{}: FaaS service is not offered on HPC", self.provider));
            }
            _ => {}
        }
        if self.service == ServiceKind::Caas {
            if self.vcpus_per_node == 0 {
                return Err(format!("{}: vcpus_per_node must be >= 1", self.provider));
            }
            if self.vcpus_per_node > profile.cores_per_node {
                return Err(format!(
                    "{}: largest VM offers {} vCPUs (requested {})",
                    self.provider, profile.cores_per_node, self.vcpus_per_node
                ));
            }
        }
        if self.service == ServiceKind::Faas && self.concurrency == 0 {
            return Err(format!("{}: FaaS concurrency must be >= 1", self.provider));
        }
        if self.service == ServiceKind::Batch {
            if self.pilots == 0 {
                return Err(format!("{}: pilots must be >= 1", self.provider));
            }
            if !self.pilot_nodes.is_empty() {
                if self.pilot_nodes.len() != self.pilots as usize {
                    return Err(format!(
                        "{}: pilot_nodes has {} entries for {} pilots",
                        self.provider,
                        self.pilot_nodes.len(),
                        self.pilots
                    ));
                }
                if self.pilot_nodes.iter().any(|&w| w == 0) {
                    return Err(format!(
                        "{}: every pilot_nodes width must be >= 1",
                        self.provider
                    ));
                }
            }
            self.fault
                .validate()
                .map_err(|e| format!("{}: invalid fault spec: {e}", self.provider))?;
        } else {
            if self.pilots != 1 {
                return Err(format!("{}: pilots apply to batch resources only", self.provider));
            }
            if !self.pilot_nodes.is_empty() {
                return Err(format!(
                    "{}: pilot_nodes applies to batch resources only",
                    self.provider
                ));
            }
            if !self.fault.is_none() {
                return Err(format!(
                    "{}: pilot fault model applies to batch resources only",
                    self.provider
                ));
            }
        }
        if !(0.0..=1.0).contains(&self.task_failure_rate) {
            return Err(format!(
                "{}: task_failure_rate must be in [0, 1], got {}",
                self.provider, self.task_failure_rate
            ));
        }
        self.provider_fault
            .validate()
            .map_err(|e| format!("{}: invalid provider fault spec: {e}", self.provider))?;
        self.retry
            .validate()
            .map_err(|e| format!("{}: invalid retry policy: {e}", self.provider))?;
        Ok(())
    }

    /// The simulated cluster this request materializes as.
    pub fn cluster_spec(&self) -> ClusterSpec {
        ClusterSpec {
            nodes: self.nodes,
            vcpus_per_node: self.vcpus_per_node,
            gpus_per_node: self.gpus_per_node,
            mem_mb_per_node: self.mem_mb_per_node,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kubernetes_request_defaults() {
        let r = ResourceRequest::kubernetes(ProviderId::Aws, 2, 16);
        assert_eq!(r.service, ServiceKind::Caas);
        assert_eq!(r.total_vcpus(), 32);
        assert_eq!(r.mem_mb_per_node, 64 * 1024);
        assert!(r.validate().is_ok());
    }

    #[test]
    fn pilot_uses_whole_nodes() {
        let r = ResourceRequest::pilot(ProviderId::Bridges2, 2);
        assert_eq!(r.service, ServiceKind::Batch);
        assert_eq!(r.vcpus_per_node, 128);
        assert_eq!(r.total_vcpus(), 256);
        assert_eq!(r.pilots, 1, "single pilot by default");
        assert!(r.validate().is_ok());
    }

    #[test]
    fn multi_pilot_requests_validate() {
        let r = ResourceRequest::hpc(ProviderId::Bridges2, 2, 4);
        assert_eq!(r.pilots, 4);
        assert_eq!(r.nodes, 2, "nodes are per pilot");
        assert!(r.validate().is_ok());
        assert_eq!(ResourceRequest::pilot(ProviderId::Bridges2, 2).with_pilots(4), r);
        // pilots = 0 rejected; pilots on non-batch services rejected.
        assert!(ResourceRequest::hpc(ProviderId::Bridges2, 1, 0).validate().is_err());
        let k = ResourceRequest::kubernetes(ProviderId::Aws, 1, 8).with_pilots(2);
        assert!(k.validate().is_err());
        let f = ResourceRequest::faas(ProviderId::Aws, 16).with_pilots(3);
        assert!(f.validate().is_err());
    }

    #[test]
    fn heterogeneous_pilot_nodes_validate_and_build_the_fleet() {
        let r = ResourceRequest::pilot(ProviderId::Bridges2, 1).with_pilot_nodes(&[2, 4, 8]);
        assert_eq!(r.pilots, 3);
        assert_eq!(r.nodes, 8, "uniform accessors track the widest pilot");
        assert!(r.validate().is_ok());
        let fleet = r.pilot_fleet();
        assert_eq!(fleet.iter().map(|p| p.nodes).collect::<Vec<_>>(), vec![2, 4, 8]);

        // The uniform shape is unchanged.
        let u = ResourceRequest::hpc(ProviderId::Bridges2, 2, 4);
        assert_eq!(u.pilot_fleet().len(), 4);
        assert!(u.pilot_fleet().iter().all(|p| p.nodes == 2));

        // Mismatched length, zero widths, and non-batch use are rejected.
        let mut bad = ResourceRequest::pilot(ProviderId::Bridges2, 1).with_pilot_nodes(&[2, 4]);
        bad.pilots = 3;
        assert!(bad.validate().is_err());
        assert!(ResourceRequest::pilot(ProviderId::Bridges2, 1)
            .with_pilot_nodes(&[2, 0])
            .validate()
            .is_err());
        let mut k = ResourceRequest::kubernetes(ProviderId::Aws, 1, 8);
        k.pilot_nodes = vec![1];
        assert!(k.validate().is_err());
    }

    #[test]
    fn fault_spec_and_failure_rate_ranges_validated() {
        let ok = ResourceRequest::hpc(ProviderId::Bridges2, 1, 2).with_faults(FaultSpec {
            walltime_s: 3600.0,
            mtbf_s: 900.0,
            materialization_failure_p: 0.05,
            retry_budget: 2,
            injected_kill: None,
        });
        assert!(ok.validate().is_ok());

        let mut bad = ok.clone();
        bad.fault.materialization_failure_p = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.fault.walltime_s = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.fault.mtbf_s = f64::NAN;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.fault.injected_kill = Some((0, f64::INFINITY));
        assert!(bad.validate().is_err());

        // Fault models are batch-only.
        let k = ResourceRequest::kubernetes(ProviderId::Aws, 1, 8)
            .with_faults(FaultSpec { mtbf_s: 100.0, ..FaultSpec::none() });
        assert!(k.validate().is_err());

        // task_failure_rate is range-checked on every service.
        assert!(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8)
            .with_task_failure_rate(0.2)
            .validate()
            .is_ok());
        assert!(ResourceRequest::hpc(ProviderId::Bridges2, 1, 1)
            .with_task_failure_rate(1.2)
            .validate()
            .is_err());
        assert!(ResourceRequest::hpc(ProviderId::Bridges2, 1, 1)
            .with_task_failure_rate(-0.1)
            .validate()
            .is_err());
    }

    #[test]
    fn provider_fault_and_retry_ranges_validated_on_every_service() {
        // The provider control plane is service-agnostic: faults and
        // retry policy are accepted on CaaS, Batch, and FaaS alike.
        let fault = ProviderFaultSpec {
            outage_window: Some((10.0, 20.0)),
            transient_error_p: 0.1,
            throttle_after_bytes: 1 << 20,
        };
        for ok in [
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 8),
            ResourceRequest::pilot(ProviderId::Bridges2, 1),
            ResourceRequest::faas(ProviderId::Aws, 16),
        ] {
            assert!(ok.clone().with_provider_faults(fault).validate().is_ok(), "{:?}", ok);
        }

        let bad = ResourceRequest::kubernetes(ProviderId::Aws, 1, 8).with_provider_faults(
            ProviderFaultSpec { transient_error_p: 2.0, ..ProviderFaultSpec::none() },
        );
        assert!(bad.validate().is_err());
        let bad = ResourceRequest::faas(ProviderId::Aws, 16).with_provider_faults(
            ProviderFaultSpec { outage_window: Some((9.0, 3.0)), ..ProviderFaultSpec::none() },
        );
        assert!(bad.validate().is_err());
        let bad = ResourceRequest::pilot(ProviderId::Bridges2, 1)
            .with_retry_policy(RetryPolicy { max_attempts: 0, ..RetryPolicy::default() });
        assert!(bad.validate().is_err());
        let bad = ResourceRequest::kubernetes(ProviderId::Aws, 1, 8)
            .with_retry_policy(RetryPolicy { jitter: 1.5, ..RetryPolicy::default() });
        assert!(bad.validate().is_err());
    }

    #[test]
    fn faas_request_validates_clouds_and_concurrency() {
        let r = ResourceRequest::faas(ProviderId::Aws, 64);
        assert_eq!(r.service, ServiceKind::Faas);
        assert_eq!(r.concurrency, 64);
        assert!(r.validate().is_ok());
        assert!(ResourceRequest::faas(ProviderId::Bridges2, 64).validate().is_err());
        assert!(ResourceRequest::faas(ProviderId::Aws, 0).validate().is_err());
    }

    #[test]
    fn service_platform_mismatches_rejected() {
        assert!(ResourceRequest::kubernetes(ProviderId::Bridges2, 1, 16).validate().is_err());
        let mut r = ResourceRequest::pilot(ProviderId::Bridges2, 1);
        r.provider = ProviderId::Aws;
        assert!(r.validate().is_err());
    }

    #[test]
    fn vm_size_limits_enforced() {
        // Paper §5.2: "the largest VM on Jetstream2 and Chameleon have 16 vCPUs".
        assert!(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 17).validate().is_err());
        assert!(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16).validate().is_ok());
        assert!(ResourceRequest::kubernetes(ProviderId::Aws, 1, 0).validate().is_err());
        let mut r = ResourceRequest::kubernetes(ProviderId::Aws, 1, 4);
        r.nodes = 0;
        assert!(r.validate().is_err());
    }

    #[test]
    fn cluster_spec_mirrors_request() {
        let r = ResourceRequest::kubernetes(ProviderId::Azure, 3, 8).with_gpus_per_node(2);
        let c = r.cluster_spec();
        assert_eq!(c.nodes, 3);
        assert_eq!(c.vcpus_per_node, 8);
        assert_eq!(c.gpus_per_node, 2);
    }
}
