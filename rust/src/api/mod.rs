//! Hydra's public API surface — the four classes of the paper's §3.2:
//! [`provider::ProviderConfig`] (Provider), the service proxy in
//! `broker::service_proxy` (Service), [`resource::ResourceRequest`]
//! (Resource), and [`task::TaskDescription`] (Task).

pub mod provider;
pub mod resource;
pub mod task;

pub use provider::{Credentials, ProviderConfig};
pub use resource::{ResourceRequest, ServiceKind};
pub use task::{Payload, TaskDescription, TaskId, TaskKind, TaskState};
