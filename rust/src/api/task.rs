//! Task API: descriptions, states, and the task lifecycle.
//!
//! Mirrors the paper's `Task` class (§3.2): a task maps to a regular
//! executable, a cloud pod, or a container; it carries resource
//! requirements (CPU/GPU units, memory), an optional explicit provider
//! binding, and holds its current/final state plus tracing events.

use crate::sim::provider::ProviderId;
use std::fmt;

/// Stable task identifier issued by the broker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "task.{:06}", self.0)
    }
}

/// How the task is realized on the platform (paper: "executables or
/// containers", chosen by brokering policy — plus serverless functions
/// through the open manager interface).
///
/// `#[non_exhaustive]`: task kinds grow with the manager layer (see
/// `broker::manager`); downstream matches need a wildcard arm.
#[non_exhaustive]
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// Plain executable (HPC path; Experiment 3B's `sleep`, FACTS steps).
    Executable { command: String },
    /// Container image (CaaS path; Experiments 1–3 `noop` containers).
    Container { image: String },
    /// Serverless function (FaaS path): a named handler invoked once per
    /// task, e.g. `pkg.module:handler`.
    Function { handler: String },
}

impl TaskKind {
    pub fn is_container(&self) -> bool {
        matches!(self, TaskKind::Container { .. })
    }
}

/// What the task actually does when it runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Zero-duration task (Experiments 1, 2, 3A): isolates broker and
    /// platform overheads.
    Noop,
    /// Fixed virtual duration in seconds, independent of platform speed
    /// (Experiment 3B's `sleep`).
    Sleep(f64),
    /// Real work: seconds on an AWS-reference core; scales with the
    /// platform's `cpu_speed`.
    Work(f64),
    /// A FACTS compute step executed through the PJRT runtime; the string
    /// is the artifact name (e.g. `fit_k2_default`). Its *measured* wall
    /// time becomes the virtual work, so platform comparisons reflect
    /// genuine compute (see `facts`).
    Compute(String),
}

/// User-facing task description (built via the builder methods).
///
/// After registration the broker never clones one of these (§Perf): the
/// registry stores `Arc<TaskDescription>` and the policy layer,
/// per-provider slices, and manager threads all share that handle —
/// `TaskRegistry::register_all_shared` / `descriptions_of` hand the
/// shared handles out in bulk, and the managers accept any
/// `Borrow<TaskDescription>`.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDescription {
    pub name: String,
    pub kind: TaskKind,
    pub cpus: u32,
    pub gpus: u32,
    pub mem_mb: u64,
    pub payload: Payload,
    /// Explicit provider binding; `None` lets the brokering policy decide.
    pub provider: Option<ProviderId>,
}

impl TaskDescription {
    pub fn container(name: impl Into<String>, image: impl Into<String>) -> TaskDescription {
        TaskDescription {
            name: name.into(),
            kind: TaskKind::Container { image: image.into() },
            cpus: 1,
            gpus: 0,
            mem_mb: 256,
            payload: Payload::Noop,
            provider: None,
        }
    }

    pub fn executable(name: impl Into<String>, command: impl Into<String>) -> TaskDescription {
        TaskDescription {
            name: name.into(),
            kind: TaskKind::Executable { command: command.into() },
            cpus: 1,
            gpus: 0,
            mem_mb: 256,
            payload: Payload::Noop,
            provider: None,
        }
    }

    /// A serverless function task: single vCPU-equivalent slice, small
    /// memory footprint (the FaaS service owns the sizing).
    pub fn function(name: impl Into<String>, handler: impl Into<String>) -> TaskDescription {
        TaskDescription {
            name: name.into(),
            kind: TaskKind::Function { handler: handler.into() },
            cpus: 1,
            gpus: 0,
            mem_mb: 128,
            payload: Payload::Noop,
            provider: None,
        }
    }

    pub fn with_cpus(mut self, cpus: u32) -> Self {
        self.cpus = cpus;
        self
    }

    pub fn with_gpus(mut self, gpus: u32) -> Self {
        self.gpus = gpus;
        self
    }

    pub fn with_mem_mb(mut self, mem_mb: u64) -> Self {
        self.mem_mb = mem_mb;
        self
    }

    pub fn with_payload(mut self, payload: Payload) -> Self {
        self.payload = payload;
        self
    }

    pub fn on(mut self, provider: ProviderId) -> Self {
        self.provider = Some(provider);
        self
    }

    /// Structural validation performed by the broker before accepting the
    /// task (the `Validated` state gate).
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("task name must not be empty".into());
        }
        if self.cpus == 0 {
            return Err(format!("task '{}': cpus must be >= 1", self.name));
        }
        if self.mem_mb == 0 {
            return Err(format!("task '{}': mem_mb must be >= 1", self.name));
        }
        match &self.kind {
            TaskKind::Container { image } if image.is_empty() => {
                Err(format!("task '{}': container image must not be empty", self.name))
            }
            TaskKind::Executable { command } if command.is_empty() => {
                Err(format!("task '{}': executable command must not be empty", self.name))
            }
            TaskKind::Function { handler } if handler.is_empty() => {
                Err(format!("task '{}': function handler must not be empty", self.name))
            }
            _ => Ok(()),
        }
    }
}

/// Task lifecycle states (paper §3.2: "each task object also holds
/// information about its current/final state and tracing events").
///
/// `Ord` follows declaration (lifecycle) order; the registry's
/// monitoring surface keys `BTreeMap`s by state so reports enumerate
/// states deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TaskState {
    New,
    Validated,
    Partitioned,
    Submitted,
    Running,
    Done,
    Failed,
    Canceled,
}

impl TaskState {
    pub fn is_final(self) -> bool {
        matches!(self, TaskState::Done | TaskState::Failed | TaskState::Canceled)
    }

    /// Legal forward transitions of the state machine. Cancellation is
    /// allowed from any non-final state; failure from any state at or
    /// after validation.
    pub fn can_transition_to(self, next: TaskState) -> bool {
        use TaskState::*;
        if self.is_final() {
            return false;
        }
        match (self, next) {
            (New, Validated) => true,
            (Validated, Partitioned) => true,
            (Partitioned, Submitted) => true,
            (Submitted, Running) => true,
            (Running, Done) => true,
            (_, Canceled) => true,
            (s, Failed) => s != New,
            _ => false,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TaskState::New => "NEW",
            TaskState::Validated => "VALIDATED",
            TaskState::Partitioned => "PARTITIONED",
            TaskState::Submitted => "SUBMITTED",
            TaskState::Running => "RUNNING",
            TaskState::Done => "DONE",
            TaskState::Failed => "FAILED",
            TaskState::Canceled => "CANCELED",
        }
    }
}

impl fmt::Display for TaskState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let t = TaskDescription::container("t0", "noop:latest");
        assert_eq!(t.cpus, 1);
        assert_eq!(t.gpus, 0);
        assert!(t.kind.is_container());
        assert_eq!(t.payload, Payload::Noop);
        assert!(t.provider.is_none());
        assert!(t.validate().is_ok());
    }

    #[test]
    fn builder_chains() {
        let t = TaskDescription::executable("fit", "facts-fit")
            .with_cpus(4)
            .with_gpus(1)
            .with_mem_mb(2048)
            .with_payload(Payload::Work(30.0))
            .on(ProviderId::Bridges2);
        assert_eq!(t.cpus, 4);
        assert_eq!(t.gpus, 1);
        assert_eq!(t.mem_mb, 2048);
        assert_eq!(t.provider, Some(ProviderId::Bridges2));
        assert!(!t.kind.is_container());
    }

    #[test]
    fn function_builder_and_kind() {
        let t = TaskDescription::function("warm", "pkg.module:handler");
        assert!(matches!(t.kind, TaskKind::Function { .. }));
        assert!(!t.kind.is_container());
        assert_eq!(t.cpus, 1);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn validation_rejects_degenerate_tasks() {
        assert!(TaskDescription::container("", "img").validate().is_err());
        assert!(TaskDescription::container("t", "").validate().is_err());
        assert!(TaskDescription::executable("t", "").validate().is_err());
        assert!(TaskDescription::function("t", "").validate().is_err());
        assert!(TaskDescription::container("t", "img").with_cpus(0).validate().is_err());
        assert!(TaskDescription::container("t", "img").with_mem_mb(0).validate().is_err());
    }

    #[test]
    fn state_machine_happy_path() {
        use TaskState::*;
        let path = [New, Validated, Partitioned, Submitted, Running, Done];
        for w in path.windows(2) {
            assert!(w[0].can_transition_to(w[1]), "{:?} -> {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn state_machine_rejects_skips_and_regressions() {
        use TaskState::*;
        assert!(!New.can_transition_to(Submitted));
        assert!(!Validated.can_transition_to(Running));
        assert!(!Running.can_transition_to(Submitted));
        assert!(!Done.can_transition_to(Running));
        assert!(!Failed.can_transition_to(Done));
        assert!(!Canceled.can_transition_to(Validated));
    }

    #[test]
    fn cancel_and_fail_edges() {
        use TaskState::*;
        for s in [New, Validated, Partitioned, Submitted, Running] {
            assert!(s.can_transition_to(Canceled), "{s:?}");
        }
        assert!(!New.can_transition_to(Failed), "unvalidated tasks cannot fail");
        for s in [Validated, Partitioned, Submitted, Running] {
            assert!(s.can_transition_to(Failed), "{s:?}");
        }
        for s in [Done, Failed, Canceled] {
            assert!(s.is_final());
            assert!(!s.can_transition_to(Canceled));
        }
    }

    #[test]
    fn display_formats() {
        assert_eq!(TaskId(7).to_string(), "task.000007");
        assert_eq!(TaskState::Running.to_string(), "RUNNING");
    }
}
