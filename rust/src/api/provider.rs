//! Provider API: credentials and provider configuration.
//!
//! Mirrors the paper's `Provider` class (§3.2): it loads "the credentials
//! and cloud provider configuration" and performs "the credential
//! validations" that gate the startup of Hydra's engine.

use crate::sim::provider::{PlatformProfile, ProviderId};
use crate::util::toml_lite::TomlDoc;

/// Credentials for one provider. In the simulation these are validated
/// structurally (format + checksum handshake) rather than against a live
/// identity service.
#[derive(Debug, Clone, PartialEq)]
pub struct Credentials {
    pub access_key: String,
    pub secret_key: String,
}

impl Credentials {
    pub fn new(access_key: impl Into<String>, secret_key: impl Into<String>) -> Credentials {
        Credentials { access_key: access_key.into(), secret_key: secret_key.into() }
    }

    /// Structural validation: non-empty, prefixed access key, minimum
    /// secret entropy length. The shape mimics real provider key formats
    /// so config mistakes surface before any submission.
    pub fn validate(&self) -> Result<(), String> {
        if self.access_key.is_empty() || self.secret_key.is_empty() {
            return Err("credentials must not be empty".into());
        }
        if !self.access_key.starts_with("HK-") {
            return Err(format!(
                "access key '{}' must start with 'HK-'",
                self.access_key
            ));
        }
        if self.secret_key.len() < 16 {
            return Err("secret key must be at least 16 characters".into());
        }
        Ok(())
    }

    /// Deterministic "handshake" token derived from the key pair — the
    /// simulated analogue of a provider auth round-trip.
    pub fn handshake_token(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.access_key.bytes().chain(self.secret_key.bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Configuration for one provider connection.
#[derive(Debug, Clone, PartialEq)]
pub struct ProviderConfig {
    pub id: ProviderId,
    pub credentials: Credentials,
    pub region: String,
    pub enabled: bool,
}

impl ProviderConfig {
    /// A ready-to-use config for tests/examples.
    pub fn simulated(id: ProviderId) -> ProviderConfig {
        ProviderConfig {
            id,
            credentials: Credentials::new(
                format!("HK-{}", id.short_name()),
                format!("sim-secret-{:024}", id.short_name().len()),
            ),
            region: "sim-east-1".into(),
            enabled: true,
        }
    }

    pub fn profile(&self) -> PlatformProfile {
        PlatformProfile::of(self.id)
    }

    /// Parse the `[provider.<name>]` sections of a config document.
    pub fn from_toml(doc: &TomlDoc) -> Result<Vec<ProviderConfig>, String> {
        let mut out = Vec::new();
        for section in doc.subsections("provider").collect::<Vec<_>>() {
            let name = section.strip_prefix("provider.").unwrap();
            let id = ProviderId::parse(name)
                .ok_or_else(|| format!("unknown provider '{name}' in config"))?;
            let access = doc
                .str(section, "access_key")
                .ok_or_else(|| format!("{section}: missing access_key"))?;
            let secret = doc
                .str(section, "secret_key")
                .ok_or_else(|| format!("{section}: missing secret_key"))?;
            out.push(ProviderConfig {
                id,
                credentials: Credentials::new(access, secret),
                region: doc.str(section, "region").unwrap_or("sim-east-1").to_string(),
                enabled: doc.bool_or(section, "enabled", true),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml_lite;

    #[test]
    fn simulated_configs_validate() {
        for id in ProviderId::ALL {
            let c = ProviderConfig::simulated(id);
            assert!(c.credentials.validate().is_ok(), "{id}");
            assert!(c.enabled);
        }
    }

    #[test]
    fn credential_format_enforced() {
        assert!(Credentials::new("", "x".repeat(20)).validate().is_err());
        assert!(Credentials::new("AK-wrongprefix", "x".repeat(20)).validate().is_err());
        assert!(Credentials::new("HK-ok", "short").validate().is_err());
        assert!(Credentials::new("HK-ok", "x".repeat(16)).validate().is_ok());
    }

    #[test]
    fn handshake_deterministic_and_key_sensitive() {
        let a = Credentials::new("HK-a", "x".repeat(20));
        let b = Credentials::new("HK-b", "x".repeat(20));
        assert_eq!(a.handshake_token(), a.handshake_token());
        assert_ne!(a.handshake_token(), b.handshake_token());
    }

    #[test]
    fn from_toml_parses_providers() {
        let doc = toml_lite::parse(
            r#"
[provider.aws]
access_key = "HK-aws"
secret_key = "0123456789abcdef"
region = "us-east-1"

[provider.bridges2]
access_key = "HK-b2"
secret_key = "0123456789abcdef"
enabled = false
"#,
        )
        .unwrap();
        let cfgs = ProviderConfig::from_toml(&doc).unwrap();
        assert_eq!(cfgs.len(), 2);
        let aws = cfgs.iter().find(|c| c.id == ProviderId::Aws).unwrap();
        assert_eq!(aws.region, "us-east-1");
        assert!(aws.enabled);
        let b2 = cfgs.iter().find(|c| c.id == ProviderId::Bridges2).unwrap();
        assert!(!b2.enabled);
    }

    #[test]
    fn from_toml_rejects_unknown_provider_and_missing_keys() {
        let doc = toml_lite::parse("[provider.gcp]\naccess_key = \"HK-x\"\n").unwrap();
        assert!(ProviderConfig::from_toml(&doc).is_err());
        let doc = toml_lite::parse("[provider.aws]\naccess_key = \"HK-x\"\n").unwrap();
        assert!(ProviderConfig::from_toml(&doc).is_err());
    }
}
