//! Substrate utilities built from scratch for the offline environment:
//! JSON, CLI parsing, TOML-lite configs, deterministic PRNG, property
//! testing, logging, and stats. See DESIGN.md §1 for the substitution table
//! (these stand in for serde_json / clap / proptest / criterion, which are
//! unavailable here).

pub mod cli;
pub mod json;
pub mod json_scan;
pub mod logging;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod toml_lite;

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide monotonic ID source (task ids, pod ids, workflow ids).
#[derive(Debug, Default)]
pub struct IdGen {
    next: AtomicU64,
}

impl IdGen {
    pub const fn new() -> IdGen {
        IdGen { next: AtomicU64::new(0) }
    }

    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }
}

/// Wall-clock stopwatch for OVH measurements (real broker work, not
/// simulated time — see DESIGN.md §1).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        // hydra-lint: allow(wallclock) — Stopwatch IS the wall-clock boundary (OVH timing)
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// Format seconds for human-readable tables: `1.23s`, `45.6ms`, `789us`.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::new();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_secs() >= 0.002);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0456), "45.60ms");
        assert_eq!(fmt_secs(0.000789), "789us");
    }
}
