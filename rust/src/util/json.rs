//! Minimal JSON value model, serializer and recursive-descent parser.
//!
//! The offline build environment ships no `serde`/`serde_json`, and JSON is a
//! first-class substrate in this reproduction: Hydra's CaaS manager builds
//! Kubernetes pod *manifests* as JSON documents (the paper's SCPP-vs-MCPP
//! overhead story is precisely the cost of building and serializing those
//! manifests), and the AOT pipeline hands the Rust runtime an
//! `artifacts/manifest.json` describing the PJRT executables.
//!
//! Design notes:
//! * Object keys keep insertion order (`Vec<(String, Json)>`) — manifests
//!   serialize deterministically, which the broker's trace hashing relies on.
//! * The serializer writes into a caller-provided `String` buffer
//!   (`write_into`) so the partitioner's hot loop can amortize allocations;
//!   see EXPERIMENTS.md §Perf.

use std::fmt;

/// A JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert; replaces an existing key.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            if let Some(slot) = m.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val.into();
            } else {
                m.push((key.to_string(), val.into()));
            }
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path lookup: `doc.at(&["spec", "containers"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            // hydra-lint: allow(float-eq) — exact integrality test, not a tolerance compare
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::with_capacity(128);
        self.write_into(&mut out);
        out
    }

    /// Serialize into an existing buffer (hot-path friendly: no allocation
    /// beyond buffer growth).
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Pretty-printed serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write_into(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    // hydra-lint: allow(float-eq) — exact integrality test, not a tolerance compare
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        // Integral values print without the trailing ".0" — Kubernetes
        // manifests expect integer resource counts. Digits go straight
        // into the caller's buffer: no intermediate String (§Perf).
        push_i64(out, n as i64);
    } else {
        // Non-integral floats go through the fmt machinery, but writing
        // *into* the buffer — `write!` appends in place where `format!`
        // would allocate a fresh String per number (§Perf hot path).
        use std::fmt::Write;
        let _ = write!(out, "{n}");
    }
}

/// Append a decimal i64 to `out` without the `fmt` machinery or any
/// intermediate allocation (§Perf hot path).
pub fn push_i64(out: &mut String, v: i64) {
    if v < 0 {
        out.push('-');
        push_u64(out, v.unsigned_abs());
    } else {
        push_u64(out, v as u64);
    }
}

/// Append a decimal u64 to `out`, digits written in place.
pub fn push_u64(out: &mut String, v: u64) {
    push_u64_padded(out, v, 1);
}

/// Append a decimal u64 left-padded with zeros to at least `width`
/// (manifest names like `hydra-pod-00000042`).
pub fn push_u64_padded(out: &mut String, mut v: u64, width: usize) {
    let mut digits = [0u8; 20];
    let mut i = 20;
    loop {
        i -= 1;
        digits[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    let have = 20 - i;
    for _ in have..width {
        out.push('0');
    }
    out.push_str(std::str::from_utf8(&digits[i..]).unwrap());
}

/// Copy `src` into the front of `dst`, returning the byte count written.
///
/// The bulk-framing path (`broker::data::frame_bulk`) sizes its output
/// buffer exactly from the shard span tables, then writes each shard into
/// its own disjoint window of that buffer — one bulk copy per shard
/// instead of the old per-manifest `push_str` re-copy (§Perf). Panics if
/// `dst` is shorter than `src`; callers compute the frame size first.
pub fn write_str_into(dst: &mut [u8], src: &str) -> usize {
    write_bytes_into(dst, src.as_bytes())
}

/// Byte-slice twin of [`write_str_into`], for callers assembling framed
/// payloads from raw (already-validated) JSON fragments.
pub fn write_bytes_into(dst: &mut [u8], src: &[u8]) -> usize {
    dst[..src.len()].copy_from_slice(src);
    src.len()
}

/// Append `s` as a JSON string literal (quoted + escaped). This is the
/// single escaping implementation shared by the tree serializer and the
/// partitioner's direct-write manifest path — keeping the two
/// byte-identical by construction.
pub fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_escaped(s: &str, out: &mut String) {
    push_json_str(out, s);
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Rejects trailing garbage.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let txt = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for txt in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(txt).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "{txt}");
        }
    }

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e3}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["d"]).unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = Json::obj().set("z", 1u64).set("a", 2u64).set("m", 3u64);
        assert_eq!(v.to_string_compact(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn set_replaces_existing_key() {
        let v = Json::obj().set("k", 1u64).set("k", 2u64);
        assert_eq!(v.to_string_compact(), r#"{"k":2}"#);
    }

    #[test]
    fn integral_numbers_have_no_fraction() {
        assert_eq!(Json::Num(4.0).to_string_compact(), "4");
        assert_eq!(Json::Num(-17.0).to_string_compact(), "-17");
        assert_eq!(Json::Num(0.25).to_string_compact(), "0.25");
    }

    #[test]
    fn escapes_control_characters() {
        let v = Json::Str("a\"b\\c\n\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\n\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = parse("[1, @]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo ☂".to_string());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(parse("\"\\u2602\"").unwrap(), Json::Str("☂".to_string()));
    }

    #[test]
    fn pretty_print_parses_back() {
        let doc = parse(r#"{"spec":{"containers":[{"name":"c0"},{"name":"c1"}]}}"#).unwrap();
        let pretty = doc.to_string_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(parse(&pretty).unwrap(), doc);
    }

    #[test]
    fn path_lookup_misses_return_none() {
        let doc = parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(doc.at(&["a", "b"]).is_some());
        assert!(doc.at(&["a", "c"]).is_none());
        assert!(doc.at(&["x"]).is_none());
    }

    #[test]
    fn push_helpers_write_digits_in_place() {
        let mut s = String::from("x=");
        push_u64(&mut s, 0);
        s.push(',');
        push_u64(&mut s, u64::MAX);
        s.push(',');
        push_i64(&mut s, i64::MIN);
        s.push(',');
        push_i64(&mut s, 42);
        s.push(',');
        push_u64_padded(&mut s, 7, 4);
        assert_eq!(s, "x=0,18446744073709551615,-9223372036854775808,42,0007");
    }

    #[test]
    fn numbers_match_fmt_machinery() {
        for v in [0i64, 1, -1, 10, -10, 999, i64::MAX, i64::MIN, 1234567890] {
            let mut s = String::new();
            push_i64(&mut s, v);
            assert_eq!(s, format!("{v}"));
        }
        for n in [0.5f64, -2.25, 1e-9, 3.14159, 12.75] {
            assert_eq!(Json::Num(n).to_string_compact(), format!("{n}"));
        }
    }

    #[test]
    fn push_json_str_matches_serializer() {
        for s in ["plain", "with \"quotes\"", "tabs\tand\nnewlines", "ctrl\u{1}", "héllo ☂"] {
            let mut direct = String::new();
            push_json_str(&mut direct, s);
            assert_eq!(direct, Json::Str(s.to_string()).to_string_compact());
        }
    }

    #[test]
    fn write_into_slice_helpers() {
        let mut buf = vec![0u8; 16];
        let n = write_str_into(&mut buf, "hello");
        assert_eq!(n, 5);
        let m = write_bytes_into(&mut buf[n..], b", world");
        assert_eq!(&buf[..n + m], b"hello, world");
        // Zero-length writes are fine anywhere, including at the very end.
        assert_eq!(write_str_into(&mut buf[16..], ""), 0);
    }

    #[test]
    #[should_panic]
    fn write_into_slice_rejects_short_destination() {
        let mut buf = vec![0u8; 2];
        write_str_into(&mut buf, "too long");
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::Num(1.0);
        for _ in 0..64 {
            v = Json::Arr(vec![v]);
        }
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }
}
