//! Minimal JSON value model, serializer and recursive-descent parser.
//!
//! The offline build environment ships no `serde`/`serde_json`, and JSON is a
//! first-class substrate in this reproduction: Hydra's CaaS manager builds
//! Kubernetes pod *manifests* as JSON documents (the paper's SCPP-vs-MCPP
//! overhead story is precisely the cost of building and serializing those
//! manifests), and the AOT pipeline hands the Rust runtime an
//! `artifacts/manifest.json` describing the PJRT executables.
//!
//! Design notes:
//! * Object keys keep insertion order (`Vec<(String, Json)>`) — manifests
//!   serialize deterministically, which the broker's trace hashing relies on.
//! * The serializer writes into a caller-provided `String` buffer
//!   (`write_into`) so the partitioner's hot loop can amortize allocations;
//!   see EXPERIMENTS.md §Perf.

use std::fmt;

/// Hard cap on container nesting, shared by this recursive-descent tree
/// parser and the non-recursive [`crate::util::json_scan::JsonScanner`].
/// The tree parser recurses once per container level, so the cap is what
/// turns a hostile deep-nest document into a [`ParseError`] instead of a
/// stack overflow; the scanner sizes its explicit state stack from the
/// same constant so the two paths accept exactly the same documents
/// (locked by `tests/json_equivalence.rs`). 128 comfortably covers every
/// manifest this repo produces (pod manifests nest 6 deep).
pub const MAX_DEPTH: usize = 128;

/// A JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert; replaces an existing key.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            if let Some(slot) = m.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val.into();
            } else {
                m.push((key.to_string(), val.into()));
            }
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path lookup: `doc.at(&["spec", "containers"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            // hydra-lint: allow(float-eq) — exact integrality test, not a tolerance compare
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::with_capacity(128);
        self.write_into(&mut out);
        out
    }

    /// Serialize into an existing buffer (hot-path friendly: no allocation
    /// beyond buffer growth).
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Pretty-printed serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write_into(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Infinity literals (RFC 8259 §6): letting the
        // fmt machinery emit `NaN`/`inf` here would produce a document
        // no parser (including ours) accepts. Non-finite values
        // serialize as `null` — lossy but valid, and the writer's
        // output is guaranteed to re-parse (see
        // `writer_output_always_reparses`).
        out.push_str("null");
        return;
    }
    // hydra-lint: allow(float-eq) — exact integrality test, not a tolerance compare
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        // Integral values print without the trailing ".0" — Kubernetes
        // manifests expect integer resource counts. Digits go straight
        // into the caller's buffer: no intermediate String (§Perf).
        push_i64(out, n as i64);
    } else {
        // Non-integral floats go through the fmt machinery, but writing
        // *into* the buffer — `write!` appends in place where `format!`
        // would allocate a fresh String per number (§Perf hot path).
        use std::fmt::Write;
        let _ = write!(out, "{n}");
    }
}

/// Append a decimal i64 to `out` without the `fmt` machinery or any
/// intermediate allocation (§Perf hot path).
pub fn push_i64(out: &mut String, v: i64) {
    if v < 0 {
        out.push('-');
        push_u64(out, v.unsigned_abs());
    } else {
        push_u64(out, v as u64);
    }
}

/// Append a decimal u64 to `out`, digits written in place.
pub fn push_u64(out: &mut String, v: u64) {
    push_u64_padded(out, v, 1);
}

/// Append a decimal u64 left-padded with zeros to at least `width`
/// (manifest names like `hydra-pod-00000042`).
pub fn push_u64_padded(out: &mut String, mut v: u64, width: usize) {
    let mut digits = [0u8; 20];
    let mut i = 20;
    loop {
        i -= 1;
        digits[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    let have = 20 - i;
    for _ in have..width {
        out.push('0');
    }
    out.push_str(std::str::from_utf8(&digits[i..]).unwrap());
}

/// Copy `src` into the front of `dst`, returning the byte count written.
///
/// The bulk-framing path (`broker::data::frame_bulk`) sizes its output
/// buffer exactly from the shard span tables, then writes each shard into
/// its own disjoint window of that buffer — one bulk copy per shard
/// instead of the old per-manifest `push_str` re-copy (§Perf). Panics if
/// `dst` is shorter than `src`; callers compute the frame size first.
pub fn write_str_into(dst: &mut [u8], src: &str) -> usize {
    write_bytes_into(dst, src.as_bytes())
}

/// Byte-slice twin of [`write_str_into`], for callers assembling framed
/// payloads from raw (already-validated) JSON fragments.
pub fn write_bytes_into(dst: &mut [u8], src: &[u8]) -> usize {
    dst[..src.len()].copy_from_slice(src);
    src.len()
}

/// Append `s` as a JSON string literal (quoted + escaped). This is the
/// single escaping implementation shared by the tree serializer and the
/// partitioner's direct-write manifest path — keeping the two
/// byte-identical by construction.
pub fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_escaped(s: &str, out: &mut String) {
    push_json_str(out, s);
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Rejects trailing garbage.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container nesting level; `value()` recurses once per
    /// level, so [`MAX_DEPTH`] bounds the call stack.
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Enter one container level; errors past [`MAX_DEPTH`] so hostile
    /// deep-nest input is a [`ParseError`], never a stack overflow.
    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("maximum nesting depth exceeded"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let code = self.hex4(self.i + 1)?;
                            if (0xD800..=0xDBFF).contains(&code) {
                                // High surrogate: pair it with an
                                // immediately following \uDC00..\uDFFF
                                // escape (RFC 8259 §7 — "characters ...
                                // represented as a twelve-character
                                // sequence, encoding the UTF-16
                                // surrogate pair"). A *lone* surrogate
                                // (no or wrong partner) is still
                                // accepted but decodes to U+FFFD
                                // REPLACEMENT CHARACTER; the scanner's
                                // validate path accepts the same inputs
                                // (tests/json_equivalence.rs).
                                let lo = if self.b.get(self.i + 5) == Some(&b'\\')
                                    && self.b.get(self.i + 6) == Some(&b'u')
                                {
                                    self.hex4(self.i + 7).ok()
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo) if (0xDC00..=0xDFFF).contains(&lo) => {
                                        let c = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                        self.i += 10;
                                    }
                                    _ => {
                                        s.push('\u{FFFD}');
                                        self.i += 4;
                                    }
                                }
                            } else if (0xDC00..=0xDFFF).contains(&code) {
                                // Lone low surrogate.
                                s.push('\u{FFFD}');
                                self.i += 4;
                            } else {
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                self.i += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let txt = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits starting at byte `at` (strict: `[0-9a-fA-F]`
    /// only — `u32::from_str_radix`'s leading-`+` laxity is rejected).
    fn hex4(&self, at: usize) -> Result<u32, ParseError> {
        if at + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let mut code = 0u32;
        for &c in &self.b[at..at + 4] {
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a' + 10) as u32,
                b'A'..=b'F' => (c - b'A' + 10) as u32,
                _ => return Err(self.err("bad \\u escape")),
            };
            code = code * 16 + d;
        }
        Ok(code)
    }

    /// RFC 8259 §6-strict number grammar: `-?(0|[1-9][0-9]*)(\.[0-9]+)?`
    /// `([eE][+-]?[0-9]+)?`. Rust's `f64::from_str` is laxer (`1.`,
    /// `01`, `-` prefixes of garbage), so the grammar is enforced here
    /// before the final parse; the scanner's validate path implements
    /// the same rules (shared vectors in `util::json_scan`).
    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        match self.peek() {
            // A leading zero is only itself: `01` stops here and the
            // stray digit fails as trailing/separator garbage.
            Some(b'0') => self.i += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            // Bare `-` (or `-x`).
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                // `1.` — the fraction requires at least one digit.
                return Err(self.err("invalid number"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                // `1e`, `1e+` — the exponent requires at least one digit.
                return Err(self.err("invalid number"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for txt in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(txt).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "{txt}");
        }
    }

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e3}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["d"]).unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = Json::obj().set("z", 1u64).set("a", 2u64).set("m", 3u64);
        assert_eq!(v.to_string_compact(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn set_replaces_existing_key() {
        let v = Json::obj().set("k", 1u64).set("k", 2u64);
        assert_eq!(v.to_string_compact(), r#"{"k":2}"#);
    }

    #[test]
    fn integral_numbers_have_no_fraction() {
        assert_eq!(Json::Num(4.0).to_string_compact(), "4");
        assert_eq!(Json::Num(-17.0).to_string_compact(), "-17");
        assert_eq!(Json::Num(0.25).to_string_compact(), "0.25");
    }

    #[test]
    fn escapes_control_characters() {
        let v = Json::Str("a\"b\\c\n\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\n\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = parse("[1, @]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo ☂".to_string());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(parse("\"\\u2602\"").unwrap(), Json::Str("☂".to_string()));
    }

    #[test]
    fn pretty_print_parses_back() {
        let doc = parse(r#"{"spec":{"containers":[{"name":"c0"},{"name":"c1"}]}}"#).unwrap();
        let pretty = doc.to_string_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(parse(&pretty).unwrap(), doc);
    }

    #[test]
    fn path_lookup_misses_return_none() {
        let doc = parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(doc.at(&["a", "b"]).is_some());
        assert!(doc.at(&["a", "c"]).is_none());
        assert!(doc.at(&["x"]).is_none());
    }

    #[test]
    fn push_helpers_write_digits_in_place() {
        let mut s = String::from("x=");
        push_u64(&mut s, 0);
        s.push(',');
        push_u64(&mut s, u64::MAX);
        s.push(',');
        push_i64(&mut s, i64::MIN);
        s.push(',');
        push_i64(&mut s, 42);
        s.push(',');
        push_u64_padded(&mut s, 7, 4);
        assert_eq!(s, "x=0,18446744073709551615,-9223372036854775808,42,0007");
    }

    #[test]
    fn numbers_match_fmt_machinery() {
        for v in [0i64, 1, -1, 10, -10, 999, i64::MAX, i64::MIN, 1234567890] {
            let mut s = String::new();
            push_i64(&mut s, v);
            assert_eq!(s, format!("{v}"));
        }
        for n in [0.5f64, -2.25, 1e-9, 3.14159, 12.75] {
            assert_eq!(Json::Num(n).to_string_compact(), format!("{n}"));
        }
    }

    #[test]
    fn push_json_str_matches_serializer() {
        for s in ["plain", "with \"quotes\"", "tabs\tand\nnewlines", "ctrl\u{1}", "héllo ☂"] {
            let mut direct = String::new();
            push_json_str(&mut direct, s);
            assert_eq!(direct, Json::Str(s.to_string()).to_string_compact());
        }
    }

    #[test]
    fn write_into_slice_helpers() {
        let mut buf = vec![0u8; 16];
        let n = write_str_into(&mut buf, "hello");
        assert_eq!(n, 5);
        let m = write_bytes_into(&mut buf[n..], b", world");
        assert_eq!(&buf[..n + m], b"hello, world");
        // Zero-length writes are fine anywhere, including at the very end.
        assert_eq!(write_str_into(&mut buf[16..], ""), 0);
    }

    #[test]
    #[should_panic]
    fn write_into_slice_rejects_short_destination() {
        let mut buf = vec![0u8; 2];
        write_str_into(&mut buf, "too long");
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::Num(1.0);
        for _ in 0..64 {
            v = Json::Arr(vec![v]);
        }
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    /// ISSUE 10 regression: pre-PR the parser recursed without a depth
    /// limit, so a hostile deep-nest document overflowed the stack (and
    /// this test's `is_err()` assertion fails against that code, which
    /// happily parses any depth it survives). The cap boundary is exact:
    /// MAX_DEPTH parses, MAX_DEPTH + 1 is a ParseError.
    #[test]
    fn deep_nesting_beyond_cap_is_parse_error_not_overflow() {
        let nest = |depth: usize| {
            let mut s = String::new();
            for _ in 0..depth {
                s.push('[');
            }
            s.push('1');
            for _ in 0..depth {
                s.push(']');
            }
            s
        };
        assert!(parse(&nest(MAX_DEPTH)).is_ok(), "cap boundary must parse");
        let e = parse(&nest(MAX_DEPTH + 1)).unwrap_err();
        assert!(e.message.contains("depth"), "got: {e}");
        // Objects count against the same cap.
        let mut s = String::new();
        for _ in 0..=MAX_DEPTH {
            s.push_str("{\"k\":");
        }
        assert!(parse(&s).is_err());
    }

    /// ISSUE 10 regression: pre-PR `😀` decoded as two U+FFFD
    /// replacement chars instead of 😀.
    #[test]
    fn surrogate_pair_escape_decodes_astral_char() {
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".to_string()));
        assert_eq!(parse(r#""😀!""#).unwrap(), Json::Str("😀!".to_string()));
        // Lone surrogates (high without low, low without high, high
        // followed by a non-surrogate escape) stay U+FFFD — accepted,
        // not an error.
        assert_eq!(parse(r#""\ud83d""#).unwrap(), Json::Str("\u{FFFD}".to_string()));
        assert_eq!(parse(r#""\ude00x""#).unwrap(), Json::Str("\u{FFFD}x".to_string()));
        assert_eq!(parse(r#""\ud83dA""#).unwrap(), Json::Str("\u{FFFD}A".to_string()));
        // A decoded pair round-trips through the writer (raw UTF-8, no
        // escape needed on the way back out).
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    /// ISSUE 10 regression: pre-PR the number grammar deferred to
    /// `f64::from_str`, which accepts non-RFC-8259 forms like `1.` and
    /// leading zeros. Vectors shared with the scanner's validate suite.
    #[test]
    fn strict_numbers_reject_nonconforming() {
        use crate::util::json_scan::{NUMBER_ACCEPT, NUMBER_REJECT};
        for txt in NUMBER_ACCEPT {
            assert!(parse(txt).is_ok(), "tree parser must accept {txt:?}");
        }
        for txt in NUMBER_REJECT {
            assert!(parse(txt).is_err(), "tree parser must reject {txt:?}");
        }
    }

    /// ISSUE 10 regression: pre-PR `write_num` pushed NaN/inf through
    /// the fmt machinery, emitting invalid JSON that no parser accepts.
    #[test]
    fn nonfinite_floats_serialize_as_null() {
        for n in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(n).to_string_compact(), "null");
        }
        let doc = Json::obj().set("rate", f64::NAN).set("cap", f64::INFINITY).set("ok", 1.5);
        let s = doc.to_string_compact();
        let back = parse(&s).expect("writer output must re-parse");
        assert!(back.get("rate").unwrap().is_null());
        assert!(back.get("cap").unwrap().is_null());
        assert_eq!(back.get("ok").unwrap().as_f64(), Some(1.5));
    }
}
