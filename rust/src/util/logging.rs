//! Leveled logging to stderr, configured via `HYDRA_LOG`
//! (`error|warn|info|debug|trace`, default `warn`).
//!
//! The broker's hot path never formats log arguments unless the level is
//! enabled (the macros check first), so logging is free when off.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Warn,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static INIT: OnceLock<()> = OnceLock::new();

fn init_from_env() {
    INIT.get_or_init(|| {
        let lvl = std::env::var("HYDRA_LOG")
            .map(|v| Level::from_env(&v))
            .unwrap_or(Level::Warn);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

/// Current level (reads `HYDRA_LOG` once).
pub fn level() -> Level {
    init_from_env();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(lvl: Level) {
    init_from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit a record. Called by the macros; prefer those.
pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5} {target}] {msg}", lvl.as_str());
    }
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Error) {
            $crate::util::logging::log($crate::util::logging::Level::Error, $target,
                                       format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Warn) {
            $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                       format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Info) {
            $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                       format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Debug) {
            $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                       format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_from_env_strings() {
        assert_eq!(Level::from_env("DEBUG"), Level::Debug);
        assert_eq!(Level::from_env("warning"), Level::Warn);
        assert_eq!(Level::from_env("bogus"), Level::Warn);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
