//! Zero-alloc streaming JSON scanner — the broker's ingest path
//! (ISSUE 10, ROADMAP item 5).
//!
//! [`JsonScanner`] is the read-side complement to the tree model in
//! [`util::json`](crate::util::json): a **single-pass, non-recursive,
//! allocation-free** scanner over raw payload bytes. The design follows
//! the two references in SNIPPETS.md:
//!
//! * *miniserde's lazy path scan* — extract one dotted path from a
//!   document without building a tree (measured ~33× over tree-parse
//!   for partial extraction); here [`JsonScanner::path_str`] /
//!   [`JsonScanner::path_u64`] / [`JsonScanner::path_f64`] return
//!   borrowed slices straight out of the input buffer.
//! * *core-json's fixed-depth state stack* — [`Cursor::skip_value`]
//!   replaces recursion with an explicit one-byte-per-level container
//!   stack sized by [`MAX_DEPTH`], so scanning cost is bounded and a
//!   hostile deep-nest payload is a [`ScanError`], never a stack
//!   overflow.
//!
//! The scanner and the tree parser accept exactly the same documents
//! (same [`MAX_DEPTH`], same RFC 8259-strict number grammar — shared
//! vectors [`NUMBER_ACCEPT`] / [`NUMBER_REJECT`] — same escape rules);
//! the agreement is locked by the differential suite in
//! `tests/json_equivalence.rs`, which also greps this file to enforce
//! the no-allocation rule in the non-test code below.
//!
//! Caveats, by design:
//!
//! * Input is assumed UTF-8 (payloads are produced by our own writers);
//!   raw non-escape string bytes are passed through unvalidated.
//! * Path segments match the *raw* key bytes between the quotes, so a
//!   key containing escapes only matches a segment spelled the same
//!   way. Manifest keys never contain escapes.
//! * [`JsonScanner::path_str`] only borrows when the string value has
//!   no escapes; an escaped value returns `None` (decode it through the
//!   tree parser if you actually need it — no manifest field does).

use super::json::MAX_DEPTH;
use std::fmt;

/// What went wrong during a scan. Fieldless so errors cost nothing to
/// construct — the scanner never allocates, success or failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanErrorKind {
    /// Input ended inside a value.
    UnexpectedEof,
    /// A byte that cannot start or continue a value at this position.
    UnexpectedChar,
    /// Container nesting exceeded [`MAX_DEPTH`].
    DepthExceeded,
    /// Malformed `\` escape or `\u` hex sequence.
    BadEscape,
    /// Number violating the RFC 8259 §6 grammar.
    BadNumber,
    /// Valid document followed by non-whitespace bytes.
    TrailingChars,
    /// Object member missing its `:`.
    ExpectedColon,
    /// Missing `,` / closing bracket after a value.
    ExpectedCommaOrClose,
    /// Object member key is not a string.
    ExpectedKey,
}

impl ScanErrorKind {
    fn msg(self) -> &'static str {
        match self {
            ScanErrorKind::UnexpectedEof => "unexpected end of input",
            ScanErrorKind::UnexpectedChar => "unexpected character",
            ScanErrorKind::DepthExceeded => "maximum nesting depth exceeded",
            ScanErrorKind::BadEscape => "bad escape",
            ScanErrorKind::BadNumber => "invalid number",
            ScanErrorKind::TrailingChars => "trailing characters",
            ScanErrorKind::ExpectedColon => "expected ':'",
            ScanErrorKind::ExpectedCommaOrClose => "expected ',' or closing bracket",
            ScanErrorKind::ExpectedKey => "expected string key",
        }
    }
}

/// Scan error with the byte offset it was detected at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanError {
    /// Byte offset into the scanned buffer.
    pub offset: usize,
    /// Failure classification.
    pub kind: ScanErrorKind,
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json scan error at byte {}: {}", self.offset, self.kind.msg())
    }
}

impl std::error::Error for ScanError {}

/// RFC 8259 §6-conforming number literals, shared with the tree-parser
/// suite (`util::json`): both implementations must accept every entry.
pub const NUMBER_ACCEPT: &[&str] = &[
    "0", "-0", "7", "120", "-42", "1.5", "0.25", "-0.5", "1e9", "1E9", "1e+9", "2.5e-3",
    "-1.25E+2", "9007199254740991",
];

/// Number literals Rust's lax `f64::from_str` tolerates (or scalar
/// near-misses) that RFC 8259 rejects — both implementations must
/// reject every entry (pre-ISSUE-10 the tree parser accepted the first
/// three).
pub const NUMBER_REJECT: &[&str] = &[
    "1.", "01", "-", "+1", ".5", "-.5", "1e", "1e+", "1.e3", "00", "0x1", "1.2.3", "--1", "1..2",
];

/// Single-pass, non-recursive, zero-alloc scanner over a JSON payload.
///
/// Construction is free (it only borrows the buffer); every method
/// starts its own pass, so the scanner itself is immutable and cheap to
/// share. See the module docs for the design and its caveats.
#[derive(Debug, Clone, Copy)]
pub struct JsonScanner<'a> {
    b: &'a [u8],
}

impl<'a> JsonScanner<'a> {
    /// Borrow `payload` for scanning.
    pub fn new(payload: &'a [u8]) -> JsonScanner<'a> {
        JsonScanner { b: payload }
    }

    /// Full-document syntax check: exactly one value, strict numbers and
    /// escapes, hard [`MAX_DEPTH`] cap, no trailing bytes. Accepts the
    /// same documents as `util::json::parse` (differentially tested).
    pub fn validate(&self) -> Result<(), ScanError> {
        let mut c = Cursor { b: self.b, i: 0 };
        c.skip_ws();
        c.skip_value()?;
        c.skip_ws();
        if c.i != c.b.len() {
            return Err(c.fail(ScanErrorKind::TrailingChars));
        }
        Ok(())
    }

    /// Raw serialized bytes of the value at `path` (objects navigated by
    /// key, no array indexing), without building a tree. `None` when the
    /// path is absent or the document is malformed along the walk.
    pub fn path_raw(&self, path: &[&str]) -> Option<&'a [u8]> {
        let (s, e) = self.path_span(path)?;
        Some(&self.b[s..e])
    }

    /// Borrowed `&str` of the string value at `path`. `None` unless the
    /// value is a string with no escapes (see module docs).
    pub fn path_str(&self, path: &[&str]) -> Option<&'a str> {
        let (s, e) = self.path_span(path)?;
        if e < s + 2 || self.b[s] != b'"' {
            return None;
        }
        let inner = &self.b[s + 1..e - 1];
        if inner.contains(&b'\\') {
            return None;
        }
        std::str::from_utf8(inner).ok()
    }

    /// The unsigned integer at `path`. `None` for anything but a plain
    /// integer token in range (no sign, fraction, or exponent).
    pub fn path_u64(&self, path: &[&str]) -> Option<u64> {
        let (s, e) = self.path_span(path)?;
        let txt = std::str::from_utf8(&self.b[s..e]).ok()?;
        txt.parse::<u64>().ok()
    }

    /// The number at `path` as f64. `None` for non-number values.
    pub fn path_f64(&self, path: &[&str]) -> Option<f64> {
        let (s, e) = self.path_span(path)?;
        if !matches!(self.b[s], b'-' | b'0'..=b'9') {
            return None;
        }
        let txt = std::str::from_utf8(&self.b[s..e]).ok()?;
        txt.parse::<f64>().ok()
    }

    /// Iterate the items of a top-level array — the framed bulk payload
    /// shape `[m0,m1,...]` — yielding each item's byte span without
    /// materializing anything. The iterator is fused: the first error
    /// (malformed item, missing separator, trailing bytes) ends it.
    pub fn items(&self) -> Items<'a> {
        Items { c: Cursor { b: self.b, i: 0 }, state: ItemsState::Start }
    }

    fn path_span(&self, path: &[&str]) -> Option<(usize, usize)> {
        let mut c = Cursor { b: self.b, i: 0 };
        c.skip_ws();
        for seg in path {
            // The current value must be an object containing `seg`.
            if c.peek() != Some(b'{') {
                return None;
            }
            c.i += 1;
            loop {
                c.skip_ws();
                if c.peek() != Some(b'"') {
                    return None;
                }
                let ks = c.i + 1;
                c.skip_string().ok()?;
                let ke = c.i - 1;
                c.skip_ws();
                if c.peek() != Some(b':') {
                    return None;
                }
                c.i += 1;
                c.skip_ws();
                if &c.b[ks..ke] == seg.as_bytes() {
                    break; // cursor now at the member's value
                }
                c.skip_value().ok()?;
                c.skip_ws();
                match c.peek() {
                    Some(b',') => c.i += 1,
                    // '}' (key absent) or garbage: either way, no match.
                    _ => return None,
                }
            }
        }
        let start = c.i;
        c.skip_value().ok()?;
        Some((start, c.i))
    }
}

/// Iterator over top-level array item spans; see [`JsonScanner::items`].
#[derive(Debug)]
pub struct Items<'a> {
    c: Cursor<'a>,
    state: ItemsState,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ItemsState {
    Start,
    Mid,
    Done,
}

impl<'a> Items<'a> {
    fn yield_item(&mut self) -> Option<Result<(usize, usize), ScanError>> {
        self.c.skip_ws();
        let start = self.c.i;
        match self.c.skip_value() {
            Ok(()) => Some(Ok((start, self.c.i))),
            Err(e) => {
                self.state = ItemsState::Done;
                Some(Err(e))
            }
        }
    }

    fn finish(&mut self) -> Option<Result<(usize, usize), ScanError>> {
        self.state = ItemsState::Done;
        self.c.skip_ws();
        if self.c.i != self.c.b.len() {
            return Some(Err(self.c.fail(ScanErrorKind::TrailingChars)));
        }
        None
    }
}

impl<'a> Iterator for Items<'a> {
    type Item = Result<(usize, usize), ScanError>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.state {
            ItemsState::Done => None,
            ItemsState::Start => {
                self.c.skip_ws();
                if self.c.peek() != Some(b'[') {
                    self.state = ItemsState::Done;
                    return Some(Err(self.c.fail(ScanErrorKind::UnexpectedChar)));
                }
                self.c.i += 1;
                self.c.skip_ws();
                if self.c.peek() == Some(b']') {
                    self.c.i += 1;
                    return self.finish();
                }
                self.state = ItemsState::Mid;
                self.yield_item()
            }
            ItemsState::Mid => {
                self.c.skip_ws();
                match self.c.peek() {
                    Some(b',') => {
                        self.c.i += 1;
                        self.yield_item()
                    }
                    Some(b']') => {
                        self.c.i += 1;
                        self.finish()
                    }
                    _ => {
                        self.state = ItemsState::Done;
                        Some(Err(self.c.fail(ScanErrorKind::ExpectedCommaOrClose)))
                    }
                }
            }
        }
    }
}

/// Byte cursor with the non-recursive skip machinery. All hot-loop code:
/// nothing here may allocate (grep-enforced from the equivalence suite).
#[derive(Debug)]
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn fail(&self, kind: ScanErrorKind) -> ScanError {
        ScanError { offset: self.i, kind }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    /// Skip one complete value (any kind) starting at the cursor.
    /// Containers are tracked on an explicit fixed-size stack — one byte
    /// per nesting level, no recursion (core-json design).
    fn skip_value(&mut self) -> Result<(), ScanError> {
        let mut stack = [0u8; MAX_DEPTH];
        let mut depth: usize = 0;
        'value: loop {
            self.skip_ws();
            match self.peek() {
                None => return Err(self.fail(ScanErrorKind::UnexpectedEof)),
                Some(open @ (b'{' | b'[')) => {
                    if depth == MAX_DEPTH {
                        return Err(self.fail(ScanErrorKind::DepthExceeded));
                    }
                    stack[depth] = open;
                    depth += 1;
                    self.i += 1;
                    self.skip_ws();
                    let close = if open == b'{' { b'}' } else { b']' };
                    if self.peek() == Some(close) {
                        self.i += 1;
                        depth -= 1;
                        // An empty container is a complete value: fall
                        // through to the separator/close loop below.
                    } else {
                        if open == b'{' {
                            self.object_key()?;
                        }
                        continue 'value;
                    }
                }
                Some(b'"') => self.skip_string()?,
                Some(b't') => self.skip_lit(b"true")?,
                Some(b'f') => self.skip_lit(b"false")?,
                Some(b'n') => self.skip_lit(b"null")?,
                Some(c) if c == b'-' || c.is_ascii_digit() => self.skip_number()?,
                Some(_) => return Err(self.fail(ScanErrorKind::UnexpectedChar)),
            }
            // One complete value just ended. Pop closed containers and
            // advance over separators until the next value starts (or
            // the whole skip is done).
            loop {
                if depth == 0 {
                    return Ok(());
                }
                self.skip_ws();
                let in_obj = stack[depth - 1] == b'{';
                match self.peek() {
                    Some(b',') => {
                        self.i += 1;
                        if in_obj {
                            self.skip_ws();
                            self.object_key()?;
                        }
                        continue 'value;
                    }
                    Some(b'}') if in_obj => {
                        self.i += 1;
                        depth -= 1;
                    }
                    Some(b']') if !in_obj => {
                        self.i += 1;
                        depth -= 1;
                    }
                    _ => return Err(self.fail(ScanErrorKind::ExpectedCommaOrClose)),
                }
            }
        }
    }

    /// `"key":` — leaves the cursor at the first byte after the colon.
    fn object_key(&mut self) -> Result<(), ScanError> {
        if self.peek() != Some(b'"') {
            return Err(self.fail(ScanErrorKind::ExpectedKey));
        }
        self.skip_string()?;
        self.skip_ws();
        if self.peek() != Some(b':') {
            return Err(self.fail(ScanErrorKind::ExpectedColon));
        }
        self.i += 1;
        Ok(())
    }

    /// Skip a string literal (cursor on the opening quote). Escapes are
    /// validated (`\u` requires exactly four hex digits — lone
    /// surrogates are *accepted*, matching the tree parser, which
    /// decodes them to U+FFFD); raw bytes pass through unvalidated.
    fn skip_string(&mut self) -> Result<(), ScanError> {
        self.i += 1;
        loop {
            match self.peek() {
                None => return Err(self.fail(ScanErrorKind::UnexpectedEof)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f') => {
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c) if c.is_ascii_hexdigit() => self.i += 1,
                                    _ => return Err(self.fail(ScanErrorKind::BadEscape)),
                                }
                            }
                        }
                        _ => return Err(self.fail(ScanErrorKind::BadEscape)),
                    }
                }
                Some(_) => self.i += 1,
            }
        }
    }

    fn skip_lit(&mut self, word: &'static [u8]) -> Result<(), ScanError> {
        if self.b[self.i..].starts_with(word) {
            self.i += word.len();
            Ok(())
        } else {
            Err(self.fail(ScanErrorKind::UnexpectedChar))
        }
    }

    /// RFC 8259 §6 number grammar — identical to the tree parser's
    /// `Parser::number` (shared vectors lock the agreement).
    fn skip_number(&mut self) -> Result<(), ScanError> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        match self.peek() {
            Some(b'0') => self.i += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return Err(self.fail(ScanErrorKind::BadNumber)),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.fail(ScanErrorKind::BadNumber));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.fail(ScanErrorKind::BadNumber));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_wellformed_documents() {
        for doc in [
            "null",
            "true",
            " 42 ",
            "\"hi\\n\\u2602\"",
            "[]",
            "{}",
            "[1,2,3]",
            r#"{"a":[1,{"b":null}],"c":"x"}"#,
            r#"[{"uid":"task.000001"},{"uid":"task.000002"}]"#,
        ] {
            assert!(JsonScanner::new(doc.as_bytes()).validate().is_ok(), "{doc}");
        }
    }

    #[test]
    fn rejects_malformed_documents() {
        for doc in [
            "",
            "[",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{1:2}",
            "tru",
            "nul",
            "{} x",
            "\"unterminated",
            "\"bad\\q\"",
            "\"\\u12g4\"",
            "[1 2]",
        ] {
            assert!(JsonScanner::new(doc.as_bytes()).validate().is_err(), "{doc}");
        }
    }

    #[test]
    fn number_vectors_shared_with_tree_parser() {
        for txt in NUMBER_ACCEPT {
            assert!(JsonScanner::new(txt.as_bytes()).validate().is_ok(), "accept {txt:?}");
        }
        for txt in NUMBER_REJECT {
            assert!(JsonScanner::new(txt.as_bytes()).validate().is_err(), "reject {txt:?}");
        }
    }

    #[test]
    fn depth_cap_boundary_matches_tree_parser() {
        let nest = |depth: usize| {
            let mut s = String::new();
            for _ in 0..depth {
                s.push('[');
            }
            s.push('1');
            for _ in 0..depth {
                s.push(']');
            }
            s
        };
        assert!(JsonScanner::new(nest(MAX_DEPTH).as_bytes()).validate().is_ok());
        let e = JsonScanner::new(nest(MAX_DEPTH + 1).as_bytes()).validate().unwrap_err();
        assert_eq!(e.kind, ScanErrorKind::DepthExceeded);
    }

    #[test]
    fn path_extraction_without_tree() {
        let doc = br#"{"metadata":{"name":"hydra-pod-00000042","labels":{"app":"hydra","hydra/pod-id":42}},"spec":{"weight":2.5}}"#;
        let s = JsonScanner::new(doc);
        assert_eq!(s.path_str(&["metadata", "name"]), Some("hydra-pod-00000042"));
        assert_eq!(s.path_u64(&["metadata", "labels", "hydra/pod-id"]), Some(42));
        assert_eq!(s.path_f64(&["spec", "weight"]), Some(2.5));
        assert_eq!(s.path_raw(&["metadata", "labels"]), Some(&br#"{"app":"hydra","hydra/pod-id":42}"#[..]));
        // Misses and type mismatches are None, not errors.
        assert_eq!(s.path_str(&["metadata", "missing"]), None);
        assert_eq!(s.path_u64(&["metadata", "name"]), None);
        assert_eq!(s.path_str(&["spec", "weight"]), None);
        assert_eq!(s.path_f64(&["metadata"]), None);
    }

    #[test]
    fn path_str_refuses_escaped_values() {
        let s = JsonScanner::new(br#"{"a":"x\ny","b":"plain"}"#);
        assert_eq!(s.path_str(&["a"]), None, "escaped value cannot be borrowed");
        assert_eq!(s.path_str(&["b"]), Some("plain"));
    }

    #[test]
    fn items_yield_framed_payload_spans() {
        let payload = br#"[{"uid":"a"},{"uid":"b"},7]"#;
        let s = JsonScanner::new(payload);
        let spans: Vec<(usize, usize)> = s.items().map(|r| r.unwrap()).collect();
        assert_eq!(spans.len(), 3);
        assert_eq!(&payload[spans[0].0..spans[0].1], br#"{"uid":"a"}"#);
        assert_eq!(&payload[spans[1].0..spans[1].1], br#"{"uid":"b"}"#);
        assert_eq!(&payload[spans[2].0..spans[2].1], b"7");
    }

    #[test]
    fn items_empty_and_error_cases() {
        assert_eq!(JsonScanner::new(b"[]").items().count(), 0);
        assert_eq!(JsonScanner::new(b" [ ] ").items().count(), 0);
        // Not an array at the top level.
        assert!(JsonScanner::new(b"{}").items().next().unwrap().is_err());
        // Malformed item ends the iterator with the error.
        let mut it = JsonScanner::new(b"[1,,2]").items();
        assert!(it.next().unwrap().is_ok());
        assert!(it.next().unwrap().is_err());
        assert!(it.next().is_none(), "iterator is fused after an error");
        // Trailing garbage after the close is reported.
        let mut it = JsonScanner::new(b"[1] x").items();
        assert!(it.next().unwrap().is_ok());
        assert!(it.next().unwrap().is_err());
    }

    #[test]
    fn surrogate_escapes_accepted_like_tree_parser() {
        for doc in [r#""😀""#, r#""\ud83d""#, r#""\ude00x""#] {
            assert!(JsonScanner::new(doc.as_bytes()).validate().is_ok(), "{doc}");
        }
    }
}
