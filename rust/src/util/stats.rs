//! Summary statistics for experiment trials.
//!
//! The paper reports every metric as mean ± error bars over repeated runs;
//! [`Summary`] is the single place that computes those aggregates so bench
//! harnesses and the metrics collector agree on definitions.

/// Aggregate of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, median: 0.0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
        }
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n > 0 {
            self.std / (self.n as f64).sqrt()
        } else {
            0.0
        }
    }

    /// Half-width of a ~95% normal CI.
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }
}

/// Linear-interpolated percentile of a pre-sorted slice (p in [0, 100]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Ordinary least-squares fit y = a + b*x, returning (a, b, r2).
///
/// Used by the bench harnesses to classify scaling behaviour (linear vs
/// sublinear) the way the paper's figures are read.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if sxx == 0.0 { // hydra-lint: allow(float-eq) — degenerate-variance sentinel
        return (my, 0.0, 0.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    // hydra-lint: allow(float-eq) — degenerate-variance sentinel
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Log–log slope: the scaling exponent alpha in y ~ x^alpha.
///
/// alpha ≈ 1 → linear scaling; alpha < 1 → sublinear; alpha ≈ 0 → invariant.
pub fn scaling_exponent(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|x| x.max(1e-12).ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-12).ln()).collect();
    linear_fit(&lx, &ly).1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_degenerate_cases() {
        let s0 = Summary::of(&[]);
        assert_eq!(s0.n, 0);
        assert_eq!(s0.ci95(), 0.0);
        let s1 = Summary::of(&[7.0]);
        assert_eq!(s1.std, 0.0);
        assert_eq!(s1.median, 7.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scaling_exponent_classifies() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let lin: Vec<f64> = xs.iter().map(|x| 5.0 * x).collect();
        let flat = [3.0, 3.0, 3.0, 3.0];
        let sqrt: Vec<f64> = xs.iter().map(|x| x.sqrt()).collect();
        assert!((scaling_exponent(&xs, &lin) - 1.0).abs() < 1e-6);
        assert!(scaling_exponent(&xs, &flat).abs() < 1e-6);
        assert!((scaling_exponent(&xs, &sqrt) - 0.5).abs() < 1e-6);
    }
}
