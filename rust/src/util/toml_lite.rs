//! A TOML-subset parser for Hydra configuration files.
//!
//! Supports what `configs/*.toml` actually use: `[section]` and
//! `[section.sub]` headers, `key = value` with strings, integers, floats,
//! booleans and homogeneous arrays, comments (`#`), and blank lines.
//! Not supported (rejected, not silently misparsed): multi-line strings,
//! dates, inline tables, arrays of tables.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: section path ("a.b") -> key -> value. Top-level keys
/// live under the empty section "".
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    pub fn str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(|v| v.as_str())
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All section names under a prefix, e.g. `subsections("provider")`
    /// yields `provider.aws`, `provider.jet2`, ...
    pub fn subsections<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.sections
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(|k| k.as_str())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    doc.sections.entry(String::new()).or_default();
    let mut current = String::new();

    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty()
                || !name.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c))
            {
                return Err(err(lineno, "invalid section name"));
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(val.trim(), lineno)?;
        doc.sections.get_mut(&current).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn err(line: usize, message: &str) -> TomlError {
    TomlError { line, message: message.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        let mut out = String::new();
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err(err(lineno, "bad escape in string")),
                }
            } else if c == '"' {
                return Err(err(lineno, "unescaped quote in string"));
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value '{s}'")))
}

/// Split on commas not nested in brackets/strings (for arrays of arrays).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# global
seed = 42
name = "hydra-run"

[provider.jet2]
kind = "cloud"
vcpus = 16
weight = 1.5
enabled = true
regions = ["iu", "tacc"]

[provider.bridges2]
kind = "hpc"
cores_per_node = 128
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = parse(DOC).unwrap();
        assert_eq!(d.i64_or("", "seed", 0), 42);
        assert_eq!(d.str("", "name"), Some("hydra-run"));
        assert_eq!(d.str("provider.jet2", "kind"), Some("cloud"));
        assert_eq!(d.i64_or("provider.jet2", "vcpus", 0), 16);
        assert_eq!(d.f64_or("provider.jet2", "weight", 0.0), 1.5);
        assert!(d.bool_or("provider.jet2", "enabled", false));
        let regions = d.get("provider.jet2", "regions").unwrap().as_arr().unwrap();
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].as_str(), Some("iu"));
    }

    #[test]
    fn subsections_enumerate_providers() {
        let d = parse(DOC).unwrap();
        let names: Vec<&str> = d.subsections("provider").collect();
        assert_eq!(names, vec!["provider.bridges2", "provider.jet2"]);
    }

    #[test]
    fn comments_and_hash_in_strings() {
        let d = parse("x = \"a#b\" # trailing\ny = 1 # c\n").unwrap();
        assert_eq!(d.str("", "x"), Some("a#b"));
        assert_eq!(d.i64_or("", "y", 0), 1);
    }

    #[test]
    fn int_float_distinction_and_underscores() {
        let d = parse("a = 10_000\nb = 2.5\nc = -3\n").unwrap();
        assert_eq!(d.get("", "a"), Some(&TomlValue::Int(10000)));
        assert_eq!(d.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(d.get("", "c"), Some(&TomlValue::Int(-3)));
        // ints coerce to f64 on demand
        assert_eq!(d.f64_or("", "a", 0.0), 10_000.0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn string_escapes() {
        let d = parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(d.str("", "s"), Some("a\nb\t\"c\""));
    }

    #[test]
    fn nested_arrays() {
        let d = parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = d.get("", "m").unwrap().as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn missing_keys_fall_back_to_defaults() {
        let d = parse("").unwrap();
        assert_eq!(d.i64_or("nope", "k", 9), 9);
        assert!(d.str("nope", "k").is_none());
    }
}
