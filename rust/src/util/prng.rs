//! Deterministic pseudo-random number generation.
//!
//! Every stochastic element of the simulation (task durations, provisioning
//! jitter, queue waits, FACTS ensemble noise) draws from a seeded
//! [`Prng`] so experiment runs are exactly reproducible: the bench harnesses
//! print the seed with every table row. Implementation: SplitMix64 for
//! seeding, xoshiro256** for the stream (public-domain reference
//! constructions), Box–Muller for normals.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Prng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent stream (e.g. one per provider thread) without
    /// correlating with the parent.
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (empty range returns lo).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        if hi <= lo {
            return lo;
        }
        // Rejection-free bounded draw (Lemire); bias is negligible for our
        // simulation ranges but we keep the multiply-shift construction.
        let span = hi - lo;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with mean/std, truncated at `lo` (simulation latencies must
    /// stay positive).
    pub fn normal_trunc(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        (mean + std * self.normal()).max(lo)
    }

    /// Log-normal parameterized by the *target* mean and coefficient of
    /// variation — cloud provisioning latencies are right-skewed.
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Exponential with the given mean (pilot MTBF draws). `uniform` is in
    /// [0, 1), so `1 - u` is in (0, 1] and the log is finite.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.uniform()).ln()
    }

    pub fn bool_with_p(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.range_usize(0, xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_with_plausible_mean() {
        let mut r = Prng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Prng::new(3);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
        }
        assert_eq!(r.range_u64(5, 5), 5);
        assert_eq!(r.range_u64(9, 3), 9);
    }

    #[test]
    fn lognormal_positive_and_mean_close() {
        let mut r = Prng::new(5);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.lognormal_mean_cv(3.0, 0.3);
            assert!(v > 0.0);
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn exponential_positive_and_mean_close() {
        let mut r = Prng::new(17);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.exponential(4.0);
            assert!(v >= 0.0 && v.is_finite());
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn truncation_respects_floor() {
        let mut r = Prng::new(9);
        for _ in 0..1000 {
            assert!(r.normal_trunc(0.0, 10.0, 0.5) >= 0.5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Prng::new(100);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn choose_empty_is_none() {
        let mut r = Prng::new(1);
        let empty: [u8; 0] = [];
        assert!(r.choose(&empty).is_none());
        assert_eq!(*r.choose(&[42]).unwrap(), 42);
    }
}
