//! A small property-based testing harness.
//!
//! The offline environment ships no `proptest`, so this module provides the
//! subset we need to state coordinator invariants (routing, batching, task
//! state machines) as properties over generated inputs: seeded generators,
//! a runner that reports the failing seed, and size-directed shrinking by
//! re-running with smaller size budgets.
//!
//! Usage:
//! ```no_run
//! use hydra::util::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Prng;

/// Input source handed to property bodies. `size` bounds generated
/// collection lengths so failures shrink toward small cases.
pub struct Gen {
    rng: Prng,
    /// Current size budget in [1, 100].
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        // hydra-lint: allow(prng-salt) — the harness's root stream; cases derive per-index seeds
        Gen { rng: Prng::new(seed), size: size.max(1) }
    }

    pub fn u64(&mut self, lo: u64, hi_inclusive: u64) -> u64 {
        self.rng.range_u64(lo, hi_inclusive.saturating_add(1))
    }

    pub fn usize(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.u64(lo as u64, hi_inclusive as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool_with_p(0.5)
    }

    /// Length scaled by the current size budget (at least `min`).
    pub fn len(&mut self, min: usize, max_at_full_size: usize) -> usize {
        let hi = (max_at_full_size * self.size / 100).max(min);
        self.usize(min, hi)
    }

    pub fn vec<T>(&mut self, min: usize, max_at_full_size: usize,
                  mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.len(min, max_at_full_size);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range_usize(0, xs.len());
        &xs[i]
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.usize(0, max_len);
        (0..n)
            .map(|_| {
                let alphabet = b"abcdefghijklmnopqrstuvwxyz0123456789-_";
                alphabet[self.rng.range_usize(0, alphabet.len())] as char
            })
            .collect()
    }
}

/// Outcome of a single case, captured across the unwind boundary.
fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    f: &F, seed: u64, size: usize,
) -> Result<(), String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, size);
        f(&mut g);
    });
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            Err(msg)
        }
    }
}

/// Run `cases` random cases of the property; on failure, shrink the size
/// budget to find a smaller failing case, then panic with the seed and
/// message so the case can be replayed deterministically.
pub fn forall<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    forall_seeded(name, 0xC0FFEE, cases, f)
}

/// `forall` with an explicit base seed (replay a failure by pasting the
/// reported seed here).
pub fn forall_seeded<F>(name: &str, base_seed: u64, cases: u64, f: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    // Quiet the default panic printer while we probe cases.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut failure: Option<(u64, usize, String)> = None;

    'outer: for i in 0..cases {
        let seed = base_seed.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
        // Grow sizes over the run: early cases are small by construction.
        let size = (1 + (i * 100 / cases.max(1)) as usize).min(100);
        if let Err(msg) = run_case(&f, seed, size) {
            // Shrink: retry the same seed with progressively smaller sizes
            // and keep the smallest size that still fails.
            let mut best = (seed, size, msg);
            let mut s = size;
            while s > 1 {
                s /= 2;
                match run_case(&f, best.0, s) {
                    Err(m) => best = (best.0, s, m),
                    Ok(()) => break,
                }
            }
            failure = Some(best);
            break 'outer;
        }
    }

    std::panic::set_hook(prev_hook);
    if let Some((seed, size, msg)) = failure {
        panic!(
            "property '{name}' failed (replay: forall_seeded(\"{name}\", {seed:#x}, 1, ..) \
             with Gen size {size}): {msg}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("add commutes", 100, |g| {
            let a = g.u64(0, 1 << 20);
            let b = g.u64(0, 1 << 20);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails on big input", 50, |g| {
                let v = g.vec(0, 50, |g| g.u64(0, 10));
                assert!(v.len() < 3, "len was {}", v.len());
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay"), "{msg}");
    }

    #[test]
    fn sizes_grow_over_run() {
        // With 100 cases the first case has size 1: vec len <= max(0*1/100,..)
        let mut g = Gen::new(1, 1);
        let v = g.vec(0, 100, |g| g.bool());
        assert!(v.len() <= 1);
        let mut g = Gen::new(1, 100);
        let v = g.vec(0, 100, |g| g.bool());
        assert!(v.len() <= 100);
    }

    #[test]
    fn deterministic_replay() {
        let mut g1 = Gen::new(99, 50);
        let mut g2 = Gen::new(99, 50);
        for _ in 0..32 {
            assert_eq!(g1.u64(0, 1000), g2.u64(0, 1000));
        }
    }

    #[test]
    fn string_alphabet() {
        let mut g = Gen::new(5, 100);
        for _ in 0..50 {
            let s = g.string(20);
            assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'));
        }
    }
}
