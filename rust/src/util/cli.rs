//! Declarative command-line parsing (no `clap` in the offline environment).
//!
//! Supports the subset the `hydra` binary and the bench harnesses need:
//! subcommands, `--flag`, `--key value` / `--key=value` options with typed
//! accessors and defaults, positional arguments, and generated `--help`
//! text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

/// An option/flag specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(default) => value option ("" = required).
    pub default: Option<&'static str>,
}

/// A (sub)command specification.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Command {
        self.opts.push(OptSpec { name, help, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Command {
        self.opts.push(OptSpec { name, help, default: Some(default) });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Command {
        self.positionals.push((name, help));
        self
    }

    fn usage(&self, program: &str) -> String {
        let mut s =
            format!("{} — {}\n\nUSAGE:\n  {program} {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            match o.default {
                None => s.push_str(&format!("  --{:<22} {}\n", o.name, o.help)),
                Some("") => s.push_str(&format!("  --{:<22} {} (required)\n",
                                                format!("{} <v>", o.name), o.help)),
                Some(d) => s.push_str(&format!("  --{:<22} {} [default: {d}]\n",
                                               format!("{} <v>", o.name), o.help)),
            }
        }
        s.push_str("  --help                   show this message\n");
        s
    }
}

/// Parsed arguments for a matched command.
#[derive(Debug, Clone)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Matches {
    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("unknown option queried: --{name}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected integer, got '{}'", self.str(name))))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.u64(name)? as usize)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected number, got '{}'", self.str(name))))
    }

    /// Comma-separated list of integers, e.g. `--tasks 4000,8000,16000`.
    pub fn u64_list(&self, name: &str) -> Result<Vec<u64>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad list element '{s}'")))
            })
            .collect()
    }
}

/// A CLI application: a set of subcommands.
pub struct App {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

pub enum Parsed {
    /// Ready to run.
    Run(Matches),
    /// `--help` was requested; the string is the help text to print.
    Help(String),
}

impl App {
    pub fn new(program: &'static str, about: &'static str) -> App {
        App { program, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> App {
        self.commands.push(c);
        self
    }

    pub fn top_usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n",
                            self.program, self.about, self.program);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
        }
        s.push_str("\nRun '<COMMAND> --help' for command options.\n");
        s
    }

    /// Parse argv (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, CliError> {
        let Some(cmd_name) = argv.first() else {
            return Ok(Parsed::Help(self.top_usage()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Ok(Parsed::Help(self.top_usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError(format!("unknown command '{cmd_name}'")))?;

        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for o in &cmd.opts {
            match o.default {
                None => {
                    flags.insert(o.name.to_string(), false);
                }
                Some(d) => {
                    values.insert(o.name.to_string(), d.to_string());
                }
            }
        }

        let mut positionals = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Ok(Parsed::Help(cmd.usage(self.program)));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key} for '{cmd_name}'")))?;
                match spec.default {
                    None => {
                        if inline.is_some() {
                            return Err(CliError(format!("--{key} takes no value")));
                        }
                        flags.insert(key, true);
                    }
                    Some(_) => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                            }
                        };
                        values.insert(key, v);
                    }
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }

        if positionals.len() < cmd.positionals.len() {
            return Err(CliError(format!(
                "'{cmd_name}' expects {} positional argument(s)",
                cmd.positionals.len()
            )));
        }
        for o in &cmd.opts {
            if o.default == Some("") && values.get(o.name).map(|v| v.is_empty()).unwrap_or(true) {
                return Err(CliError(format!("--{} is required", o.name)));
            }
        }
        Ok(Parsed::Run(Matches { command: cmd_name.clone(), values, flags, positionals }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("hydra", "broker")
            .command(
                Command::new("run", "run a workload")
                    .opt("tasks", "1000", "number of tasks")
                    .opt("provider", "jet2", "target provider")
                    .opt("out", "", "output file")
                    .flag("scpp", "single container per pod"),
            )
            .command(Command::new("facts", "run FACTS").positional("config", "config path"))
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let m = match app().parse(&argv(&["run", "--tasks", "4000", "--scpp", "--out", "x"])) {
            Ok(Parsed::Run(m)) => m,
            other => panic!("{other:?}", other = matches!(other, Ok(_))),
        };
        assert_eq!(m.u64("tasks").unwrap(), 4000);
        assert_eq!(m.str("provider"), "jet2");
        assert!(m.flag("scpp"));
    }

    #[test]
    fn equals_syntax() {
        let m = match app().parse(&argv(&["run", "--tasks=64", "--out=y"])) {
            Ok(Parsed::Run(m)) => m,
            _ => panic!(),
        };
        assert_eq!(m.u64("tasks").unwrap(), 64);
    }

    #[test]
    fn required_option_enforced() {
        let e = app().parse(&argv(&["run"])).err().unwrap();
        assert!(e.0.contains("--out is required"), "{}", e.0);
    }

    #[test]
    fn unknown_command_and_option_rejected() {
        assert!(app().parse(&argv(&["nope"])).is_err());
        assert!(app().parse(&argv(&["run", "--bogus", "1", "--out", "x"])).is_err());
    }

    #[test]
    fn positional_required() {
        assert!(app().parse(&argv(&["facts"])).is_err());
        let m = match app().parse(&argv(&["facts", "cfg.toml"])) {
            Ok(Parsed::Run(m)) => m,
            _ => panic!(),
        };
        assert_eq!(m.positionals, vec!["cfg.toml".to_string()]);
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&argv(&[])), Ok(Parsed::Help(_))));
        assert!(matches!(app().parse(&argv(&["--help"])), Ok(Parsed::Help(_))));
        match app().parse(&argv(&["run", "--help"])) {
            Ok(Parsed::Help(h)) => assert!(h.contains("--tasks")),
            _ => panic!(),
        }
    }

    #[test]
    fn list_option() {
        let m = match app().parse(&argv(&["run", "--tasks", "1,2,3", "--out", "x"])) {
            Ok(Parsed::Run(m)) => m,
            _ => panic!(),
        };
        assert_eq!(m.u64_list("tasks").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn flag_rejects_value() {
        assert!(app().parse(&argv(&["run", "--scpp=1", "--out", "x"])).is_err());
    }
}
