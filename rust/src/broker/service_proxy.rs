//! Service Proxy: Hydra's brokering engine.
//!
//! Paper §3.1: "Service Proxy implements Hydra's brokering capabilities,
//! exposing service managers to concurrently interact with multiple cloud
//! services and HPC batch systems. Further, the Service Proxy maps
//! workloads to each service manager and monitors each manager and
//! workload at runtime."
//!
//! Concurrency model: one OS thread per acquired provider; each thread
//! owns that provider's service manager — instantiated through the
//! [`ManagerFactory`], the codebase's single `ServiceKind` dispatch — and
//! executes its share of the workload independently. Reports flow back
//! over a channel; the proxy aggregates them into the paper's
//! per-provider and aggregate metrics. The proxy itself is
//! manager-agnostic: it never matches on the service kind.

use crate::api::resource::{ResourceRequest, ServiceKind};
use crate::api::task::{TaskDescription, TaskId};
use crate::broker::data::SerializeOptions;
use crate::broker::manager::{ManagerFactory, ManagerReport};
use crate::broker::partitioner::{PartitionModel, PodBuildMode};
use crate::broker::policy::{assign, Assignment, BrokerPolicy};
use crate::broker::provider_proxy::{ProviderProxy, ProxyError};
use crate::broker::state::TaskRegistry;
use crate::metrics::{aggregate, AggregateMetrics, RunMetrics};
use crate::sim::provider::ProviderId;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Outcome of one brokered workload execution.
#[derive(Debug)]
pub struct BrokerRun {
    pub assignment: Assignment,
    pub reports: BTreeMap<ProviderId, ManagerReport>,
    pub aggregate: AggregateMetrics,
}

impl BrokerRun {
    pub fn per_provider(&self) -> Vec<&RunMetrics> {
        self.reports.values().map(|r| r.metrics()).collect()
    }
}

/// Broker-level failures. `#[non_exhaustive]`: new managers and proxies
/// may surface new failure classes without a breaking change.
#[non_exhaustive]
#[derive(Debug)]
pub enum BrokerError {
    Policy(crate::broker::policy::PolicyError),
    /// Provider bring-up failed (credentials, duplicate/disabled config).
    Provider(ProxyError),
    Resource(String),
    Manager { provider: ProviderId, message: String },
    Thread(String),
}

impl std::fmt::Display for BrokerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BrokerError::Policy(e) => write!(f, "policy error: {e}"),
            BrokerError::Provider(e) => write!(f, "provider error: {e}"),
            BrokerError::Resource(m) => write!(f, "resource error: {m}"),
            BrokerError::Manager { provider, message } => {
                write!(f, "{provider} manager failed: {message}")
            }
            BrokerError::Thread(m) => write!(f, "manager thread panicked: {m}"),
        }
    }
}

impl std::error::Error for BrokerError {}

impl From<crate::broker::policy::PolicyError> for BrokerError {
    fn from(e: crate::broker::policy::PolicyError) -> Self {
        BrokerError::Policy(e)
    }
}

impl From<ProxyError> for BrokerError {
    fn from(e: ProxyError) -> Self {
        BrokerError::Provider(e)
    }
}

/// The proxy: validated providers + acquired resources + policy knobs.
pub struct ServiceProxy {
    pub providers: ProviderProxy,
    pub resources: BTreeMap<ProviderId, ResourceRequest>,
    pub partition_model: PartitionModel,
    pub build_mode: PodBuildMode,
    /// Serialize-phase fan-out for every manager (ISSUE 3 tentpole);
    /// defaults to available parallelism, `1` = serial reference path.
    pub serialize: SerializeOptions,
    pub registry: TaskRegistry,
    pub seed: u64,
}

impl ServiceProxy {
    pub fn new(providers: ProviderProxy) -> ServiceProxy {
        ServiceProxy {
            providers,
            resources: BTreeMap::new(),
            partition_model: PartitionModel::Mcpp { max_cpp: 16 },
            build_mode: PodBuildMode::Memory,
            serialize: SerializeOptions::default(),
            registry: TaskRegistry::new(),
            seed: 0x48_59_44_52, // "HYDR"
        }
    }

    /// Acquire resources on one provider (validates the request).
    pub fn acquire(&mut self, req: ResourceRequest) -> Result<(), BrokerError> {
        req.validate().map_err(BrokerError::Resource)?;
        if self.providers.handle(req.provider).is_none() {
            return Err(BrokerError::Resource(format!(
                "provider {} not connected",
                req.provider
            )));
        }
        self.resources.insert(req.provider, req);
        Ok(())
    }

    pub fn with_partition_model(mut self, m: PartitionModel) -> Self {
        self.partition_model = m;
        self
    }

    pub fn with_build_mode(mut self, b: PodBuildMode) -> Self {
        self.build_mode = b;
        self
    }

    pub fn with_serialize(mut self, serialize: SerializeOptions) -> Self {
        self.serialize = serialize;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Broker a workload: register, bind by policy, execute concurrently
    /// on every assigned provider, aggregate.
    ///
    /// Manager instantiation goes through the [`ManagerFactory`] — the
    /// proxy has no per-service code path of its own.
    ///
    /// §Perf data path: descriptions are moved into the registry once and
    /// shared from there as `Arc` handles — binding, slicing, and every
    /// manager thread bump a refcount instead of deep-cloning
    /// `TaskDescription`s per hop.
    pub fn run(
        &self,
        descs: Vec<TaskDescription>,
        policy: &BrokerPolicy,
    ) -> Result<BrokerRun, BrokerError> {
        let tasks: Vec<(TaskId, Arc<TaskDescription>)> =
            self.registry.register_all_shared(descs);

        let acquired: Vec<(ProviderId, ServiceKind)> =
            self.resources.iter().map(|(p, r)| (*p, r.service)).collect();
        let assignment = assign(policy, &tasks, &acquired)?;

        // Index description handles for per-provider slices.
        let by_id: BTreeMap<u64, Arc<TaskDescription>> =
            tasks.iter().map(|(id, t)| (id.0, Arc::clone(t))).collect();

        // §Perf: each per-provider manager thread fans its serialize
        // phase out; dividing the *auto* default across the concurrent
        // managers keeps the total near available parallelism instead of
        // providers × cores (an explicit thread count is respected as
        // given — `threads == 1` stays the serial reference path).
        let active = assignment.values().filter(|ids| !ids.is_empty()).count().max(1);
        let serialize = if self.serialize.threads == 0 {
            // 0 = auto: resolve to available parallelism, then split it.
            SerializeOptions::with_threads((self.serialize.effective_threads() / active).max(1))
        } else {
            self.serialize
        };

        // The single dispatch: every manager is built through the factory,
        // regardless of service kind.
        let factory =
            ManagerFactory::new(self.partition_model, self.build_mode.clone(), serialize);

        let (tx, rx) = mpsc::channel::<(ProviderId, Result<ManagerReport, String>)>();
        let mut threads = Vec::new();
        let mut expected = 0usize;

        for (&provider, task_ids) in &assignment {
            if task_ids.is_empty() {
                continue;
            }
            expected += 1;
            let slice: Vec<(TaskId, Arc<TaskDescription>)> = task_ids
                .iter()
                .map(|id| (*id, Arc::clone(by_id.get(&id.0).unwrap())))
                .collect();
            let req = self.resources.get(&provider).unwrap().clone();
            let cfg = self.providers.handle(provider).unwrap().config.clone();
            let registry = self.registry.clone();
            let factory = factory.clone();
            let seed = self.seed ^ (provider as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let tx = tx.clone();
            threads.push(std::thread::spawn(move || {
                let result = factory
                    .create(cfg, req, seed)
                    .and_then(|m| m.execute(&slice, &registry))
                    .map(ManagerReport::from)
                    .map_err(|e| e.to_string());
                let _ = tx.send((provider, result));
            }));
        }
        drop(tx);

        let mut reports = BTreeMap::new();
        let mut first_error: Option<BrokerError> = None;
        for _ in 0..expected {
            match rx.recv() {
                Ok((provider, Ok(report))) => {
                    reports.insert(provider, report);
                }
                Ok((provider, Err(message))) => {
                    first_error
                        .get_or_insert(BrokerError::Manager { provider, message });
                }
                Err(e) => {
                    first_error.get_or_insert(BrokerError::Thread(e.to_string()));
                }
            }
        }
        for t in threads {
            t.join().map_err(|_| BrokerError::Thread("join failed".into()))?;
        }
        if let Some(e) = first_error {
            return Err(e);
        }

        let metrics: Vec<RunMetrics> = reports.values().map(|r| r.metrics().clone()).collect();
        let agg = aggregate(&metrics).ok_or_else(|| {
            BrokerError::Resource("workload assigned to zero providers".into())
        })?;
        Ok(BrokerRun { assignment, reports, aggregate: agg })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::Payload;

    fn proxy_clouds() -> ServiceProxy {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&ProviderId::CLOUDS));
        for p in ProviderId::CLOUDS {
            sp.acquire(ResourceRequest::kubernetes(p, 1, 16)).unwrap();
        }
        sp
    }

    fn containers(n: usize) -> Vec<TaskDescription> {
        (0..n)
            .map(|i| TaskDescription::container(format!("t{i}"), "noop:latest"))
            .collect()
    }

    #[test]
    fn cross_provider_run_aggregates() {
        let sp = proxy_clouds();
        let run = sp.run(containers(400), &BrokerPolicy::RoundRobin).unwrap();
        assert_eq!(run.reports.len(), 4);
        assert_eq!(run.aggregate.tasks, 400);
        for m in run.per_provider() {
            assert_eq!(m.tasks, 100);
            assert!(m.tpt_s > 0.0);
        }
        assert!(sp.registry.all_final());
    }

    #[test]
    fn concurrency_adds_no_broker_overhead() {
        // Exp 2's finding: running four managers concurrently does not add
        // broker-side overhead — each provider's OVH matches the
        // single-provider case, and the aggregate window is bounded by the
        // total work. (The paper's 4x aggregate-TH speedup additionally
        // needs >= 4 cores; this testbed has 1, so benches/exp2.rs reports
        // both the wall-clock and the sum-of-providers throughput — see
        // EXPERIMENTS.md.)
        let mut single = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]));
        single.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
        let ovh1 = single
            .run(containers(2000), &BrokerPolicy::RoundRobin)
            .unwrap()
            .aggregate
            .ovh_s;
        let sp = proxy_clouds();
        let run = sp.run(containers(8000), &BrokerPolicy::RoundRobin).unwrap();
        // Aggregate window must not exceed the serialized total by more
        // than scheduling noise: concurrent != more work.
        assert!(
            run.aggregate.ovh_s < ovh1 * 4.0 * 2.0,
            "concurrent OVH window {} vs single {ovh1}",
            run.aggregate.ovh_s
        );
        // And each provider's own OVH stays in the regime of the
        // single-provider run (no cross-manager interference).
        for m in run.per_provider() {
            assert!(
                m.ovh.total_s() < ovh1 * 12.0, // 1-core testbed: threads time-slice
                "{}: OVH {} vs single {ovh1}",
                m.provider,
                m.ovh.total_s()
            );
        }
    }

    #[test]
    fn mixed_cloud_hpc_run() {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[
            ProviderId::Aws,
            ProviderId::Bridges2,
        ]));
        sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
        sp.acquire(ResourceRequest::pilot(ProviderId::Bridges2, 1)).unwrap();
        let mut tasks = containers(60);
        tasks.extend((0..60).map(|i| {
            TaskDescription::executable(format!("e{i}"), "sleep")
                .with_payload(Payload::Sleep(1.0))
        }));
        let run = sp.run(tasks, &BrokerPolicy::ByTaskKind).unwrap();
        assert_eq!(run.reports.len(), 2);
        assert!(matches!(run.reports[&ProviderId::Aws], ManagerReport::Caas(_)));
        assert!(matches!(run.reports[&ProviderId::Bridges2], ManagerReport::Hpc(_)));
        assert_eq!(run.aggregate.tasks, 120);
    }

    #[test]
    fn serialize_knob_does_not_change_payload_bytes() {
        let run_with = |threads: usize| {
            let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]))
                .with_serialize(SerializeOptions::with_threads(threads));
            sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
            let run = sp.run(containers(500), &BrokerPolicy::RoundRobin).unwrap();
            match &run.reports[&ProviderId::Aws] {
                ManagerReport::Caas(r) => (r.bytes_serialized, r.bulk_bytes),
                _ => unreachable!("kubernetes resource runs CaaS"),
            }
        };
        let serial = run_with(1);
        assert!(serial.1 > serial.0);
        assert_eq!(serial, run_with(8));
    }

    #[test]
    fn faas_resource_runs_through_the_open_dispatch() {
        // A FaaS workload submitted through the proxy completes with
        // byte-identical bulk payloads for any serialize_threads value
        // (the ISSUE 4 acceptance guarantee).
        let run_with = |threads: usize| {
            let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]))
                .with_serialize(SerializeOptions::with_threads(threads));
            sp.acquire(ResourceRequest::faas(ProviderId::Aws, 64)).unwrap();
            let descs: Vec<TaskDescription> = (0..500)
                .map(|i| TaskDescription::function(format!("f{i}"), "pkg.handler"))
                .collect();
            let run = sp.run(descs, &BrokerPolicy::RoundRobin).unwrap();
            assert!(sp.registry.all_final());
            match &run.reports[&ProviderId::Aws] {
                ManagerReport::Faas(r) => (r.bytes_serialized, r.bulk_bytes),
                _ => unreachable!("faas resource runs FaaS"),
            }
        };
        let serial = run_with(1);
        assert!(serial.1 > serial.0);
        for threads in [2, 8] {
            assert_eq!(serial, run_with(threads), "threads={threads}");
        }
    }

    #[test]
    fn acquire_validates_connection_and_request() {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]));
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Azure, 1, 8)).is_err());
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 0, 8)).is_err());
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8)).is_ok());
    }

    #[test]
    fn empty_provider_slices_are_skipped() {
        let sp = proxy_clouds();
        // 2 tasks across 4 providers: two providers get nothing.
        let run = sp.run(containers(2), &BrokerPolicy::RoundRobin).unwrap();
        assert_eq!(run.reports.len(), 2);
        assert_eq!(run.aggregate.tasks, 2);
    }

    #[test]
    fn policy_errors_surface() {
        let sp = proxy_clouds();
        let e = sp.run(containers(1), &BrokerPolicy::ExplicitOnly).unwrap_err();
        assert!(matches!(e, BrokerError::Policy(_)));
    }
}
