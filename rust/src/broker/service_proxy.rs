//! Service Proxy: Hydra's brokering engine.
//!
//! Paper §3.1: "Service Proxy implements Hydra's brokering capabilities,
//! exposing service managers to concurrently interact with multiple cloud
//! services and HPC batch systems. Further, the Service Proxy maps
//! workloads to each service manager and monitors each manager and
//! workload at runtime."
//!
//! Concurrency model: one OS thread per acquired provider; each thread
//! owns that provider's service manager — instantiated through the
//! [`ManagerFactory`], the codebase's single `ServiceKind` dispatch — and
//! executes its share of the workload independently. Reports flow back
//! over a channel; the proxy aggregates them into the paper's
//! per-provider and aggregate metrics. The proxy itself is
//! manager-agnostic: it never matches on the service kind.
//!
//! Cross-provider failover (ISSUE 7): when a manager run fails with a
//! *retryable* error — the provider control plane rejected the bulk
//! submit after retries, or its circuit breaker opened — the proxy
//! rewinds the stranded slice ([`TaskRegistry::requeue_for_failover`])
//! and re-brokers it to a surviving provider offering the same service
//! kind ([`failover_targets`]), through the normal factory path. A
//! broker-level exactly-once ledger books which provider resolved each
//! task; a double booking is a broker bug surfaced as
//! [`BrokerError::DoubleCompletion`]. Slices with no surviving target
//! are canceled and reported in [`BrokerRun::abandoned`].

use crate::api::resource::{ResourceRequest, ServiceKind};
use crate::api::task::{TaskDescription, TaskId, TaskState};
use crate::broker::data::SerializeOptions;
use crate::broker::manager::{ManagerError, ManagerFactory, ManagerReport};
use crate::broker::partitioner::{PartitionModel, PodBuildMode};
use crate::broker::policy::{assign, failover_targets, Assignment, BrokerPolicy};
use crate::broker::provider_proxy::{ProviderProxy, ProxyError};
use crate::broker::state::TaskRegistry;
use crate::metrics::{aggregate, AggregateMetrics, RunMetrics};
use crate::sim::provider::ProviderId;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Seed salt for a failover leg: the re-brokered slice draws a stream
/// decorrelated from the target's primary run on the same broker seed.
const FAILOVER_SEED_SALT: u64 = 0x0F_A1_10_7E;

/// One completed failover leg: `tasks` tasks moved `from` → `to`, with
/// the target's full manager report (its `faults.failed_over` counts the
/// re-brokered tasks).
#[derive(Debug)]
pub struct Failover {
    pub from: ProviderId,
    pub to: ProviderId,
    pub tasks: usize,
    pub report: ManagerReport,
}

/// Outcome of one brokered workload execution.
#[derive(Debug)]
pub struct BrokerRun {
    pub assignment: Assignment,
    pub reports: BTreeMap<ProviderId, ManagerReport>,
    /// Slices re-brokered off failed providers (ISSUE 7), in order.
    pub failovers: Vec<Failover>,
    /// Tasks canceled because no surviving compatible provider could
    /// take their slice.
    pub abandoned: Vec<TaskId>,
    pub aggregate: AggregateMetrics,
}

impl BrokerRun {
    /// Per-provider metrics: primary runs first, then failover legs.
    pub fn per_provider(&self) -> Vec<&RunMetrics> {
        self.reports
            .values()
            .map(|r| r.metrics())
            .chain(self.failovers.iter().map(|f| f.report.metrics()))
            .collect()
    }
}

/// Broker-level failures. `#[non_exhaustive]`: new managers and proxies
/// may surface new failure classes without a breaking change.
#[non_exhaustive]
#[derive(Debug)]
pub enum BrokerError {
    Policy(crate::broker::policy::PolicyError),
    /// Provider bring-up failed (credentials, duplicate/disabled config).
    Provider(ProxyError),
    Resource(String),
    /// A manager run failed terminally (the typed [`ManagerError`] rides
    /// along so callers can inspect `retryable()` / submit accounting).
    Manager { provider: ProviderId, error: ManagerError },
    Thread(String),
    /// Exactly-once violation: one task booked as resolved on two
    /// providers. Never expected — a broker bug made loud.
    DoubleCompletion { task: TaskId, first: ProviderId, second: ProviderId },
}

impl std::fmt::Display for BrokerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BrokerError::Policy(e) => write!(f, "policy error: {e}"),
            BrokerError::Provider(e) => write!(f, "provider error: {e}"),
            BrokerError::Resource(m) => write!(f, "resource error: {m}"),
            BrokerError::Manager { provider, error } => {
                write!(f, "{provider} manager failed: {error}")
            }
            BrokerError::Thread(m) => write!(f, "manager thread panicked: {m}"),
            BrokerError::DoubleCompletion { task, first, second } => {
                write!(f, "{task} completed on both {first} and {second}")
            }
        }
    }
}

impl std::error::Error for BrokerError {}

impl From<crate::broker::policy::PolicyError> for BrokerError {
    fn from(e: crate::broker::policy::PolicyError) -> Self {
        BrokerError::Policy(e)
    }
}

impl From<ProxyError> for BrokerError {
    fn from(e: ProxyError) -> Self {
        BrokerError::Provider(e)
    }
}

/// The proxy: validated providers + acquired resources + policy knobs.
pub struct ServiceProxy {
    pub providers: ProviderProxy,
    pub resources: BTreeMap<ProviderId, ResourceRequest>,
    pub partition_model: PartitionModel,
    pub build_mode: PodBuildMode,
    /// Serialize-phase fan-out for every manager (ISSUE 3 tentpole);
    /// defaults to available parallelism, `1` = serial reference path.
    pub serialize: SerializeOptions,
    pub registry: TaskRegistry,
    pub seed: u64,
}

impl ServiceProxy {
    pub fn new(providers: ProviderProxy) -> ServiceProxy {
        ServiceProxy {
            providers,
            resources: BTreeMap::new(),
            partition_model: PartitionModel::Mcpp { max_cpp: 16 },
            build_mode: PodBuildMode::Memory,
            serialize: SerializeOptions::default(),
            registry: TaskRegistry::new(),
            seed: 0x48_59_44_52, // "HYDR"
        }
    }

    /// Acquire resources on one provider (validates the request).
    pub fn acquire(&mut self, req: ResourceRequest) -> Result<(), BrokerError> {
        req.validate().map_err(BrokerError::Resource)?;
        if self.providers.handle(req.provider).is_none() {
            return Err(BrokerError::Resource(format!(
                "provider {} not connected",
                req.provider
            )));
        }
        self.resources.insert(req.provider, req);
        Ok(())
    }

    pub fn with_partition_model(mut self, m: PartitionModel) -> Self {
        self.partition_model = m;
        self
    }

    pub fn with_build_mode(mut self, b: PodBuildMode) -> Self {
        self.build_mode = b;
        self
    }

    pub fn with_serialize(mut self, serialize: SerializeOptions) -> Self {
        self.serialize = serialize;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Broker a workload: register, bind by policy, execute concurrently
    /// on every assigned provider, aggregate.
    ///
    /// Manager instantiation goes through the [`ManagerFactory`] — the
    /// proxy has no per-service code path of its own.
    ///
    /// §Perf data path: descriptions are moved into the registry once and
    /// shared from there as `Arc` handles — binding, slicing, and every
    /// manager thread bump a refcount instead of deep-cloning
    /// `TaskDescription`s per hop.
    pub fn run(
        &self,
        descs: Vec<TaskDescription>,
        policy: &BrokerPolicy,
    ) -> Result<BrokerRun, BrokerError> {
        let tasks: Vec<(TaskId, Arc<TaskDescription>)> =
            self.registry.register_all_shared(descs);

        let acquired: Vec<(ProviderId, ServiceKind)> =
            self.resources.iter().map(|(p, r)| (*p, r.service)).collect();
        let assignment = assign(policy, &tasks, &acquired)?;

        // Index description handles for per-provider slices.
        let by_id: BTreeMap<u64, Arc<TaskDescription>> =
            tasks.iter().map(|(id, t)| (id.0, Arc::clone(t))).collect();

        // §Perf: each per-provider manager thread fans its serialize
        // phase out; dividing the *auto* default across the concurrent
        // managers keeps the total near available parallelism instead of
        // providers × cores (an explicit thread count is respected as
        // given — `threads == 1` stays the serial reference path).
        let active = assignment.values().filter(|ids| !ids.is_empty()).count().max(1);
        let serialize = if self.serialize.threads == 0 {
            // 0 = auto: resolve to available parallelism, then split it.
            SerializeOptions::with_threads((self.serialize.effective_threads() / active).max(1))
        } else {
            self.serialize
        };

        // The single dispatch: every manager is built through the factory,
        // regardless of service kind.
        let factory =
            ManagerFactory::new(self.partition_model, self.build_mode.clone(), serialize);

        let (tx, rx) = mpsc::channel::<(ProviderId, Result<ManagerReport, ManagerError>)>();
        let mut threads = Vec::new();
        let mut expected = 0usize;

        for (&provider, task_ids) in &assignment {
            if task_ids.is_empty() {
                continue;
            }
            expected += 1;
            let slice: Vec<(TaskId, Arc<TaskDescription>)> = task_ids
                .iter()
                .map(|id| (*id, Arc::clone(by_id.get(&id.0).unwrap())))
                .collect();
            let req = self.resources.get(&provider).unwrap().clone();
            let handle = self.providers.handle(provider).unwrap();
            let cfg = handle.config.clone();
            // Shared with the ProviderHandle: trips accumulated here are
            // visible to the failover target-selection below.
            let breaker = handle.breaker.clone();
            let registry = self.registry.clone();
            let factory = factory.clone();
            let seed = self.seed ^ (provider as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let tx = tx.clone();
            threads.push(std::thread::spawn(move || {
                let result = factory
                    .create_with_breaker(cfg, req, seed, breaker)
                    .and_then(|m| m.execute(&slice, &registry))
                    .map(ManagerReport::from);
                let _ = tx.send((provider, result));
            }));
        }
        drop(tx);

        // Exactly-once ledger: which provider resolved each task. Every
        // booking must be the first — a second is a broker bug.
        let mut ledger: BTreeMap<u64, ProviderId> = BTreeMap::new();
        let book = |ledger: &mut BTreeMap<u64, ProviderId>,
                        ids: &[TaskId],
                        provider: ProviderId|
         -> Result<(), BrokerError> {
            for id in ids {
                if let Some(first) = ledger.insert(id.0, provider) {
                    return Err(BrokerError::DoubleCompletion {
                        task: *id,
                        first,
                        second: provider,
                    });
                }
            }
            Ok(())
        };

        let mut reports = BTreeMap::new();
        let mut failed_runs: Vec<(ProviderId, ManagerError)> = Vec::new();
        let mut first_error: Option<BrokerError> = None;
        for _ in 0..expected {
            match rx.recv() {
                Ok((provider, Ok(report))) => {
                    book(&mut ledger, &assignment[&provider], provider)?;
                    reports.insert(provider, report);
                }
                Ok((provider, Err(error))) if error.retryable() => {
                    failed_runs.push((provider, error));
                }
                Ok((provider, Err(error))) => {
                    first_error.get_or_insert(BrokerError::Manager { provider, error });
                }
                Err(e) => {
                    first_error.get_or_insert(BrokerError::Thread(e.to_string()));
                }
            }
        }
        for t in threads {
            t.join().map_err(|_| BrokerError::Thread("join failed".into()))?;
        }
        if let Some(e) = first_error {
            return Err(e);
        }

        // §Failover: re-broker each stranded slice to a surviving provider
        // of the same service kind, through the normal factory path.
        failed_runs.sort_by_key(|(p, _)| *p);
        let failed_set: Vec<ProviderId> = failed_runs.iter().map(|(p, _)| *p).collect();
        let mut failovers: Vec<Failover> = Vec::new();
        let mut abandoned: Vec<TaskId> = Vec::new();
        for (failed, _error) in &failed_runs {
            let ids = &assignment[failed];
            // Manager submit errors fire before any task reaches a final
            // state, so the whole slice is rewindable; a final task here
            // would mean a double execution and fails the batch loudly.
            self.registry
                .requeue_for_failover(ids)
                .map_err(|e| BrokerError::Resource(e.to_string()))?;
            let slice: Vec<(TaskId, Arc<TaskDescription>)> = ids
                .iter()
                .map(|id| (*id, Arc::clone(by_id.get(&id.0).unwrap())))
                .collect();
            let kind = self.resources[failed].service;
            let mut landed = false;
            for target in failover_targets(*failed, kind, &acquired) {
                let handle = self.providers.handle(target).unwrap();
                if failed_set.contains(&target) || handle.breaker.is_open() {
                    continue;
                }
                let cfg = handle.config.clone();
                let breaker = handle.breaker.clone();
                let req = self.resources[&target].clone();
                let seed = self.seed
                    ^ (target as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ FAILOVER_SEED_SALT;
                match factory
                    .create_with_breaker(cfg, req, seed, breaker)
                    .and_then(|m| m.execute(&slice, &self.registry))
                {
                    Ok(mut run) => {
                        run.faults.failed_over = slice.len();
                        book(&mut ledger, ids, target)?;
                        failovers.push(Failover {
                            from: *failed,
                            to: target,
                            tasks: slice.len(),
                            report: ManagerReport::from(run),
                        });
                        landed = true;
                        break;
                    }
                    Err(e) if e.retryable() => {
                        // Target's control plane failed too; rewind and
                        // try the next compatible provider.
                        self.registry
                            .requeue_for_failover(ids)
                            .map_err(|e| BrokerError::Resource(e.to_string()))?;
                    }
                    Err(error) => {
                        return Err(BrokerError::Manager { provider: target, error });
                    }
                }
            }
            if !landed {
                self.registry
                    .transition_all(ids, TaskState::Canceled)
                    .map_err(|e| BrokerError::Resource(e.to_string()))?;
                abandoned.extend(ids.iter().copied());
            }
        }

        let metrics: Vec<RunMetrics> = reports
            .values()
            .map(|r| r.metrics().clone())
            .chain(failovers.iter().map(|f| f.report.metrics().clone()))
            .collect();
        let agg = aggregate(&metrics).ok_or_else(|| {
            BrokerError::Resource(if abandoned.is_empty() {
                "workload assigned to zero providers".into()
            } else {
                "every provider failed; workload abandoned".into()
            })
        })?;
        Ok(BrokerRun { assignment, reports, failovers, abandoned, aggregate: agg })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::Payload;

    fn proxy_clouds() -> ServiceProxy {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&ProviderId::CLOUDS));
        for p in ProviderId::CLOUDS {
            sp.acquire(ResourceRequest::kubernetes(p, 1, 16)).unwrap();
        }
        sp
    }

    fn containers(n: usize) -> Vec<TaskDescription> {
        (0..n)
            .map(|i| TaskDescription::container(format!("t{i}"), "noop:latest"))
            .collect()
    }

    #[test]
    fn cross_provider_run_aggregates() {
        let sp = proxy_clouds();
        let run = sp.run(containers(400), &BrokerPolicy::RoundRobin).unwrap();
        assert_eq!(run.reports.len(), 4);
        assert_eq!(run.aggregate.tasks, 400);
        for m in run.per_provider() {
            assert_eq!(m.tasks, 100);
            assert!(m.tpt_s > 0.0);
        }
        assert!(sp.registry.all_final());
    }

    #[test]
    fn concurrency_adds_no_broker_overhead() {
        // Exp 2's finding: running four managers concurrently does not add
        // broker-side overhead — each provider's OVH matches the
        // single-provider case, and the aggregate window is bounded by the
        // total work. (The paper's 4x aggregate-TH speedup additionally
        // needs >= 4 cores; this testbed has 1, so benches/exp2.rs reports
        // both the wall-clock and the sum-of-providers throughput — see
        // EXPERIMENTS.md.)
        let mut single = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]));
        single.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
        let ovh1 = single
            .run(containers(2000), &BrokerPolicy::RoundRobin)
            .unwrap()
            .aggregate
            .ovh_s;
        let sp = proxy_clouds();
        let run = sp.run(containers(8000), &BrokerPolicy::RoundRobin).unwrap();
        // Aggregate window must not exceed the serialized total by more
        // than scheduling noise: concurrent != more work.
        assert!(
            run.aggregate.ovh_s < ovh1 * 4.0 * 2.0,
            "concurrent OVH window {} vs single {ovh1}",
            run.aggregate.ovh_s
        );
        // And each provider's own OVH stays in the regime of the
        // single-provider run (no cross-manager interference).
        for m in run.per_provider() {
            assert!(
                m.ovh.total_s() < ovh1 * 12.0, // 1-core testbed: threads time-slice
                "{}: OVH {} vs single {ovh1}",
                m.provider,
                m.ovh.total_s()
            );
        }
    }

    #[test]
    fn mixed_cloud_hpc_run() {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[
            ProviderId::Aws,
            ProviderId::Bridges2,
        ]));
        sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
        sp.acquire(ResourceRequest::pilot(ProviderId::Bridges2, 1)).unwrap();
        let mut tasks = containers(60);
        tasks.extend((0..60).map(|i| {
            TaskDescription::executable(format!("e{i}"), "sleep")
                .with_payload(Payload::Sleep(1.0))
        }));
        let run = sp.run(tasks, &BrokerPolicy::ByTaskKind).unwrap();
        assert_eq!(run.reports.len(), 2);
        assert!(matches!(run.reports[&ProviderId::Aws], ManagerReport::Caas(_)));
        assert!(matches!(run.reports[&ProviderId::Bridges2], ManagerReport::Hpc(_)));
        assert_eq!(run.aggregate.tasks, 120);
    }

    #[test]
    fn serialize_knob_does_not_change_payload_bytes() {
        let run_with = |threads: usize| {
            let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]))
                .with_serialize(SerializeOptions::with_threads(threads));
            sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
            let run = sp.run(containers(500), &BrokerPolicy::RoundRobin).unwrap();
            match &run.reports[&ProviderId::Aws] {
                ManagerReport::Caas(r) => (r.bytes_serialized, r.bulk_bytes),
                _ => unreachable!("kubernetes resource runs CaaS"),
            }
        };
        let serial = run_with(1);
        assert!(serial.1 > serial.0);
        assert_eq!(serial, run_with(8));
    }

    #[test]
    fn faas_resource_runs_through_the_open_dispatch() {
        // A FaaS workload submitted through the proxy completes with
        // byte-identical bulk payloads for any serialize_threads value
        // (the ISSUE 4 acceptance guarantee).
        let run_with = |threads: usize| {
            let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]))
                .with_serialize(SerializeOptions::with_threads(threads));
            sp.acquire(ResourceRequest::faas(ProviderId::Aws, 64)).unwrap();
            let descs: Vec<TaskDescription> = (0..500)
                .map(|i| TaskDescription::function(format!("f{i}"), "pkg.handler"))
                .collect();
            let run = sp.run(descs, &BrokerPolicy::RoundRobin).unwrap();
            assert!(sp.registry.all_final());
            match &run.reports[&ProviderId::Aws] {
                ManagerReport::Faas(r) => (r.bytes_serialized, r.bulk_bytes),
                _ => unreachable!("faas resource runs FaaS"),
            }
        };
        let serial = run_with(1);
        assert!(serial.1 > serial.0);
        for threads in [2, 8] {
            assert_eq!(serial, run_with(threads), "threads={threads}");
        }
    }

    #[test]
    fn acquire_validates_connection_and_request() {
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[ProviderId::Aws]));
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Azure, 1, 8)).is_err());
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 0, 8)).is_err());
        assert!(sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8)).is_ok());
    }

    #[test]
    fn empty_provider_slices_are_skipped() {
        let sp = proxy_clouds();
        // 2 tasks across 4 providers: two providers get nothing.
        let run = sp.run(containers(2), &BrokerPolicy::RoundRobin).unwrap();
        assert_eq!(run.reports.len(), 2);
        assert_eq!(run.aggregate.tasks, 2);
    }

    #[test]
    fn policy_errors_surface() {
        let sp = proxy_clouds();
        let e = sp.run(containers(1), &BrokerPolicy::ExplicitOnly).unwrap_err();
        assert!(matches!(e, BrokerError::Policy(_)));
    }

    #[test]
    fn dead_provider_fails_over_to_a_surviving_caas() {
        use crate::broker::data::{ProviderFaultSpec, RetryPolicy};
        // Azure's control plane is down for the whole run; its slice must
        // land on Aws exactly once.
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[
            ProviderId::Aws,
            ProviderId::Azure,
        ]));
        sp.acquire(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16)).unwrap();
        sp.acquire(
            ResourceRequest::kubernetes(ProviderId::Azure, 1, 16)
                .with_provider_faults(ProviderFaultSpec {
                    outage_window: Some((0.0, 1e9)),
                    ..ProviderFaultSpec::none()
                })
                .with_retry_policy(RetryPolicy { max_attempts: 2, ..RetryPolicy::default() }),
        )
        .unwrap();
        let run = sp.run(containers(40), &BrokerPolicy::RoundRobin).unwrap();

        assert_eq!(run.failovers.len(), 1);
        let fo = &run.failovers[0];
        assert_eq!((fo.from, fo.to), (ProviderId::Azure, ProviderId::Aws));
        assert_eq!(fo.tasks, 20);
        assert_eq!(fo.report.run().faults.failed_over, 20);
        assert!(run.abandoned.is_empty());
        // Primary reports: only Aws completed its own slice.
        assert_eq!(run.reports.len(), 1);
        assert!(run.reports.contains_key(&ProviderId::Aws));
        // Every task resolved exactly once, none stranded.
        assert_eq!(run.aggregate.tasks, 40);
        assert!(sp.registry.all_final());
        for id in run.assignment.values().flatten() {
            assert_eq!(sp.registry.state_of(*id), Some(crate::api::task::TaskState::Done));
        }
    }

    #[test]
    fn no_compatible_survivor_abandons_the_slice() {
        use crate::broker::data::{ProviderFaultSpec, RetryPolicy};
        // The only CaaS provider is down and the FaaS survivor is not a
        // compatible target: the container slice is canceled, the
        // function slice completes, and the run still returns.
        let mut sp = ServiceProxy::new(ProviderProxy::simulated(&[
            ProviderId::Aws,
            ProviderId::Azure,
        ]));
        sp.acquire(
            ResourceRequest::kubernetes(ProviderId::Azure, 1, 16)
                .with_provider_faults(ProviderFaultSpec {
                    outage_window: Some((0.0, 1e9)),
                    ..ProviderFaultSpec::none()
                })
                .with_retry_policy(RetryPolicy { max_attempts: 2, ..RetryPolicy::default() }),
        )
        .unwrap();
        sp.acquire(ResourceRequest::faas(ProviderId::Aws, 64)).unwrap();
        let mut tasks = containers(30);
        tasks.extend(
            (0..30).map(|i| TaskDescription::function(format!("f{i}"), "pkg.handler")),
        );
        let run = sp.run(tasks, &BrokerPolicy::ByTaskKind).unwrap();

        assert!(run.failovers.is_empty());
        assert_eq!(run.abandoned.len(), 30);
        for id in &run.abandoned {
            assert_eq!(sp.registry.state_of(*id), Some(crate::api::task::TaskState::Canceled));
        }
        assert_eq!(run.aggregate.tasks, 30); // the surviving FaaS slice
        assert!(sp.registry.all_final());
    }
}
