//! The Hydra broker — the paper's system contribution (§3).
//!
//! * [`provider_proxy`] — credential validation and provider bring-up.
//! * [`service_proxy`] — concurrent service managers + workload mapping.
//! * [`caas`] — CaaS Manager (Kubernetes clusters, pod workloads).
//! * [`hpc`] — HPC Manager (pilot connector, bulk task submission).
//! * [`faas`] — FaaS Manager (the §3.1 extensibility example, implemented).
//! * [`data`] — Data Manager (copy/move/link/delete/list, staging) and
//!   the bulk serialization data path (shards, framing, submit sink).
//! * [`partitioner`] — MCPP/SCPP pod partitioning + manifest building.
//! * [`policy`] — task→provider binding policies.
//! * [`state`] — task registry, state machine, tracing.
//!
//! [`Hydra`] is the user-facing facade combining all of the above.

pub mod caas;
pub mod data;
pub mod faas;
pub mod hpc;
pub mod partitioner;
pub mod policy;
pub mod provider_proxy;
pub mod service_proxy;
pub mod state;

use crate::api::resource::ResourceRequest;
use crate::api::task::TaskDescription;
use crate::api::ProviderConfig;
use crate::sim::provider::ProviderId;
pub use data::SerializeOptions;
pub use partitioner::{PartitionModel, PodBuildMode};
pub use policy::BrokerPolicy;
pub use service_proxy::{BrokerError, BrokerRun, ServiceProxy};

/// User-facing facade: configure providers + resources, then submit
/// workloads.
///
/// ```no_run
/// use hydra::broker::{Hydra, BrokerPolicy};
/// use hydra::api::{ResourceRequest, TaskDescription};
/// use hydra::sim::provider::ProviderId;
///
/// let hydra = Hydra::builder()
///     .simulated_provider(ProviderId::Aws)
///     .resource(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8))
///     .build()
///     .unwrap();
/// let tasks = (0..32)
///     .map(|i| TaskDescription::container(format!("t{i}"), "noop:latest"))
///     .collect();
/// let run = hydra.submit(tasks, &BrokerPolicy::RoundRobin).unwrap();
/// assert_eq!(run.aggregate.tasks, 32);
/// ```
pub struct Hydra {
    proxy: ServiceProxy,
}

/// Builder for [`Hydra`].
#[derive(Default)]
pub struct HydraBuilder {
    configs: Vec<ProviderConfig>,
    resources: Vec<ResourceRequest>,
    partition_model: Option<PartitionModel>,
    build_mode: Option<PodBuildMode>,
    serialize: Option<SerializeOptions>,
    seed: Option<u64>,
}

impl HydraBuilder {
    pub fn provider(mut self, cfg: ProviderConfig) -> Self {
        self.configs.push(cfg);
        self
    }

    pub fn simulated_provider(mut self, id: ProviderId) -> Self {
        self.configs.push(ProviderConfig::simulated(id));
        self
    }

    pub fn resource(mut self, req: ResourceRequest) -> Self {
        self.resources.push(req);
        self
    }

    pub fn partition_model(mut self, m: PartitionModel) -> Self {
        self.partition_model = Some(m);
        self
    }

    pub fn build_mode(mut self, b: PodBuildMode) -> Self {
        self.build_mode = Some(b);
        self
    }

    /// Serialize-phase fan-out for every manager: `1` = serial reference
    /// path, `0` = available parallelism (the default). The bulk payload
    /// bytes are identical for any value (ISSUE 3 tentpole guarantee).
    pub fn serialize_threads(mut self, threads: usize) -> Self {
        self.serialize = Some(SerializeOptions::with_threads(threads));
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn build(self) -> Result<Hydra, BrokerError> {
        let providers = provider_proxy::ProviderProxy::connect(self.configs)
            .map_err(|e| BrokerError::Resource(e.to_string()))?;
        let mut proxy = ServiceProxy::new(providers);
        if let Some(m) = self.partition_model {
            proxy.partition_model = m;
        }
        if let Some(b) = self.build_mode {
            proxy.build_mode = b;
        }
        if let Some(s) = self.serialize {
            proxy.serialize = s;
        }
        if let Some(s) = self.seed {
            proxy.seed = s;
        }
        for r in self.resources {
            proxy.acquire(r)?;
        }
        Ok(Hydra { proxy })
    }
}

impl Hydra {
    pub fn builder() -> HydraBuilder {
        HydraBuilder::default()
    }

    /// Broker one workload across the acquired resources.
    pub fn submit(
        &self,
        tasks: Vec<TaskDescription>,
        policy: &BrokerPolicy,
    ) -> Result<BrokerRun, BrokerError> {
        self.proxy.run(tasks, policy)
    }

    pub fn registry(&self) -> &state::TaskRegistry {
        &self.proxy.registry
    }

    pub fn service_proxy(&self) -> &ServiceProxy {
        &self.proxy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facade_end_to_end() {
        let hydra = Hydra::builder()
            .simulated_provider(ProviderId::Jetstream2)
            .simulated_provider(ProviderId::Bridges2)
            .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
            .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
            .partition_model(PartitionModel::Scpp)
            .seed(99)
            .build()
            .unwrap();
        let mut tasks: Vec<TaskDescription> = (0..40)
            .map(|i| TaskDescription::container(format!("c{i}"), "noop:latest"))
            .collect();
        tasks.extend((0..40).map(|i| TaskDescription::executable(format!("e{i}"), "noop")));
        let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
        assert_eq!(run.aggregate.tasks, 80);
        assert!(hydra.registry().all_final());
        assert!(hydra.registry().trace_len() >= 80 * 6);
    }

    #[test]
    fn build_fails_without_valid_providers() {
        assert!(Hydra::builder().build().is_err());
    }
}
