//! The Hydra broker — the paper's system contribution (§3).
//!
//! The broker is organized around an **open manager interface**: every
//! workload manager implements the [`ServiceManager`] trait and reports
//! the same unified [`ManagerRun`] shape, and the [`ManagerFactory`]
//! holds the codebase's one and only `ServiceKind` → manager dispatch.
//! Both brokered runs ([`ServiceProxy::run`]) and workflow waves
//! (`workflow::engine`) build their managers through that factory, so a
//! new service kind — the paper's §3.1 "for example, a Function as a
//! Service manager", shipped here as the [`faas`] module — lands as one
//! enum variant, one trait impl, and one factory arm.
//!
//! * [`provider_proxy`] — credential validation and provider bring-up.
//! * [`service_proxy`] — workload mapping + one manager thread per
//!   provider, aggregation of the unified reports.
//! * [`manager`] — the [`ServiceManager`] trait, unified
//!   [`ManagerRun`]/[`RunDetail`] reports, and the [`ManagerFactory`].
//! * [`caas`] — CaaS Manager (Kubernetes clusters, pod workloads).
//! * [`hpc`] — HPC Manager (pilot connector, bulk task submission,
//!   fault-tolerant pilot fleets: a `FaultSpec` on the acquired
//!   `ResourceRequest` arms pilot death / walltime expiry /
//!   materialization failure, with exactly-once re-queue onto survivors
//!   and per-retry-wave transport accounting in `ManagerRun::faults`).
//! * [`faas`] — FaaS Manager (functions with cold starts + concurrency
//!   limits).
//! * [`data`] — Data Manager (copy/move/link/delete/list, staging) and
//!   the bulk serialization data path (shards, framing, and the
//!   fallible [`ProviderEndpoint`] submit path: outages, transient
//!   errors, throttling, retry/backoff).
//! * [`partitioner`] — MCPP/SCPP pod partitioning + manifest building.
//! * [`policy`] — task→provider binding policies (kind-aware routing
//!   across CaaS/Batch/FaaS services) and failover target selection.
//! * [`state`] — task registry, state machine, tracing.
//!
//! # Failure model: provider layer
//!
//! Mirroring the pilot-layer failure model in `sim/hpc.rs` (ISSUE 6),
//! the *provider control plane* is fallible too (ISSUE 7):
//!
//! * **Faults** — a [`ProviderFaultSpec`] carried on the acquired
//!   `ResourceRequest` arms an outage window, a per-attempt transient
//!   error probability, and a byte-budget throttle on the provider's
//!   bulk-submit endpoint. Fault draws come from a dedicated PRNG
//!   stream (`PROVIDER_FAULT_STREAM_SALT`) so the healthy path
//!   (`ProviderFaultSpec::none()`) consumes nothing and stays
//!   byte-identical to the pre-fault broker.
//! * **Retry/backoff** — every manager drives its submits through a
//!   [`ProviderEndpoint`] governed by a [`RetryPolicy`]: exponential
//!   backoff with seeded jitter, an attempt cap, and a total-backoff
//!   deadline. Simulated backoff time is charged into the run's OVH.
//! * **Circuit breaker** — each connected `ProviderHandle` carries a
//!   shared [`CircuitBreaker`] (closed → open after K consecutive
//!   failures → half-open probe). While open, submits fast-fail
//!   instead of burning attempts.
//! * **Failover** — on a terminal submit error the `ServiceProxy`
//!   rewinds the stranded slice and re-brokers it to a surviving
//!   provider of the same service kind, guarded by a broker-level
//!   exactly-once ledger; slices with no survivor are canceled and
//!   reported as abandoned. Per-run accounting lands in
//!   `ManagerRun::faults` (`submit_retries` / `backoff_ms` /
//!   `circuit_opens` / `failed_over`).
//!
//! # Ingest layer: provider acks (ISSUE 10)
//!
//! The transport is no longer write-only. Every accepted bulk payload is
//! answered by a deterministic **ack** —
//! `{"ack":"hydra/v1","count":N,"bytes":B,"first_id":…,"last_id":…}` — a
//! pure function of the accepted payload bytes
//! ([`data::provider_ack`], no PRNG, no clock, so the healthy path stays
//! byte- and draw-identical to the pre-ack broker), returned alongside
//! the byte count by [`ProviderEndpoint::submit_acked`]. Each manager
//! scans the ack with the zero-alloc lazy scanner
//! (`util::json_scan::JsonScanner` — single pass, no recursion, no tree
//! materialized) and verifies it against what it framed: item count plus
//! first/last id spot-checks (`hydra/pod-id` labels for CaaS,
//! `payload.hydra_task_id` for FaaS, `uid` strings for HPC, including
//! retry waves). The scan runs inside the submit stopwatch window, so
//! verification cost is charged into OVH like every other broker-side
//! cost. A disagreement means an already-accepted payload was corrupted
//! in flight: [`ManagerError::AckMismatch`], **never retryable** —
//! resubmitting accepted work would duplicate it; failover/re-brokering
//! policy is the caller's call. The tree parser (`util::json::parse`)
//! and the scanner are locked together by `tests/json_equivalence.rs`
//! (differential accept/reject + extraction properties), so the ingest
//! path can never drift from the document model the rest of the crate
//! writes and parses.
//!
//! # Determinism invariants
//!
//! Every headline claim in this repo — byte-identical reference paths
//! (LinearScan vs indexed scheduler, heap vs calendar queue, serial vs
//! multi-pilot HPC, `FaultSpec::none()` vs the pre-fault broker) and
//! the exactly-once properties — rests on the simulation being a pure
//! function of `(workload, config, seed)`. Four rules keep it that way,
//! and `hydra-lint` (ISSUE 9, `cargo run --release --bin hydra_lint`)
//! enforces them statically in CI:
//!
//! * **No wall-clock in library code.** `Instant::now`/`SystemTime`
//!   only at the measurement boundary (`util::Stopwatch`, metrics trace
//!   epochs) — never inside simulation or broker logic, where it would
//!   leak host timing into results.
//! * **No observable `HashMap`/`HashSet` iteration order** in
//!   `src/{sim,broker,workflow,facts}/`. Iterate a `BTreeMap` (see
//!   [`state::TaskRegistry`]'s task table), or collect-and-sort before
//!   anything downstream can observe the order.
//! * **Salted, documented PRNG streams.** Every derived stream salts
//!   the user seed with a crate-unique constant (e.g.
//!   `PROVIDER_FAULT_STREAM_SALT`), so arming one fault model never
//!   shifts another's draws. `hydra-lint` checks salt uniqueness
//!   crate-wide.
//! * **No panics, no float `==`.** Library code returns `Result`
//!   (ratcheted down via `rust/ci/lint_baseline.json`), and floats are
//!   compared against literals only for documented exact sentinels
//!   (suppressed case by case with `// hydra-lint: allow(float-eq)`
//!   pragmas).
//!
//! [`Hydra`] is the user-facing facade combining all of the above.

pub mod caas;
pub mod data;
pub mod faas;
pub mod hpc;
pub mod manager;
pub mod partitioner;
pub mod policy;
pub mod provider_proxy;
pub mod service_proxy;
pub mod state;

use crate::api::resource::ResourceRequest;
use crate::api::task::TaskDescription;
use crate::api::ProviderConfig;
use crate::sim::provider::ProviderId;
pub use data::{
    ProviderEndpoint, ProviderFaultSpec, RetryPolicy, SerializeOptions, SubmitReceipt,
};
pub use manager::{
    ManagerError, ManagerFactory, ManagerReport, ManagerRun, RunDetail, ServiceManager,
};
pub use partitioner::{PartitionModel, PodBuildMode};
pub use policy::BrokerPolicy;
pub use provider_proxy::{CircuitBreaker, CircuitState};
pub use service_proxy::{BrokerError, BrokerRun, Failover, ServiceProxy};

/// User-facing facade: configure providers + resources, then submit
/// workloads.
///
/// Each acquired resource names a service kind (CaaS cluster, HPC pilot,
/// FaaS function service); at submit time the broker binds tasks to
/// providers by policy and drives one [`ServiceManager`] per provider,
/// instantiated through the [`ManagerFactory`]. All managers report the
/// unified [`ManagerRun`] shape.
///
/// ```no_run
/// use hydra::broker::{Hydra, BrokerPolicy};
/// use hydra::api::{ResourceRequest, TaskDescription};
/// use hydra::sim::provider::ProviderId;
///
/// // A Kubernetes cluster and a function service, one per provider.
/// let hydra = Hydra::builder()
///     .simulated_provider(ProviderId::Aws)
///     .resource(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8))
///     .simulated_provider(ProviderId::Azure)
///     .resource(ResourceRequest::faas(ProviderId::Azure, 64))
///     .build()
///     .unwrap();
/// // Containers route to the CaaS manager, functions to FaaS.
/// let mut tasks: Vec<TaskDescription> = (0..32)
///     .map(|i| TaskDescription::container(format!("t{i}"), "noop:latest"))
///     .collect();
/// tasks.extend((0..32).map(|i| TaskDescription::function(format!("f{i}"), "pkg.handler")));
/// let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
/// assert_eq!(run.aggregate.tasks, 64);
/// ```
pub struct Hydra {
    proxy: ServiceProxy,
}

/// Builder for [`Hydra`].
#[derive(Default)]
pub struct HydraBuilder {
    configs: Vec<ProviderConfig>,
    resources: Vec<ResourceRequest>,
    partition_model: Option<PartitionModel>,
    build_mode: Option<PodBuildMode>,
    serialize: Option<SerializeOptions>,
    seed: Option<u64>,
}

impl HydraBuilder {
    pub fn provider(mut self, cfg: ProviderConfig) -> Self {
        self.configs.push(cfg);
        self
    }

    pub fn simulated_provider(mut self, id: ProviderId) -> Self {
        self.configs.push(ProviderConfig::simulated(id));
        self
    }

    pub fn resource(mut self, req: ResourceRequest) -> Self {
        self.resources.push(req);
        self
    }

    pub fn partition_model(mut self, m: PartitionModel) -> Self {
        self.partition_model = Some(m);
        self
    }

    pub fn build_mode(mut self, b: PodBuildMode) -> Self {
        self.build_mode = Some(b);
        self
    }

    /// Serialize-phase fan-out for every manager: `1` = serial reference
    /// path, `0` = available parallelism (the default). The bulk payload
    /// bytes are identical for any value (ISSUE 3 tentpole guarantee).
    pub fn serialize_threads(mut self, threads: usize) -> Self {
        self.serialize = Some(SerializeOptions::with_threads(threads));
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn build(self) -> Result<Hydra, BrokerError> {
        let providers = provider_proxy::ProviderProxy::connect(self.configs)?;
        let mut proxy = ServiceProxy::new(providers);
        if let Some(m) = self.partition_model {
            proxy.partition_model = m;
        }
        if let Some(b) = self.build_mode {
            proxy.build_mode = b;
        }
        if let Some(s) = self.serialize {
            proxy.serialize = s;
        }
        if let Some(s) = self.seed {
            proxy.seed = s;
        }
        for r in self.resources {
            proxy.acquire(r)?;
        }
        Ok(Hydra { proxy })
    }
}

impl Hydra {
    pub fn builder() -> HydraBuilder {
        HydraBuilder::default()
    }

    /// Broker one workload across the acquired resources.
    pub fn submit(
        &self,
        tasks: Vec<TaskDescription>,
        policy: &BrokerPolicy,
    ) -> Result<BrokerRun, BrokerError> {
        self.proxy.run(tasks, policy)
    }

    pub fn registry(&self) -> &state::TaskRegistry {
        &self.proxy.registry
    }

    pub fn service_proxy(&self) -> &ServiceProxy {
        &self.proxy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facade_end_to_end() {
        // One provider per service kind: the facade drives all three
        // managers through the factory in a single brokered run.
        let hydra = Hydra::builder()
            .simulated_provider(ProviderId::Jetstream2)
            .simulated_provider(ProviderId::Bridges2)
            .simulated_provider(ProviderId::Aws)
            .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
            .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
            .resource(ResourceRequest::faas(ProviderId::Aws, 32))
            .partition_model(PartitionModel::Scpp)
            .seed(99)
            .build()
            .unwrap();
        let mut tasks: Vec<TaskDescription> = (0..40)
            .map(|i| TaskDescription::container(format!("c{i}"), "noop:latest"))
            .collect();
        tasks.extend((0..40).map(|i| TaskDescription::executable(format!("e{i}"), "noop")));
        tasks.extend((0..40).map(|i| TaskDescription::function(format!("f{i}"), "pkg.handler")));
        let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
        assert_eq!(run.aggregate.tasks, 120);
        assert_eq!(run.reports.len(), 3);
        assert!(matches!(run.reports[&ProviderId::Jetstream2], ManagerReport::Caas(_)));
        assert!(matches!(run.reports[&ProviderId::Bridges2], ManagerReport::Hpc(_)));
        assert!(matches!(run.reports[&ProviderId::Aws], ManagerReport::Faas(_)));
        assert!(hydra.registry().all_final());
        assert!(hydra.registry().trace_len() >= 120 * 6);
    }

    #[test]
    fn build_fails_without_valid_providers() {
        assert!(matches!(Hydra::builder().build(), Err(BrokerError::Provider(_))));
    }
}
