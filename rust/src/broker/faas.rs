//! FaaS Manager: the paper's example of Service Proxy extensibility.
//!
//! §3.1: the Service Proxy "exposes a private interface to add new
//! managers like, for example, a Function as a Service manager". This
//! manager implements that interface — now the public `ServiceManager`
//! trait (`broker::manager`) — validate → translate → bulk-submit →
//! trace, against the FaaS simulator. It is built from a
//! `ResourceRequest::faas` acquisition by `ManagerFactory` like every
//! other manager, and reports the unified `ManagerRun` with the FaaS sim
//! report in `RunDetail::Faas`.

use crate::api::resource::ResourceRequest;
use crate::api::task::{Payload, TaskDescription, TaskId, TaskKind, TaskState};
use crate::api::ProviderConfig;
use crate::broker::data::{
    expected_framed_len, frame_bulk, serialize_sharded, ManifestShard, ProviderEndpoint,
    ProviderFaultSpec, RetryPolicy, SerializeOptions,
};
use crate::broker::manager::{FaultTally, ManagerError, ManagerRun, RunDetail};
use crate::broker::provider_proxy::CircuitBreaker;
use crate::broker::state::TaskRegistry;
use crate::metrics::{Overhead, RunMetrics};
use crate::sim::faas::{FaasSim, FaasSpec, Invocation};
use crate::util::json::Json;
use crate::util::json_scan::JsonScanner;
use crate::util::Stopwatch;
use std::borrow::Borrow;

/// Serialize the bulk invoke request as contiguous task shards on scoped
/// threads (§Perf: the serialize phase is embarrassingly parallel across
/// invocations; `opts.threads == 1` is the serial reference path and the
/// framed bytes are identical for every thread count). Function tasks
/// carry their handler; other kinds invoke by task name alone.
pub fn bulk_invoke_document<T: Borrow<TaskDescription> + Sync>(
    tasks: &[(TaskId, T)],
    opts: SerializeOptions,
) -> Vec<ManifestShard> {
    serialize_sharded(tasks, opts, 96, |out, (id, t), _| {
        let t = t.borrow();
        let mut doc = Json::obj().set("function", t.name.as_str());
        if let TaskKind::Function { handler } = &t.kind {
            doc = doc.set("handler", handler.as_str());
        }
        doc.set("qualifier", "$LATEST")
            .set("payload", Json::obj().set("hydra_task_id", id.0))
            .write_into(out)
    })
}

/// FaaS manager bound to one cloud provider connection. The acquired
/// resource is consumed at construction (its `concurrency` becomes the
/// service spec); only the derived [`FaasSpec`] is kept.
pub struct FaasManager {
    pub config: ProviderConfig,
    pub spec: FaasSpec,
    pub seed: u64,
    /// Serialize-phase fan-out; defaults to available parallelism.
    pub serialize: SerializeOptions,
    /// Provider control-plane fault model from the acquired resource.
    pub provider_fault: ProviderFaultSpec,
    /// Retry/backoff policy from the acquired resource.
    pub retry: RetryPolicy,
    /// Per-provider circuit breaker shared with the provider handle.
    pub breaker: CircuitBreaker,
}

impl FaasManager {
    pub fn new(
        config: ProviderConfig,
        resource: ResourceRequest,
        seed: u64,
    ) -> Result<FaasManager, ManagerError> {
        crate::broker::manager::validate_binding(&config, &resource)?;
        let spec = FaasSpec { concurrency: resource.concurrency, ..FaasSpec::default() };
        Ok(FaasManager {
            config,
            spec,
            seed,
            serialize: SerializeOptions::default(),
            provider_fault: resource.provider_fault,
            retry: resource.retry,
            breaker: CircuitBreaker::default(),
        })
    }

    pub fn with_serialize(mut self, serialize: SerializeOptions) -> Self {
        self.serialize = serialize;
        self
    }

    /// Share an existing per-provider circuit breaker.
    pub fn with_breaker(mut self, breaker: CircuitBreaker) -> Self {
        self.breaker = breaker;
        self
    }

    /// Execute a workload as function invocations.
    ///
    /// Generic over `Borrow<TaskDescription>` like the CaaS/HPC managers:
    /// descriptions arrive as registry-shared `Arc` handles on the broker
    /// path (§Perf). `Sync` because the serialize phase fans the batch
    /// out over scoped threads.
    pub fn execute<T: Borrow<TaskDescription> + Sync>(
        &self,
        tasks: &[(TaskId, T)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        let ids: Vec<TaskId> = tasks.iter().map(|(id, _)| *id).collect();
        for (_, t) in tasks {
            let t = t.borrow();
            t.validate().map_err(ManagerError::InvalidTask)?;
            if t.gpus > 0 {
                return Err(ManagerError::InvalidTask(format!(
                    "task '{}': functions cannot request GPUs",
                    t.name
                )));
            }
        }
        registry.transition_all(&ids, TaskState::Validated)?;

        // -- OVH: translate to invocations --------------------------------
        let sw = Stopwatch::start();
        let invocations: Vec<Invocation> = tasks
            .iter()
            .map(|(id, t)| {
                let (work_s, sleep_s) = match t.borrow().payload {
                    Payload::Noop => (0.0, 0.0),
                    Payload::Sleep(s) => (0.0, s),
                    Payload::Work(w) => (w, 0.0),
                    Payload::Compute(_) => (0.0, 0.0),
                };
                Invocation { task_id: id.0, work_s, sleep_s }
            })
            .collect();
        let partition_s = sw.elapsed_secs();
        registry.transition_all(&ids, TaskState::Partitioned)?;

        // -- OVH: serialize the bulk invoke request (sharded, §Perf) -------
        let sw = Stopwatch::start();
        let shards = bulk_invoke_document(tasks, self.serialize);
        let serialize_s = sw.elapsed_secs();
        let bytes_serialized: usize = shards.iter().map(ManifestShard::item_bytes).sum();

        // -- OVH: frame + submit -------------------------------------------
        // The bulk payload is framed directly from the shard buffers (one
        // copy per shard) and shipped through the shared provider-API sink.
        let sw = Stopwatch::start();
        let expected_bulk = expected_framed_len(&shards);
        let bulk = frame_bulk(&shards, self.serialize);
        let mut endpoint = ProviderEndpoint::new(
            self.provider_fault,
            self.retry,
            self.breaker.clone(),
            self.seed,
        );
        let receipt = endpoint.submit_acked(&bulk)?;
        let bulk_bytes = receipt.bytes;
        assert_eq!(bulk_bytes, expected_bulk, "bulk framing lost bytes");
        // -- ingest: verify the provider's ack round-trip (ISSUE 10) ------
        // Inside the submit stopwatch window, charged into OVH.
        verify_ack(&receipt.ack, &ids)?;
        let mut sim = FaasSim::new(self.config.profile(), self.spec, self.seed);
        sim.submit(invocations);
        // Simulated backoff is charged into OVH: resilience has a cost.
        let submit_s = sw.elapsed_secs() + endpoint.backoff_s();
        registry.transition_all(&ids, TaskState::Submitted)?;

        let report = sim.run();
        for rec in &report.invocations {
            registry.transition_virtual(
                TaskId(rec.task_id),
                TaskState::Running,
                Some(rec.started_s),
            )?;
            registry.transition_virtual(
                TaskId(rec.task_id),
                TaskState::Done,
                Some(rec.finished_s),
            )?;
        }

        let metrics = RunMetrics {
            provider: self.config.id,
            tasks: tasks.len(),
            pods: tasks.len(), // one invocation per task
            ovh: Overhead { partition_s, serialize_s, submit_s },
            tpt_s: report.makespan_s,
            ttx_s: report.makespan_s,
        };
        Ok(ManagerRun {
            metrics,
            bytes_serialized,
            bulk_bytes,
            // The simulated function service retries invocations
            // internally; the control-plane submit accounting is real.
            faults: FaultTally {
                submit_retries: endpoint.submit_retries(),
                backoff_ms: endpoint.backoff_ms(),
                circuit_opens: endpoint.circuit_opens(),
                ..FaultTally::default()
            },
            detail: RunDetail::Faas { sim: report },
        })
    }
}

/// ISSUE 10 round-trip check: the echoed item count must equal the
/// invocation count and the first/last id echoes (each item's
/// `payload.hydra_task_id`) must match the framed task ids. Lazily
/// scanned; a disagreement is terminal (see `ManagerError::AckMismatch`).
fn verify_ack(ack: &str, ids: &[TaskId]) -> Result<(), ManagerError> {
    let scan = JsonScanner::new(ack.as_bytes());
    let count = scan.path_u64(&["count"]);
    if count != Some(ids.len() as u64) {
        return Err(ManagerError::AckMismatch {
            message: format!("framed {} invocations, provider acked {count:?}", ids.len()),
        });
    }
    let (Some(first), Some(last)) = (ids.first(), ids.last()) else {
        return Ok(());
    };
    let checks = [
        ("first", first.0, scan.path_u64(&["first_id"])),
        ("last", last.0, scan.path_u64(&["last_id"])),
    ];
    for (which, want, got) in checks {
        if got != Some(want) {
            return Err(ManagerError::AckMismatch {
                message: format!("{which} task id {want} not echoed, got {got:?}"),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::ProviderId;

    fn manager() -> FaasManager {
        FaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::faas(ProviderId::Aws, 64),
            3,
        )
        .unwrap()
    }

    fn workload(reg: &TaskRegistry, n: usize) -> Vec<(TaskId, TaskDescription)> {
        (0..n)
            .map(|i| {
                let d = TaskDescription::function(format!("fn-{i}"), "pkg.module:handler")
                    .with_payload(Payload::Work(1.0));
                (reg.register(d.clone()), d)
            })
            .collect()
    }

    #[test]
    fn executes_invocations_to_done() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 150);
        let r = manager().execute(&tasks, &reg).unwrap();
        assert_eq!(r.metrics.tasks, 150);
        assert!(r.detail.faas_sim().unwrap().cold_starts >= 1);
        assert!(r.metrics.tpt_s > 0.0);
        assert!(r.bulk_bytes > r.bytes_serialized);
        assert!(reg.all_final());
    }

    #[test]
    fn rejects_hpc_provider_and_gpu_tasks() {
        assert!(FaasManager::new(
            ProviderConfig::simulated(ProviderId::Bridges2),
            ResourceRequest::faas(ProviderId::Bridges2, 64),
            0
        )
        .is_err());
        let reg = TaskRegistry::new();
        let d = TaskDescription::container("g", "img").with_gpus(1);
        let id = reg.register(d.clone());
        assert!(manager().execute(&[(id, d)], &reg).is_err());
    }

    #[test]
    fn bulk_invoke_document_is_thread_count_invariant() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 300);
        let serial_opts = SerializeOptions::serial();
        let serial = frame_bulk(&bulk_invoke_document(&tasks, serial_opts), serial_opts);
        assert_eq!(serial[0], b'[');
        assert!(serial.windows(13).any(|w| w == b"hydra_task_id".as_slice()));
        assert!(serial.windows(7).any(|w| w == b"handler".as_slice()));
        for threads in [2, 8] {
            let opts = SerializeOptions::with_threads(threads);
            let bulk = frame_bulk(&bulk_invoke_document(&tasks, opts), opts);
            assert_eq!(bulk, serial, "threads={threads}");
        }
    }

    #[test]
    fn zero_concurrency_rejected() {
        assert!(FaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::faas(ProviderId::Aws, 0),
            0
        )
        .is_err());
    }

    #[test]
    fn mismatched_provider_rejected() {
        assert!(FaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::faas(ProviderId::Azure, 64),
            0
        )
        .is_err());
    }

    #[test]
    fn faas_submits_are_fallible_and_tallied() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 64);
        let mut m = manager();
        m.provider_fault = ProviderFaultSpec {
            outage_window: Some((0.0, 0.12)),
            ..ProviderFaultSpec::none()
        };
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.faults.submit_retries, 2, "two backoffs ride out a 0.12s outage");
        assert!(r.faults.backoff_ms > 0, "FaultTally is no longer structurally zero on FaaS");
        assert!(reg.all_final());

        // A hard outage errors before the Submitted transition.
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 8);
        let mut m = manager();
        m.provider_fault = ProviderFaultSpec {
            outage_window: Some((0.0, 1e9)),
            ..ProviderFaultSpec::none()
        };
        let e = m.execute(&tasks, &reg).unwrap_err();
        assert!(e.retryable());
        for (id, _) in &tasks {
            assert_eq!(reg.state_of(*id), Some(TaskState::Partitioned));
        }
    }

    #[test]
    fn faas_ack_verification_flags_mismatches() {
        let ids = [TaskId(5), TaskId(6), TaskId(9)];
        assert!(verify_ack(r#"{"count":3,"bytes":1,"first_id":5,"last_id":9}"#, &ids).is_ok());
        for bad in [
            r#"{"count":2,"bytes":1,"first_id":5,"last_id":9}"#,
            r#"{"count":3,"bytes":1,"first_id":4,"last_id":9}"#,
            r#"{"count":3,"bytes":1,"first_id":5,"last_id":null}"#,
        ] {
            let e = verify_ack(bad, &ids).unwrap_err();
            assert!(matches!(e, ManagerError::AckMismatch { .. }), "{bad}");
            assert!(!e.retryable());
        }
        assert!(verify_ack(r#"{"count":0,"bytes":2}"#, &[]).is_ok());
    }

    #[test]
    fn faas_beats_kubernetes_on_short_bursts() {
        // The motivation for a FaaS manager: short bursty tasks avoid pod
        // sandbox + container start costs once instances are warm.
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 400);
        let faas = manager().execute(&tasks, &reg).unwrap();

        let reg2 = TaskRegistry::new();
        let tasks2: Vec<_> = (0..400)
            .map(|i| {
                let d = TaskDescription::container(format!("c-{i}"), "image")
                    .with_payload(Payload::Work(1.0));
                (reg2.register(d.clone()), d)
            })
            .collect();
        let caas = crate::broker::caas::CaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 16),
            crate::broker::partitioner::Partitioner::new(
                crate::broker::partitioner::PartitionModel::Scpp,
                crate::broker::partitioner::PodBuildMode::Memory,
            ),
            3,
        )
        .unwrap()
        .execute(&tasks2, &reg2)
        .unwrap();
        assert!(
            faas.metrics.tpt_s < caas.metrics.tpt_s,
            "faas {} vs caas {}",
            faas.metrics.tpt_s,
            caas.metrics.tpt_s
        );
    }
}
