//! Workload partitioning: tasks → pods → serialized manifests.
//!
//! This is the core of the CaaS Manager's data path (paper §3.2): "Based
//! on the available resources of each cluster, the CaaS Manager partitions
//! the given workload into batches that fit the available resources."
//!
//! Two partitioning models (paper §5):
//! * **MCPP** (Multiple-Containers-Per-Pod): containers share a pod up to
//!   the node's vCPU capacity (or an explicit cap) — fewer pods, fewer
//!   manifests, less serialization.
//! * **SCPP** (Single-Container-Per-Pod): one container per pod — more
//!   I/O per task; the paper measures ≈ +46% OVH and ≈ −44% TH vs MCPP.
//!
//! Two manifest build modes (the paper's §6 future-work ablation — we
//! implement both):
//! * **Disk** — each pod manifest is serialized to a staging file, the
//!   behaviour the paper measured ("Hydra generates pods ... by relying on
//!   the file system. That is inefficient").
//! * **Memory** — manifests are built in RAM and handed to the provider
//!   API directly (their prototyped fix; see benches/ablations.rs).
//!
//! §Perf (data-path overhaul): the pipeline is zero-copy end to end —
//! `partition` builds the `Vec<PodSpec>` once, `build_manifests` takes it
//! *by value* and returns it inside [`PreparedWorkload`] (no `to_vec`),
//! and the manager moves the same vector into the simulator's `submit`.
//! Memory-mode manifests are serialized into **contiguous shards**
//! ([`ManifestShard`]: one buffer + span table per shard) on scoped
//! threads — [`SerializeOptions`] picks the fan-out, `threads == 1` being
//! the serial reference path — and the bulk submission payload is framed
//! directly from the shard buffers with one copy per shard, never per
//! manifest. The framed bytes are identical for every thread count. Task
//! descriptions arrive behind `Borrow<TaskDescription>` so callers can
//! pass `Arc<TaskDescription>` handles shared with the registry instead
//! of cloned descriptions.

use crate::api::task::{Payload, TaskDescription, TaskId, TaskKind};
use crate::broker::data::{
    frame_bulk, framed_len, serialize_sharded, sharded_map, ManifestShard, SerializeOptions,
};
use crate::sim::kubernetes::{ClusterSpec, ContainerSpec, PodSpec};
use crate::util::json::{push_json_str, push_u64, push_u64_padded, Json};
use std::borrow::Borrow;
use std::io::Write;
use std::path::PathBuf;

/// Pod partitioning model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionModel {
    /// Pack up to `max_cpp` containers per pod (bounded additionally by
    /// node vCPU capacity).
    Mcpp { max_cpp: usize },
    Scpp,
}

impl PartitionModel {
    pub fn short_name(self) -> &'static str {
        match self {
            PartitionModel::Mcpp { .. } => "MCPP",
            PartitionModel::Scpp => "SCPP",
        }
    }
}

/// Where manifests are materialized.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PodBuildMode {
    Disk { staging_dir: PathBuf },
    Memory,
}

/// A prepared workload: simulator-ready pods plus their serialized
/// manifests. Memory mode serializes the manifests into contiguous
/// [`ManifestShard`]s (one buffer + span table per shard, `,` separators
/// between manifests already in place, §Perf); Disk mode records the
/// staging file paths instead.
#[derive(Debug)]
pub struct PreparedWorkload {
    pub pods: Vec<PodSpec>,
    /// Memory-mode manifest shards, in pod order; empty in Disk mode.
    pub shards: Vec<ManifestShard>,
    pub manifest_paths: Vec<PathBuf>,
    /// Total manifest bytes (bulk-envelope separators excluded).
    pub bytes_serialized: usize,
}

impl PreparedWorkload {
    /// The i-th pod's manifest. **Memory mode only**: Disk mode keeps no
    /// manifests in memory (read them back via `manifest_paths`), so
    /// `manifest_count()` is 0 there and any index panics — check the
    /// build mode or `manifest_count()` first.
    pub fn manifest(&self, i: usize) -> &str {
        let k = self.shards.partition_point(|s| s.first <= i) - 1;
        let shard = &self.shards[k];
        let (s, e) = shard.spans[i - shard.first];
        &shard.buf[s..e]
    }

    /// Iterate Memory-mode manifests in pod order (empty in Disk mode).
    pub fn manifests(&self) -> impl Iterator<Item = &str> + '_ {
        self.shards
            .iter()
            .flat_map(|shard| shard.spans.iter().map(move |&(s, e)| &shard.buf[s..e]))
    }

    /// Number of in-memory manifests: `pods.len()` in Memory mode, 0 in
    /// Disk mode (where `manifest_paths.len()` counts instead).
    pub fn manifest_count(&self) -> usize {
        self.shards.iter().map(|s| s.spans.len()).sum()
    }

    /// Frame the bulk submission payload `[m0,m1,...]` directly from the
    /// shard buffers — one copy per shard, never per manifest (§Perf;
    /// Memory mode only, `[]` in Disk mode).
    pub fn frame_bulk(&self, opts: SerializeOptions) -> Vec<u8> {
        frame_bulk(&self.shards, opts)
    }

    /// Exact byte length [`Self::frame_bulk`] will produce.
    pub fn framed_len(&self) -> usize {
        framed_len(&self.shards)
    }
}

/// Partitioning/serialization errors.
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionError {
    /// A task can never fit an empty node of this cluster.
    Unschedulable { task: TaskId, reason: String },
    Io(String),
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::Unschedulable { task, reason } => {
                write!(f, "{task} is unschedulable: {reason}")
            }
            PartitionError::Io(e) => write!(f, "manifest I/O failed: {e}"),
        }
    }
}

impl std::error::Error for PartitionError {}

pub struct Partitioner {
    pub model: PartitionModel,
    pub build_mode: PodBuildMode,
    /// Serialize-phase fan-out; defaults to available parallelism.
    pub serialize: SerializeOptions,
}

impl Partitioner {
    pub fn new(model: PartitionModel, build_mode: PodBuildMode) -> Partitioner {
        Partitioner { model, build_mode, serialize: SerializeOptions::default() }
    }

    pub fn with_serialize(mut self, serialize: SerializeOptions) -> Partitioner {
        self.serialize = serialize;
        self
    }

    /// Partition `tasks` into pods that individually fit an empty node of
    /// `cluster`. Preserves task order (FIFO fairness downstream).
    ///
    /// Generic over `Borrow<TaskDescription>`: the broker passes
    /// `Arc<TaskDescription>` handles shared with the registry; tests may
    /// pass owned descriptions.
    pub fn partition<T: Borrow<TaskDescription>>(
        &self,
        tasks: &[(TaskId, T)],
        cluster: &ClusterSpec,
        first_pod_id: u64,
    ) -> Result<Vec<PodSpec>, PartitionError> {
        let cap_cpus = cluster.vcpus_per_node;
        let cap_gpus = cluster.gpus_per_node;
        let cap_mem = cluster.mem_mb_per_node;
        for (id, t) in tasks {
            let t = t.borrow();
            if t.cpus > cap_cpus {
                return Err(PartitionError::Unschedulable {
                    task: *id,
                    reason: format!("needs {} cpus; node offers {cap_cpus}", t.cpus),
                });
            }
            if t.gpus > cap_gpus {
                return Err(PartitionError::Unschedulable {
                    task: *id,
                    reason: format!("needs {} gpus; node offers {cap_gpus}", t.gpus),
                });
            }
            if t.mem_mb > cap_mem {
                return Err(PartitionError::Unschedulable {
                    task: *id,
                    reason: format!("needs {} MB; node offers {cap_mem}", t.mem_mb),
                });
            }
        }

        let max_cpp = match self.model {
            PartitionModel::Scpp => 1,
            PartitionModel::Mcpp { max_cpp } => max_cpp.max(1),
        };

        let mut pods: Vec<PodSpec> = Vec::new();
        let mut cur: Vec<ContainerSpec> = Vec::new();
        let (mut cur_cpu, mut cur_gpu, mut cur_mem) = (0u32, 0u32, 0u64);
        let mut pod_id = first_pod_id;
        for (id, t) in tasks {
            let c = to_container(*id, t.borrow());
            let fits = cur.len() < max_cpp
                && cur_cpu + c.cpus <= cap_cpus
                && cur_gpu + c.gpus <= cap_gpus
                && cur_mem + c.mem_mb <= cap_mem;
            if !cur.is_empty() && !fits {
                pods.push(PodSpec { id: pod_id, containers: std::mem::take(&mut cur) });
                pod_id += 1;
                cur_cpu = 0;
                cur_gpu = 0;
                cur_mem = 0;
            }
            cur_cpu += c.cpus;
            cur_gpu += c.gpus;
            cur_mem += c.mem_mb;
            cur.push(c);
        }
        if !cur.is_empty() {
            pods.push(PodSpec { id: pod_id, containers: cur });
        }
        Ok(pods)
    }

    /// Build (and in Disk mode persist) the Kubernetes manifests for a
    /// set of pods. The serialization cost measured here is the dominant
    /// OVH component of the paper's Experiment 1 — and it is
    /// embarrassingly parallel across pods, so both modes shard the batch
    /// into contiguous chunks and serialize each shard on its own scoped
    /// thread (`self.serialize` picks the fan-out; `threads == 1` is the
    /// serial reference path with byte-identical output).
    ///
    /// Takes `pods` by value and hands the same vector back inside the
    /// [`PreparedWorkload`] — the caller moves it onward to the simulator
    /// without any `PodSpec` clone (§Perf).
    pub fn build_manifests<T: Borrow<TaskDescription>>(
        &self,
        pods: Vec<PodSpec>,
        tasks: &[(TaskId, T)],
    ) -> Result<PreparedWorkload, PartitionError> {
        // Index task descriptions for manifest enrichment (image, name).
        let by_id: std::collections::HashMap<u64, &TaskDescription> =
            tasks.iter().map(|(id, t)| (id.0, t.borrow())).collect();

        let mut shards = Vec::new();
        let mut paths = Vec::new();
        let bytes;

        match &self.build_mode {
            PodBuildMode::Memory => {
                shards = serialize_sharded(&pods, self.serialize, 384, |out, pod, _| {
                    write_pod_manifest(out, pod, &by_id)
                });
                bytes = shards.iter().map(ManifestShard::item_bytes).sum();
            }
            PodBuildMode::Disk { staging_dir } => {
                std::fs::create_dir_all(staging_dir)
                    .map_err(|e| PartitionError::Io(e.to_string()))?;
                let write_range =
                    |lo: usize, hi: usize| -> Result<(Vec<PathBuf>, usize), PartitionError> {
                        let mut buf = String::with_capacity(1024);
                        let mut paths = Vec::with_capacity(hi - lo);
                        let mut bytes = 0usize;
                        for pod in &pods[lo..hi] {
                            buf.clear();
                            write_pod_manifest(&mut buf, pod, &by_id);
                            bytes += buf.len();
                            let path = staging_dir.join(format!("pod-{:08}.json", pod.id));
                            let f = std::fs::File::create(&path)
                                .map_err(|e| PartitionError::Io(e.to_string()))?;
                            let mut w = std::io::BufWriter::new(f);
                            w.write_all(buf.as_bytes())
                                .map_err(|e| PartitionError::Io(e.to_string()))?;
                            w.flush().map_err(|e| PartitionError::Io(e.to_string()))?;
                            paths.push(path);
                        }
                        Ok((paths, bytes))
                    };
                let results =
                    sharded_map(pods.len(), self.serialize.shards_for(pods.len()), write_range);
                let mut total = 0usize;
                paths.reserve(pods.len());
                for r in results {
                    let (shard_paths, shard_bytes) = r?;
                    paths.extend(shard_paths);
                    total += shard_bytes;
                }
                bytes = total;
            }
        }
        Ok(PreparedWorkload { pods, shards, manifest_paths: paths, bytes_serialized: bytes })
    }
}

fn to_container(id: TaskId, t: &TaskDescription) -> ContainerSpec {
    let (work_s, sleep_s) = match t.payload {
        Payload::Noop => (0.0, 0.0),
        Payload::Sleep(s) => (0.0, s),
        Payload::Work(w) => (w, 0.0),
        // Compute tasks are resolved to measured Work by the FACTS engine
        // before submission; an unresolved Compute costs nothing here.
        Payload::Compute(_) => (0.0, 0.0),
    };
    ContainerSpec {
        task_id: id.0,
        cpus: t.cpus,
        gpus: t.gpus,
        mem_mb: t.mem_mb,
        work_s,
        sleep_s,
    }
}

/// Serialize a pod manifest directly into `out` without building a
/// [`Json`] tree — the broker's measured hot path (§Perf: the tree
/// construction dominated OVH; direct writing cut serialize time ~3x).
/// Byte-identical to `pod_manifest(..).write_into(..)`, enforced by
/// `fast_path_matches_tree_path` below. The numeric/string writers are
/// `util::json`'s in-place push helpers — one escaping implementation for
/// both paths.
fn write_pod_manifest(
    out: &mut String,
    pod: &PodSpec,
    by_id: &std::collections::HashMap<u64, &TaskDescription>,
) {
    out.push_str("{\"apiVersion\":\"v1\",\"kind\":\"Pod\",\"metadata\":{\"name\":\"hydra-pod-");
    push_u64_padded(out, pod.id, 8);
    out.push_str("\",\"labels\":{\"app\":\"hydra\",\"hydra/pod-id\":");
    push_u64(out, pod.id);
    out.push_str("}},\"spec\":{\"restartPolicy\":\"Never\",\"containers\":[");
    for (i, c) in pod.containers.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"name\":");
        match by_id.get(&c.task_id) {
            Some(t) => {
                push_json_str(out, &t.name);
                out.push_str(",\"image\":");
                match &t.kind {
                    TaskKind::Container { image } => push_json_str(out, image),
                    TaskKind::Executable { command } => {
                        push_json_str(out, &format!("exec://{command}"))
                    }
                    TaskKind::Function { handler } => {
                        push_json_str(out, &format!("faas://{handler}"))
                    }
                }
            }
            None => {
                push_json_str(out, &format!("task-{}", c.task_id));
                out.push_str(",\"image\":\"noop:latest\"");
            }
        }
        out.push_str(",\"resources\":{\"requests\":{\"cpu\":");
        push_u64(out, c.cpus as u64);
        out.push_str(",\"memory\":\"");
        push_u64(out, c.mem_mb);
        out.push_str("Mi\"");
        if c.gpus > 0 {
            out.push_str(",\"nvidia.com/gpu\":");
            push_u64(out, c.gpus as u64);
        }
        out.push_str("}},\"env\":[{\"name\":\"HYDRA_TASK_ID\",\"value\":\"");
        push_u64(out, c.task_id);
        out.push_str("\"}]}");
    }
    out.push_str("]}}");
}

/// Build a Kubernetes-style pod manifest document (reference/tree path;
/// the hot path uses `write_pod_manifest` — kept for the byte-equivalence
/// test and external consumers needing a structured document).
#[cfg_attr(not(test), allow(dead_code))]
fn pod_manifest(
    pod: &PodSpec,
    by_id: &std::collections::HashMap<u64, &TaskDescription>,
) -> Json {
    let containers: Vec<Json> = pod
        .containers
        .iter()
        .map(|c| {
            let (name, image) = match by_id.get(&c.task_id) {
                Some(t) => {
                    let img = match &t.kind {
                        TaskKind::Container { image } => image.clone(),
                        TaskKind::Executable { command } => format!("exec://{command}"),
                        TaskKind::Function { handler } => format!("faas://{handler}"),
                    };
                    (t.name.clone(), img)
                }
                None => (format!("task-{}", c.task_id), "noop:latest".to_string()),
            };
            let mut requests = Json::obj()
                .set("cpu", c.cpus as u64)
                .set("memory", format!("{}Mi", c.mem_mb));
            if c.gpus > 0 {
                requests = requests.set("nvidia.com/gpu", c.gpus as u64);
            }
            Json::obj()
                .set("name", name)
                .set("image", image)
                .set("resources", Json::obj().set("requests", requests))
                .set(
                    "env",
                    Json::Arr(vec![Json::obj()
                        .set("name", "HYDRA_TASK_ID")
                        .set("value", format!("{}", c.task_id))]),
                )
        })
        .collect();
    Json::obj()
        .set("apiVersion", "v1")
        .set("kind", "Pod")
        .set(
            "metadata",
            Json::obj()
                .set("name", format!("hydra-pod-{:08}", pod.id))
                .set(
                    "labels",
                    Json::obj().set("app", "hydra").set("hydra/pod-id", pod.id),
                ),
        )
        .set(
            "spec",
            Json::obj()
                .set("restartPolicy", "Never")
                .set("containers", Json::Arr(containers)),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::TaskDescription;
    use crate::util::json;

    fn tasks(n: usize) -> Vec<(TaskId, TaskDescription)> {
        (0..n)
            .map(|i| {
                (TaskId(i as u64), TaskDescription::container(format!("t{i}"), "noop:latest"))
            })
            .collect()
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::uniform(1, 16)
    }

    #[test]
    fn scpp_is_one_task_per_pod() {
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let pods = p.partition(&tasks(37), &cluster(), 0).unwrap();
        assert_eq!(pods.len(), 37);
        assert!(pods.iter().all(|p| p.containers.len() == 1));
    }

    #[test]
    fn mcpp_packs_to_capacity() {
        let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 16 }, PodBuildMode::Memory);
        let pods = p.partition(&tasks(40), &cluster(), 0).unwrap();
        // 16-vCPU node, 1-cpu tasks, cap 16 => 16+16+8
        assert_eq!(pods.len(), 3);
        assert_eq!(pods[0].containers.len(), 16);
        assert_eq!(pods[2].containers.len(), 8);
    }

    #[test]
    fn partition_preserves_all_tasks_exactly_once() {
        for model in [PartitionModel::Scpp, PartitionModel::Mcpp { max_cpp: 7 }] {
            let p = Partitioner::new(model, PodBuildMode::Memory);
            let pods = p.partition(&tasks(101), &cluster(), 0).unwrap();
            let mut seen: Vec<u64> = pods
                .iter()
                .flat_map(|p| p.containers.iter().map(|c| c.task_id))
                .collect();
            seen.sort();
            assert_eq!(seen, (0..101).collect::<Vec<_>>());
        }
    }

    #[test]
    fn partition_accepts_arc_shared_descriptions() {
        // The broker's hot path passes Arc handles shared with the
        // registry; result must match the owned-description path.
        use std::sync::Arc;
        let owned = tasks(24);
        let shared: Vec<(TaskId, Arc<TaskDescription>)> = owned
            .iter()
            .map(|(id, t)| (*id, Arc::new(t.clone())))
            .collect();
        let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 5 }, PodBuildMode::Memory);
        let a = p.partition(&owned, &cluster(), 0).unwrap();
        let b = p.partition(&shared, &cluster(), 0).unwrap();
        assert_eq!(a.len(), b.len());
        let wa = p.build_manifests(a, &owned).unwrap();
        let wb = p.build_manifests(b, &shared).unwrap();
        assert_eq!(wa.shards, wb.shards);
        assert_eq!(wa.frame_bulk(p.serialize), wb.frame_bulk(p.serialize));
    }

    #[test]
    fn heterogeneous_tasks_respect_cpu_capacity() {
        let mut ts = tasks(10);
        for (i, (_, t)) in ts.iter_mut().enumerate() {
            t.cpus = 1 + (i as u32 % 4) * 2; // 1,3,5,7,...
        }
        let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 16 }, PodBuildMode::Memory);
        let pods = p.partition(&ts, &cluster(), 0).unwrap();
        for pod in &pods {
            assert!(pod.cpus() <= 16, "pod over capacity: {}", pod.cpus());
        }
    }

    #[test]
    fn unschedulable_task_is_rejected_with_reason() {
        let mut ts = tasks(3);
        ts[1].1.cpus = 64;
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let e = p.partition(&ts, &cluster(), 0).unwrap_err();
        match e {
            PartitionError::Unschedulable { task, reason } => {
                assert_eq!(task, TaskId(1));
                assert!(reason.contains("64"));
            }
            other => panic!("{other}"),
        }
    }

    #[test]
    fn gpu_and_mem_limits_enforced() {
        let mut ts = tasks(2);
        ts[0].1.gpus = 2;
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        assert!(p.partition(&ts, &cluster(), 0).is_err()); // 0-GPU cluster
        let c = ClusterSpec::uniform(1, 16).with_gpus(4);
        assert!(p.partition(&ts, &c, 0).is_ok());
        let mut ts = tasks(1);
        ts[0].1.mem_mb = u64::MAX;
        assert!(p.partition(&ts, &cluster(), 0).is_err());
    }

    #[test]
    fn pod_ids_start_at_offset_and_increment() {
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let pods = p.partition(&tasks(5), &cluster(), 100).unwrap();
        let ids: Vec<u64> = pods.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![100, 101, 102, 103, 104]);
    }

    #[test]
    fn memory_manifests_are_valid_kubernetes_json() {
        let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 4 }, PodBuildMode::Memory);
        let ts = tasks(10);
        let pods = p.partition(&ts, &cluster(), 0).unwrap();
        let n_pods = pods.len();
        let w = p.build_manifests(pods, &ts).unwrap();
        assert_eq!(w.manifest_count(), n_pods);
        assert_eq!(w.pods.len(), n_pods);
        assert!(w.bytes_serialized > 0);
        assert_eq!(w.bytes_serialized, w.manifests().map(str::len).sum::<usize>());
        for m in w.manifests() {
            let doc = json::parse(m).unwrap();
            assert_eq!(doc.get("kind").unwrap().as_str(), Some("Pod"));
            assert!(doc.at(&["spec", "containers"]).unwrap().as_arr().unwrap().len() <= 4);
            assert_eq!(doc.at(&["spec", "restartPolicy"]).unwrap().as_str(), Some("Never"));
        }
    }

    #[test]
    fn manifest_spans_tile_each_shard_exactly() {
        // Shard buffers hold manifests back to back with one `,` between
        // spans; spans must cover each buffer with no other gaps, and the
        // shards' `first` indices must cover the batch contiguously.
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory)
            .with_serialize(SerializeOptions::with_threads(3));
        let ts = tasks(200);
        let pods = p.partition(&ts, &cluster(), 0).unwrap();
        let w = p.build_manifests(pods, &ts).unwrap();
        let mut seen = 0usize;
        for shard in &w.shards {
            assert_eq!(shard.first, seen);
            let mut cursor = 0usize;
            for (i, &(s, e)) in shard.spans.iter().enumerate() {
                assert_eq!(s, if i == 0 { 0 } else { cursor + 1 });
                assert!(e > s);
                cursor = e;
            }
            assert_eq!(cursor, shard.buf.len());
            seen += shard.spans.len();
        }
        assert_eq!(seen, w.manifest_count());
        assert_eq!(w.manifest(0), w.manifests().next().unwrap());
    }

    #[test]
    fn manifest_lookup_crosses_shard_boundaries() {
        let serial = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory)
            .with_serialize(SerializeOptions::serial());
        let sharded = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory)
            .with_serialize(SerializeOptions::with_threads(8));
        let ts = tasks(300);
        let ws = serial
            .build_manifests(serial.partition(&ts, &cluster(), 0).unwrap(), &ts)
            .unwrap();
        let wp = sharded
            .build_manifests(sharded.partition(&ts, &cluster(), 0).unwrap(), &ts)
            .unwrap();
        assert!(ws.shards.len() == 1 && wp.shards.len() > 1);
        for i in 0..300 {
            assert_eq!(ws.manifest(i), wp.manifest(i), "manifest {i}");
        }
        let all: Vec<&str> = wp.manifests().collect();
        assert_eq!(all.len(), 300);
        assert_eq!(all[299], ws.manifest(299));
    }

    #[test]
    fn framed_bulk_is_byte_identical_across_thread_counts() {
        // 1500 tasks / 7-container pods ≈ 215 pods: enough for several
        // 64-pod shards, so the parallel paths really are multi-shard.
        let ts = tasks(1500);
        let frame = |threads: usize| {
            let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 7 }, PodBuildMode::Memory)
                .with_serialize(SerializeOptions::with_threads(threads));
            let w = p.build_manifests(p.partition(&ts, &cluster(), 0).unwrap(), &ts).unwrap();
            if threads > 1 {
                assert!(w.shards.len() > 1, "expected multi-shard at threads={threads}");
            }
            let bulk = w.frame_bulk(p.serialize);
            assert_eq!(bulk.len(), w.framed_len());
            (bulk, w.bytes_serialized)
        };
        let (serial, serial_bytes) = frame(1);
        // Serial reference: '[' + manifests joined by ',' + ']'.
        assert_eq!(serial[0], b'[');
        assert_eq!(*serial.last().unwrap(), b']');
        for threads in [2, 8] {
            let (bulk, bytes) = frame(threads);
            assert_eq!(bulk, serial, "threads={threads}");
            assert_eq!(bytes, serial_bytes);
        }
    }

    #[test]
    fn disk_mode_sharding_preserves_path_order_and_content() {
        let ts = tasks(130);
        let run = |threads: usize, tag: &str| {
            let dir = std::env::temp_dir()
                .join(format!("hydra-disk-shard-{tag}-{}", std::process::id()));
            let p = Partitioner::new(
                PartitionModel::Scpp,
                PodBuildMode::Disk { staging_dir: dir.clone() },
            )
            .with_serialize(SerializeOptions::with_threads(threads));
            let w = p.build_manifests(p.partition(&ts, &cluster(), 0).unwrap(), &ts).unwrap();
            let contents: Vec<String> = w
                .manifest_paths
                .iter()
                .map(|p| std::fs::read_to_string(p).unwrap())
                .collect();
            std::fs::remove_dir_all(&dir).ok();
            (w.manifest_paths.clone(), contents, w.bytes_serialized)
        };
        let (paths1, contents1, bytes1) = run(1, "serial");
        let (paths8, contents8, bytes8) = run(8, "par");
        assert_eq!(contents1, contents8);
        assert_eq!(bytes1, bytes8);
        assert_eq!(paths1.len(), 130);
        let names = |ps: &[PathBuf]| -> Vec<String> {
            ps.iter().map(|p| p.file_name().unwrap().to_string_lossy().into_owned()).collect()
        };
        assert_eq!(names(&paths1), names(&paths8));
    }

    #[test]
    fn disk_mode_writes_one_file_per_pod() {
        let dir = std::env::temp_dir().join(format!("hydra-test-{}", std::process::id()));
        let p = Partitioner::new(
            PartitionModel::Scpp,
            PodBuildMode::Disk { staging_dir: dir.clone() },
        );
        let ts = tasks(7);
        let pods = p.partition(&ts, &cluster(), 0).unwrap();
        let w = p.build_manifests(pods, &ts).unwrap();
        assert_eq!(w.manifest_paths.len(), 7);
        for path in &w.manifest_paths {
            let content = std::fs::read_to_string(path).unwrap();
            assert!(json::parse(&content).is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scpp_serializes_more_bytes_than_mcpp() {
        // The OVH asymmetry of Fig 2: more pods => more manifest envelope
        // bytes for the same task count.
        let ts = tasks(64);
        let scpp = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let mcpp = Partitioner::new(PartitionModel::Mcpp { max_cpp: 16 }, PodBuildMode::Memory);
        let ws = scpp
            .build_manifests(scpp.partition(&ts, &cluster(), 0).unwrap(), &ts)
            .unwrap();
        let wm = mcpp
            .build_manifests(mcpp.partition(&ts, &cluster(), 0).unwrap(), &ts)
            .unwrap();
        assert!(ws.bytes_serialized > wm.bytes_serialized);
    }

    #[test]
    fn fast_path_matches_tree_path() {
        // The direct-write serializer (hot path) must stay byte-identical
        // to the Json-tree path (reference).
        let mut ts = tasks(6);
        ts[1].1.cpus = 3;
        ts[2].1.gpus = 2;
        ts[3].1 = TaskDescription::executable("weird\"name\n", "cmd --x");
        let c = ClusterSpec::uniform(1, 16).with_gpus(4);
        let p = Partitioner::new(PartitionModel::Mcpp { max_cpp: 3 }, PodBuildMode::Memory);
        let pods = p.partition(&ts, &c, 7).unwrap();
        let by_id: std::collections::HashMap<u64, &TaskDescription> =
            ts.iter().map(|(id, t)| (id.0, t)).collect();
        for pod in &pods {
            let mut fast = String::new();
            write_pod_manifest(&mut fast, pod, &by_id);
            let tree = pod_manifest(pod, &by_id).to_string_compact();
            assert_eq!(fast, tree, "pod {}", pod.id);
        }
    }

    #[test]
    fn gpu_request_appears_in_manifest() {
        let mut ts = tasks(1);
        ts[0].1.gpus = 2;
        let c = ClusterSpec::uniform(1, 16).with_gpus(8);
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let pods = p.partition(&ts, &c, 0).unwrap();
        let w = p.build_manifests(pods, &ts).unwrap();
        assert!(w.manifest(0).contains("nvidia.com/gpu"));
    }
}
