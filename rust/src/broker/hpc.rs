//! HPC Manager: executable workloads through a pilot-job connector.
//!
//! Mirrors the paper's §3.2: "The HPC Manager uses the RADICAL-Pilot
//! connector to bulk-submit resource requirements and task descriptions",
//! then monitors the submitted tasks and retrieves their traces. The
//! connector here targets the pilot simulator (`sim::hpc`); its request
//! format is a bulk JSON document of task descriptions, serialized by the
//! broker (a real, measured OVH cost, symmetric with the CaaS manifests).
//!
//! Multi-pilot (ISSUE 5): a request with `pilots = P` stages P concurrent
//! pilot jobs. The connector **shards the bulk submission transport
//! across the pilot agents** — one framed `[dict,...]` payload per pilot,
//! over contiguous task chunks — while the *schedule* stays global: the
//! fleet executes one FIFO workload placed on the best-fit live pilot
//! through the shared capacity index (`sim::hpc::MultiPilotSim`). With
//! `P == 1` the single payload and the produced `HpcTaskRecord`s are
//! byte-identical to the serial pilot-lifecycle reference
//! (`tests/pilot_equivalence.rs`). Per-pilot utilization is reported in
//! `RunDetail::Hpc`.
//!
//! Fault tolerance (ISSUE 6): the resource request can carry a
//! [`FaultSpec`](crate::api::resource::FaultSpec) — pilot walltime, MTBF,
//! materialization-failure probability, retry budget. Dead pilots roll
//! their in-flight tasks back to the FIFO head; each re-queue wave is
//! **resubmitted over the transport** (one framed `[dict,...]` payload
//! per wave, charged to `FaultTally::retry_bulk_bytes`), tasks whose
//! retry budget is exhausted are transitioned to `Failed` as abandoned,
//! and the unified run surfaces failed/retried/abandoned counts in
//! `ManagerRun::faults`. A heterogeneous fleet
//! (`ResourceRequest::with_pilot_nodes`) stages one pilot per width.
//!
//! Implements the open manager interface (`broker::manager`): built
//! through `ManagerFactory`, reporting the unified `ManagerRun` with the
//! pilot-fleet report in `RunDetail::Hpc`.

use crate::api::resource::ResourceRequest;
use crate::api::task::{Payload, TaskDescription, TaskId, TaskState};
use crate::api::ProviderConfig;
use crate::broker::data::{
    expected_framed_len, frame_bulk, serialize_sharded, shard_ranges, ManifestShard,
    ProviderEndpoint, SerializeOptions,
};
use crate::broker::manager::{FaultTally, ManagerError, ManagerRun, RunDetail};
use crate::broker::provider_proxy::CircuitBreaker;
use crate::broker::state::TaskRegistry;
use crate::metrics::{Overhead, RunMetrics};
use crate::sim::hpc::{HpcTaskSpec, MultiPilotSim};
use crate::util::json::Json;
use crate::util::json_scan::JsonScanner;
use crate::util::Stopwatch;
use std::borrow::Borrow;

/// Translate tasks into pilot task specs (the HPC path's "partition"
/// phase: translation to connector task dicts).
pub fn pilot_specs<T: Borrow<TaskDescription>>(tasks: &[(TaskId, T)]) -> Vec<HpcTaskSpec> {
    tasks
        .iter()
        .map(|(id, t)| {
            let t = t.borrow();
            let (work_s, sleep_s) = match t.payload {
                Payload::Noop => (0.0, 0.0),
                Payload::Sleep(s) => (0.0, s),
                Payload::Work(w) => (w, 0.0),
                Payload::Compute(_) => (0.0, 0.0),
            };
            HpcTaskSpec { task_id: id.0, cores: t.cpus, work_s, sleep_s }
        })
        .collect()
}

/// Serialize the bulk RADICAL-Pilot-style submission document as
/// contiguous task shards on scoped threads (§Perf; `opts.threads == 1`
/// is the serial reference path and the framed bytes are identical for
/// every thread count). `specs` must be index-aligned with `tasks`
/// (see [`pilot_specs`]).
pub fn bulk_task_document<T: Borrow<TaskDescription> + Sync>(
    tasks: &[(TaskId, T)],
    specs: &[HpcTaskSpec],
    opts: SerializeOptions,
) -> Vec<ManifestShard> {
    assert_eq!(tasks.len(), specs.len(), "specs must align with tasks");
    serialize_sharded(tasks, opts, 128, |out, (id, t), i| {
        task_dict(*id, t.borrow(), &specs[i]).write_into(out)
    })
}

/// Contiguous task chunks for sharding the bulk submission transport
/// across `pilots` agents: one chunk per payload, in task order. An empty
/// workload still ships one (empty) payload, so `pilots == 1` frames
/// exactly the single-payload reference bytes at every task count.
pub fn pilot_chunks(tasks: usize, pilots: u32) -> Vec<(usize, usize)> {
    if tasks == 0 {
        return vec![(0, 0)];
    }
    shard_ranges(tasks, pilots.max(1) as usize)
}

pub struct HpcManager {
    pub config: ProviderConfig,
    pub resource: ResourceRequest,
    pub seed: u64,
    /// Injected per-task failure probability (0 = reliable platform).
    /// Seeded from `resource.task_failure_rate`;
    /// [`HpcManager::with_failure_handling`] still overrides.
    pub failure_rate: f64,
    /// Cancel not-yet-started tasks after the first failure.
    pub cancel_on_failure: bool,
    /// Serialize-phase fan-out; defaults to available parallelism.
    pub serialize: SerializeOptions,
    /// Per-provider circuit breaker shared with the provider handle.
    pub breaker: CircuitBreaker,
}

impl HpcManager {
    pub fn new(
        config: ProviderConfig,
        resource: ResourceRequest,
        seed: u64,
    ) -> Result<HpcManager, ManagerError> {
        crate::broker::manager::validate_binding(&config, &resource)?;
        let failure_rate = resource.task_failure_rate;
        Ok(HpcManager {
            config,
            resource,
            seed,
            failure_rate,
            cancel_on_failure: false,
            serialize: SerializeOptions::default(),
            breaker: CircuitBreaker::default(),
        })
    }

    pub fn with_failure_handling(mut self, failure_rate: f64, cancel_on_failure: bool) -> Self {
        self.failure_rate = failure_rate;
        self.cancel_on_failure = cancel_on_failure;
        self
    }

    pub fn with_serialize(mut self, serialize: SerializeOptions) -> Self {
        self.serialize = serialize;
        self
    }

    /// Share an existing per-provider circuit breaker.
    pub fn with_breaker(mut self, breaker: CircuitBreaker) -> Self {
        self.breaker = breaker;
        self
    }

    /// Execute a workload: validate → serialize bulk task descriptions
    /// (one transport payload per pilot) → submit onto the pilot fleet →
    /// trace to completion.
    ///
    /// Generic over `Borrow<TaskDescription>`: the service proxy passes
    /// `Arc<TaskDescription>` handles shared with the registry (§Perf: no
    /// description clone per manager hop). `Sync` because the serialize
    /// phase fans the batch out over scoped threads.
    pub fn execute<T: Borrow<TaskDescription> + Sync>(
        &self,
        tasks: &[(TaskId, T)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        let ids: Vec<TaskId> = tasks.iter().map(|(id, _)| *id).collect();
        for (_, t) in tasks {
            t.borrow().validate().map_err(ManagerError::InvalidTask)?;
        }
        registry.transition_all(&ids, TaskState::Validated)?;

        // -- OVH: build pilot task descriptions ("partitioning" on the
        // HPC path is the translation to connector task dicts) ----------
        let sw = Stopwatch::start();
        let specs = pilot_specs(tasks);
        let partition_s = sw.elapsed_secs();
        registry.transition_all(&ids, TaskState::Partitioned)?;

        // -- OVH: serialize the bulk submission (RADICAL-Pilot-style task
        // description dicts), the transport sharded across the pilot
        // agents — one JSON document per pilot over contiguous task
        // chunks, each serialized on scoped threads (§Perf). One pilot =
        // one document = the serial reference bytes.
        let sw = Stopwatch::start();
        let chunks = pilot_chunks(tasks.len(), self.resource.pilots);
        let per_pilot: Vec<Vec<ManifestShard>> = chunks
            .iter()
            .map(|&(lo, hi)| bulk_task_document(&tasks[lo..hi], &specs[lo..hi], self.serialize))
            .collect();
        let serialize_s = sw.elapsed_secs();

        // -- OVH: frame + submit -----------------------------------------
        // Each pilot's document is framed directly from its shard buffers
        // (one copy per shard) and shipped through the shared
        // provider-API sink before the fleet takes the specs. The shipped
        // total is asserted against the span-table accounting.
        let bytes_serialized: usize = per_pilot
            .iter()
            .flat_map(|shards| shards.iter())
            .map(ManifestShard::item_bytes)
            .sum();
        let sw = Stopwatch::start();
        let mut endpoint = ProviderEndpoint::new(
            self.resource.provider_fault,
            self.resource.retry,
            self.breaker.clone(),
            self.seed,
        );
        let mut expected_bulk = 0usize;
        let mut bulk_bytes = 0usize;
        for (shards, &(lo, hi)) in per_pilot.iter().zip(&chunks) {
            expected_bulk += expected_framed_len(shards);
            let receipt = endpoint.submit_acked(&frame_bulk(shards, self.serialize))?;
            bulk_bytes += receipt.bytes;
            // -- ingest: verify the provider's ack per chunk (ISSUE 10) --
            // Inside the submit stopwatch window, charged into OVH.
            verify_ack(
                &receipt.ack,
                hi - lo,
                tasks.get(lo).map(|(id, _)| *id),
                hi.checked_sub(1).and_then(|j| tasks.get(j)).map(|(id, _)| *id),
            )?;
        }
        assert_eq!(bulk_bytes, expected_bulk, "bulk framing lost bytes");
        let mut sim =
            MultiPilotSim::new(self.config.profile(), self.resource.pilot_fleet(), self.seed)
                .with_failure_rate(self.failure_rate)
                .with_faults(self.resource.fault);
        sim.submit(specs.clone());
        let submit_s = sw.elapsed_secs();
        registry.transition_all(&ids, TaskState::Submitted)?;

        // -- platform: the pilot fleet executes in virtual time -----------
        let report = sim.run();

        // -- OVH: resubmission transport per retry wave (ISSUE 6) ---------
        // Every dead-pilot rollback that re-queued tasks costs one more
        // framed `[dict,...]` bulk over the connector — real transport
        // bytes the healthy path never pays, accounted separately from
        // the initial submission.
        let mut retry_bulk_bytes = 0usize;
        let mut retried = 0usize;
        for wave in &report.retry_waves {
            let mut doc = String::with_capacity(2 + wave.tasks.len() * 64);
            doc.push('[');
            for (k, &idx) in wave.tasks.iter().enumerate() {
                if k > 0 {
                    doc.push(',');
                }
                task_dict(tasks[idx].0, tasks[idx].1.borrow(), &specs[idx]).write_into(&mut doc);
            }
            doc.push(']');
            // Retry waves ride the same acked transport as the initial
            // submission: count + uid spot-checks per wave payload.
            let receipt = endpoint.submit_acked(doc.as_bytes())?;
            retry_bulk_bytes += receipt.bytes;
            verify_ack(
                &receipt.ack,
                wave.tasks.len(),
                wave.tasks.first().map(|&idx| tasks[idx].0),
                wave.tasks.last().map(|&idx| tasks[idx].0),
            )?;
            retried += wave.tasks.len();
        }

        // Abandoned tasks (retry budget exhausted, or the whole fleet
        // died) reach a final state instead of being silently dropped.
        for &task_id in &report.abandoned {
            registry.transition_virtual(
                TaskId(task_id),
                TaskState::Failed,
                Some(report.makespan_s),
            )?;
        }

        let first_fail = report
            .tasks
            .iter()
            .filter(|r| r.failed)
            .map(|r| r.finished_s)
            .fold(f64::INFINITY, f64::min);
        for rec in &report.tasks {
            if rec.failed {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Running,
                    Some(rec.launched_s),
                )?;
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Failed,
                    Some(rec.finished_s),
                )?;
            } else if self.cancel_on_failure && rec.launched_s > first_fail {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Canceled,
                    Some(first_fail),
                )?;
            } else {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Running,
                    Some(rec.launched_s),
                )?;
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Done,
                    Some(rec.finished_s),
                )?;
            }
        }

        let metrics = RunMetrics {
            provider: self.config.id,
            tasks: tasks.len(),
            // "pods" on the HPC path counts connector task descriptions.
            pods: tasks.len(),
            // Simulated backoff (initial + retry-wave submits) is charged
            // into the submit-phase OVH: resilience has a cost.
            ovh: Overhead {
                partition_s,
                serialize_s,
                submit_s: submit_s + endpoint.backoff_s(),
            },
            tpt_s: report.makespan_s,
            ttx_s: report.makespan_s,
        };
        let faults = FaultTally {
            failed: report.tasks.iter().filter(|r| r.failed).count(),
            retried,
            abandoned: report.abandoned.len(),
            retry_waves: report.retry_waves.len(),
            retry_bulk_bytes,
            submit_retries: endpoint.submit_retries(),
            backoff_ms: endpoint.backoff_ms(),
            circuit_opens: endpoint.circuit_opens(),
            failed_over: 0,
        };
        Ok(ManagerRun {
            metrics,
            bytes_serialized,
            bulk_bytes,
            faults,
            detail: RunDetail::Hpc { sim: report },
        })
    }
}

/// Verify a provider ack against what this manager framed (ISSUE 10).
///
/// The endpoint echoes `{"ack":"hydra/v1","count":..,"first_id":..,
/// "last_id":..}` per accepted payload; the HPC task dicts carry their id
/// as the `uid` *string* (`task.%06d`), so the spot-check compares the
/// echoed strings against the expected [`TaskId`] renderings. A mismatch
/// is payload corruption on an already-accepted submission — terminal,
/// never retryable (resubmitting would duplicate work).
fn verify_ack(
    ack: &str,
    expect: usize,
    first: Option<TaskId>,
    last: Option<TaskId>,
) -> Result<(), ManagerError> {
    let scan = JsonScanner::new(ack.as_bytes());
    let count = scan.path_u64(&["count"]);
    if count != Some(expect as u64) {
        return Err(ManagerError::AckMismatch {
            message: format!("framed {expect} task dicts, provider acked {count:?}"),
        });
    }
    let checks = [
        ("first", first, scan.path_str(&["first_id"])),
        ("last", last, scan.path_str(&["last_id"])),
    ];
    for (which, want, got) in checks {
        let Some(want) = want else { continue };
        let want = format!("{want}");
        if got != Some(want.as_str()) {
            return Err(ManagerError::AckMismatch {
                message: format!("{which} task uid {want:?} not echoed, got {got:?}"),
            });
        }
    }
    Ok(())
}

/// RADICAL-Pilot-style task description document.
fn task_dict(id: TaskId, t: &TaskDescription, spec: &HpcTaskSpec) -> Json {
    let exe = match &t.kind {
        crate::api::task::TaskKind::Executable { command } => command.clone(),
        crate::api::task::TaskKind::Container { image } => format!("singularity run {image}"),
        // A function routed to a pilot runs through a handler shim.
        crate::api::task::TaskKind::Function { handler } => format!("faas-shim {handler}"),
    };
    Json::obj()
        .set("uid", format!("{id}"))
        .set("executable", exe)
        .set("cpu_processes", spec.cores as u64)
        .set("gpu_processes", t.gpus as u64)
        .set("mem_per_process", format!("{}MB", t.mem_mb))
        .set(
            "arguments",
            Json::Arr(vec![Json::Num(spec.work_s), Json::Num(spec.sleep_s)]),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::provider::ProviderId;

    fn manager(nodes: u32) -> HpcManager {
        manager_with_pilots(nodes, 1)
    }

    fn manager_with_pilots(nodes: u32, pilots: u32) -> HpcManager {
        HpcManager::new(
            ProviderConfig::simulated(ProviderId::Bridges2),
            ResourceRequest::hpc(ProviderId::Bridges2, nodes, pilots),
            11,
        )
        .unwrap()
    }

    fn workload(reg: &TaskRegistry, n: usize, sleep: f64) -> Vec<(TaskId, TaskDescription)> {
        (0..n)
            .map(|i| {
                let d = TaskDescription::executable(format!("e{i}"), "/bin/sleep")
                    .with_payload(Payload::Sleep(sleep));
                (reg.register(d.clone()), d)
            })
            .collect()
    }

    #[test]
    fn executes_bulk_to_done() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 200, 0.0);
        let r = manager(1).execute(&tasks, &reg).unwrap();
        assert_eq!(r.metrics.tasks, 200);
        assert!(r.metrics.tpt_s > r.detail.hpc_sim().unwrap().first_agent_ready_s());
        assert!(r.bytes_serialized > 200 * 50);
        assert!(r.bulk_bytes > r.bytes_serialized, "framed envelope bytes missing");
        assert!(reg.all_final());
    }

    #[test]
    fn hpc_ack_verification_flags_mismatches() {
        let first = Some(TaskId(0));
        let last = Some(TaskId(2));
        // A faithful ack passes: HPC ids are echoed as `uid` strings.
        let good =
            r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":"task.000000","last_id":"task.000002"}"#;
        assert!(verify_ack(good, 3, first, last).is_ok());
        // Count, first-uid and last-uid disagreements are each terminal.
        for bad in [
            r#"{"ack":"hydra/v1","count":2,"bytes":10,"first_id":"task.000000","last_id":"task.000002"}"#,
            r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":"task.000007","last_id":"task.000002"}"#,
            r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":"task.000000","last_id":null}"#,
        ] {
            let e = verify_ack(bad, 3, first, last).unwrap_err();
            assert!(matches!(e, ManagerError::AckMismatch { .. }), "{bad}");
            assert!(!e.retryable(), "ack mismatch must never be re-brokered");
        }
        // Empty chunk (`pilot_chunks(0, _)` yields one `[]` payload):
        // count 0, no uid spot-checks.
        let empty = r#"{"ack":"hydra/v1","count":0,"bytes":2,"first_id":null,"last_id":null}"#;
        assert!(verify_ack(empty, 0, None, None).is_ok());
    }

    #[test]
    fn sleep_tasks_have_platform_independent_duration() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 1, 5.0);
        let r = manager(1).execute(&tasks, &reg).unwrap();
        let t = &r.detail.hpc_sim().unwrap().tasks[0];
        assert!(((t.finished_s - t.launched_s) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn bulk_task_document_is_thread_count_invariant() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 300, 2.5);
        let specs = pilot_specs(&tasks);
        let serial_opts = SerializeOptions::serial();
        let serial = frame_bulk(&bulk_task_document(&tasks, &specs, serial_opts), serial_opts);
        assert_eq!(serial[0], b'[');
        for threads in [2, 8] {
            let opts = SerializeOptions::with_threads(threads);
            let bulk = frame_bulk(&bulk_task_document(&tasks, &specs, opts), opts);
            assert_eq!(bulk, serial, "threads={threads}");
        }
    }

    #[test]
    fn rejects_cloud_resource() {
        let e = HpcManager::new(
            ProviderConfig::simulated(ProviderId::Bridges2),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 8),
            0,
        );
        assert!(e.is_err());
    }

    #[test]
    fn failure_injection_and_graceful_termination() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 300, 1.0);
        let m = manager(1).with_failure_handling(0.1, true);
        m.execute(&tasks, &reg).unwrap();
        let counts = reg.counts();
        assert!(counts.get(&TaskState::Failed).copied().unwrap_or(0) > 5, "{counts:?}");
        assert!(counts.get(&TaskState::Canceled).copied().unwrap_or(0) > 0, "{counts:?}");
        assert!(reg.all_final());
    }

    #[test]
    fn pilot_chunks_tile_the_workload() {
        assert_eq!(pilot_chunks(0, 1), vec![(0, 0)], "empty bulk still ships one payload");
        assert_eq!(pilot_chunks(0, 4), vec![(0, 0)]);
        assert_eq!(pilot_chunks(10, 1), vec![(0, 10)]);
        assert_eq!(pilot_chunks(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        // Fewer tasks than pilots: one chunk per task, never an empty one.
        assert_eq!(pilot_chunks(2, 4).len(), 2);
    }

    #[test]
    fn multi_pilot_run_reports_per_pilot_utilization() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 400, 2.0);
        let r = manager_with_pilots(1, 4).execute(&tasks, &reg).unwrap();
        let sim = r.detail.hpc_sim().unwrap();
        assert_eq!(sim.pilots.len(), 4);
        assert_eq!(sim.tasks.len(), 400);
        assert_eq!(sim.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(), 400);
        for (i, p) in sim.pilots.iter().enumerate() {
            assert_eq!(p.total_cores, 128, "pilot {i}");
            assert!(p.peak_cores_busy <= p.total_cores, "pilot {i}");
            assert!((0.0..=1.0).contains(&p.utilization), "pilot {i}: {}", p.utilization);
        }
        assert!(reg.all_final());
    }

    #[test]
    fn sharded_submission_byte_accounting_reconciles() {
        // pilots = P ships k = min(P, n) framed payloads: total bulk
        // bytes must equal item_bytes + (n - k) separators between items
        // + 2k brackets = item_bytes + n + k — for every pilot count.
        for pilots in [1u32, 3, 4, 7] {
            let reg = TaskRegistry::new();
            let n = 250usize;
            let tasks = workload(&reg, n, 0.0);
            let r = manager_with_pilots(1, pilots).execute(&tasks, &reg).unwrap();
            let payloads = (pilots as usize).min(n);
            assert_eq!(
                r.bulk_bytes,
                r.bytes_serialized + n + payloads,
                "pilots={pilots}"
            );
        }
    }

    #[test]
    fn item_bytes_invariant_across_pilot_counts() {
        // Sharding the transport must not change what is serialized —
        // only how it is framed.
        let mk = |pilots: u32| {
            let reg = TaskRegistry::new();
            let tasks = workload(&reg, 300, 1.0);
            manager_with_pilots(1, pilots).execute(&tasks, &reg).unwrap().bytes_serialized
        };
        let one = mk(1);
        for pilots in [2u32, 8] {
            assert_eq!(mk(pilots), one, "pilots={pilots}");
        }
    }

    #[test]
    fn pilot_kill_surfaces_retry_stats_and_transport_bytes() {
        use crate::api::resource::FaultSpec;
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 300, 60.0);
        let resource = ResourceRequest::hpc(ProviderId::Bridges2, 1, 2)
            .with_faults(FaultSpec { injected_kill: Some((0, 20.0)), ..FaultSpec::none() });
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        let r = m.execute(&tasks, &reg).unwrap();
        let sim = r.detail.hpc_sim().unwrap();
        assert!(sim.pilots[0].died_at.is_some(), "pilot 0 must die");
        assert!(r.faults.retried > 0, "mid-run kill must re-queue tasks");
        assert_eq!(r.faults.retry_waves, 1);
        assert!(r.faults.retry_bulk_bytes > 0, "resubmission transport must be charged");
        assert_eq!(r.faults.abandoned, 0, "survivor absorbs every retry");
        assert_eq!(sim.tasks.len(), 300, "every task still completes");
        assert!(reg.all_final());
    }

    #[test]
    fn exhausted_retry_budget_reports_abandoned_tasks_as_failed() {
        use crate::api::resource::FaultSpec;
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 50, 600.0);
        // Single pilot killed mid-run with budget 0: nothing survives.
        let resource = ResourceRequest::hpc(ProviderId::Bridges2, 1, 1).with_faults(FaultSpec {
            injected_kill: Some((0, 10.0)),
            retry_budget: 0,
            ..FaultSpec::none()
        });
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.faults.abandoned, 50);
        assert_eq!(r.faults.retried, 0);
        assert_eq!(r.faults.retry_bulk_bytes, 0);
        let counts = reg.counts();
        assert_eq!(counts.get(&TaskState::Failed).copied().unwrap_or(0), 50, "{counts:?}");
        assert!(reg.all_final(), "abandoned tasks must reach a final state");
    }

    #[test]
    fn heterogeneous_fleet_stages_mixed_pilot_widths() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 200, 2.0);
        let resource =
            ResourceRequest::pilot(ProviderId::Bridges2, 1).with_pilot_nodes(&[1, 2, 4]);
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        let r = m.execute(&tasks, &reg).unwrap();
        let sim = r.detail.hpc_sim().unwrap();
        let widths: Vec<u32> = sim.pilots.iter().map(|p| p.total_cores).collect();
        assert_eq!(widths, vec![128, 256, 512]);
        assert_eq!(sim.tasks.len(), 200);
        assert!(reg.all_final());
    }

    #[test]
    fn task_failure_rate_flows_from_the_resource_request() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 400, 1.0);
        let resource =
            ResourceRequest::hpc(ProviderId::Bridges2, 1, 1).with_task_failure_rate(0.1);
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        assert!((m.failure_rate - 0.1).abs() < 1e-12);
        let r = m.execute(&tasks, &reg).unwrap();
        assert!(r.faults.failed > 5, "failed count must surface upward: {:?}", r.faults);
        let counts = reg.counts();
        assert_eq!(
            counts.get(&TaskState::Failed).copied().unwrap_or(0),
            r.faults.failed,
            "{counts:?}"
        );
        assert!(reg.all_final());
    }

    #[test]
    fn control_plane_outage_is_ridden_out_and_tallied() {
        use crate::api::resource::ProviderFaultSpec;
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 100, 1.0);
        let resource =
            ResourceRequest::hpc(ProviderId::Bridges2, 1, 1).with_provider_faults(
                ProviderFaultSpec { outage_window: Some((0.0, 0.12)), ..ProviderFaultSpec::none() },
            );
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.faults.submit_retries, 2, "two backoffs ride out a 0.12s outage");
        assert!(r.faults.backoff_ms > 0);
        assert!(r.metrics.ovh.submit_s > 0.13, "backoff charged into OVH");
        assert_eq!(r.faults.circuit_opens, 0);
        assert!(reg.all_final());

        // A hard outage errors before any task reaches a final state.
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 20, 1.0);
        let resource =
            ResourceRequest::hpc(ProviderId::Bridges2, 1, 1).with_provider_faults(
                ProviderFaultSpec { outage_window: Some((0.0, 1e9)), ..ProviderFaultSpec::none() },
            );
        let m = HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), resource, 11)
            .unwrap();
        let e = m.execute(&tasks, &reg).unwrap_err();
        assert!(e.retryable());
        for (id, _) in &tasks {
            assert_eq!(reg.state_of(*id), Some(TaskState::Partitioned));
        }
    }

    #[test]
    fn ovh_scales_with_tasks_not_nodes() {
        // Exp 3A's claim: HPC capabilities add no task-count-independent
        // overhead; OVH tracks #tasks, and adding nodes leaves it flat.
        // Best-of-3 per configuration to shed wall-clock noise.
        let best = |nodes: u32| {
            (0..3)
                .map(|_| {
                    let reg = TaskRegistry::new();
                    let tasks = workload(&reg, 1000, 0.0);
                    manager(nodes).execute(&tasks, &reg).unwrap().metrics.ovh.total_s()
                })
                .fold(f64::INFINITY, f64::min)
        };
        let o1 = best(1);
        let o6 = best(6);
        let r = o6 / o1;
        assert!(r > 0.2 && r < 5.0, "node count changed OVH by {r}x");
    }
}
