//! Task registry: the broker's source of truth for task state.
//!
//! Every task submitted through Hydra lives here with its description,
//! current state, and trace of transitions. Transitions are validated
//! against the `TaskState` machine — an illegal transition is a broker
//! bug, surfaced as an error rather than silently recorded.

use crate::api::task::{TaskDescription, TaskId, TaskState};
use crate::metrics::TraceLog;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug)]
pub struct TaskEntry {
    /// Shared handle: the registry, the policy layer, and every manager
    /// thread read the same immutable description instead of cloning it
    /// per hop (§Perf data-path overhaul).
    pub desc: Arc<TaskDescription>,
    pub state: TaskState,
}

/// Shared, thread-safe registry (service managers run on their own
/// threads and report transitions concurrently).
#[derive(Clone, Default)]
pub struct TaskRegistry {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default)]
struct Inner {
    /// Keyed by task id. A `BTreeMap` on purpose: `counts()` and
    /// `all_final()` iterate this map, and the iteration order must be
    /// deterministic (sorted by id) or monitoring output would vary
    /// run-to-run under an unordered map (hydra-lint `hash-order`).
    tasks: BTreeMap<u64, TaskEntry>,
    trace: Option<TraceLog>,
    next_id: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum StateError {
    UnknownTask(TaskId),
    IllegalTransition { task: TaskId, from: TaskState, to: TaskState },
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::UnknownTask(id) => write!(f, "unknown task {id}"),
            StateError::IllegalTransition { task, from, to } => {
                write!(f, "{task}: illegal transition {from} -> {to}")
            }
        }
    }
}

impl std::error::Error for StateError {}

impl TaskRegistry {
    pub fn new() -> TaskRegistry {
        let reg = TaskRegistry { inner: Arc::new(Mutex::new(Inner::default())) };
        reg.inner.lock().unwrap().trace = Some(TraceLog::new());
        reg
    }

    /// Register a new task in state `New`, returning its id.
    pub fn register(&self, desc: TaskDescription) -> TaskId {
        let mut g = self.inner.lock().unwrap();
        Self::register_locked(&mut g, desc).0
    }

    /// Register a whole workload, preserving order. Takes the mutex once
    /// for the whole batch (§Perf: was one lock per task).
    pub fn register_all(&self, descs: Vec<TaskDescription>) -> Vec<TaskId> {
        let mut g = self.inner.lock().unwrap();
        descs
            .into_iter()
            .map(|d| Self::register_locked(&mut g, d).0)
            .collect()
    }

    /// Register a whole workload and hand back the shared description
    /// handles in one lock acquisition — the broker's submit path uses
    /// this instead of `register_all` + `descriptions_of` (§Perf: no
    /// second lock/lookup round-trip for descriptions it just stored).
    pub fn register_all_shared(
        &self,
        descs: Vec<TaskDescription>,
    ) -> Vec<(TaskId, Arc<TaskDescription>)> {
        let mut g = self.inner.lock().unwrap();
        descs
            .into_iter()
            .map(|d| Self::register_locked(&mut g, d))
            .collect()
    }

    /// The single registration implementation; callers hold the lock.
    fn register_locked(g: &mut Inner, desc: TaskDescription) -> (TaskId, Arc<TaskDescription>) {
        let id = TaskId(g.next_id);
        g.next_id += 1;
        let desc = Arc::new(desc);
        g.tasks.insert(id.0, TaskEntry { desc: Arc::clone(&desc), state: TaskState::New });
        if let Some(t) = g.trace.as_mut() {
            t.record(id, TaskState::New);
        }
        (id, desc)
    }

    /// Validated state transition with tracing.
    pub fn transition(&self, id: TaskId, to: TaskState) -> Result<(), StateError> {
        self.transition_virtual(id, to, None)
    }

    /// Transition carrying a virtual (platform) timestamp, used when the
    /// simulator reports completion times.
    pub fn transition_virtual(
        &self,
        id: TaskId,
        to: TaskState,
        virtual_s: Option<f64>,
    ) -> Result<(), StateError> {
        let mut g = self.inner.lock().unwrap();
        let entry = g.tasks.get_mut(&id.0).ok_or(StateError::UnknownTask(id))?;
        if !entry.state.can_transition_to(to) {
            return Err(StateError::IllegalTransition { task: id, from: entry.state, to });
        }
        entry.state = to;
        if let Some(t) = g.trace.as_mut() {
            t.record_virtual(id, to, virtual_s);
        }
        Ok(())
    }

    /// Bulk transition (used on the partition/submit path — one lock
    /// acquisition for the whole batch, not one per task).
    pub fn transition_all(&self, ids: &[TaskId], to: TaskState) -> Result<(), StateError> {
        let mut g = self.inner.lock().unwrap();
        for id in ids {
            let entry = g.tasks.get_mut(&id.0).ok_or(StateError::UnknownTask(*id))?;
            if !entry.state.can_transition_to(to) {
                return Err(StateError::IllegalTransition { task: *id, from: entry.state, to });
            }
        }
        for id in ids {
            g.tasks.get_mut(&id.0).unwrap().state = to;
            if let Some(t) = g.trace.as_mut() {
                t.record(*id, to);
            }
        }
        Ok(())
    }

    /// Reset a failed provider's unfinished slice to `New` so it can be
    /// re-brokered to a surviving provider (ISSUE 7 failover path).
    ///
    /// This is the one deliberate exception to the forward-only state
    /// machine: a provider-local submit failure leaves its tasks stranded
    /// mid-pipeline (`Validated`/`Partitioned`/`Submitted`), and the
    /// failover leg re-runs them through a fresh manager from the top.
    /// Final states are never rewound — any final task in `ids` fails the
    /// whole batch before anything moves (exactly-once: a completed task
    /// cannot be re-queued onto a second provider).
    pub fn requeue_for_failover(&self, ids: &[TaskId]) -> Result<(), StateError> {
        let mut g = self.inner.lock().unwrap();
        for id in ids {
            let entry = g.tasks.get(&id.0).ok_or(StateError::UnknownTask(*id))?;
            if entry.state.is_final() {
                return Err(StateError::IllegalTransition {
                    task: *id,
                    from: entry.state,
                    to: TaskState::New,
                });
            }
        }
        for id in ids {
            g.tasks.get_mut(&id.0).unwrap().state = TaskState::New;
            if let Some(t) = g.trace.as_mut() {
                t.record(*id, TaskState::New);
            }
        }
        Ok(())
    }

    pub fn state_of(&self, id: TaskId) -> Option<TaskState> {
        self.inner.lock().unwrap().tasks.get(&id.0).map(|e| e.state)
    }

    /// Shared handle to one task's description (cheap refcount bump, no
    /// deep clone).
    pub fn description_of(&self, id: TaskId) -> Option<Arc<TaskDescription>> {
        self.inner.lock().unwrap().tasks.get(&id.0).map(|e| Arc::clone(&e.desc))
    }

    /// Bulk description lookup: one mutex acquisition for the whole id
    /// slice, in id order. Managers resolving per-task descriptions in a
    /// loop should call this instead of `description_of` per task (§Perf).
    pub fn descriptions_of(
        &self,
        ids: &[TaskId],
    ) -> Result<Vec<Arc<TaskDescription>>, StateError> {
        let g = self.inner.lock().unwrap();
        ids.iter()
            .map(|id| {
                g.tasks
                    .get(&id.0)
                    .map(|e| Arc::clone(&e.desc))
                    .ok_or(StateError::UnknownTask(*id))
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of tasks per state (monitoring surface). Both the task map
    /// iterated here and the returned map are ordered, so the counts and
    /// any report derived from them are stable across runs.
    pub fn counts(&self) -> BTreeMap<TaskState, usize> {
        let g = self.inner.lock().unwrap();
        let mut m = BTreeMap::new();
        for e in g.tasks.values() {
            *m.entry(e.state).or_insert(0) += 1;
        }
        m
    }

    /// True when every registered task reached a final state.
    pub fn all_final(&self) -> bool {
        self.inner.lock().unwrap().tasks.values().all(|e| e.state.is_final())
    }

    /// Export the trace as JSON (events in recording order).
    pub fn trace_json(&self) -> crate::util::json::Json {
        let g = self.inner.lock().unwrap();
        g.trace.as_ref().map(|t| t.to_json()).unwrap_or(crate::util::json::Json::Arr(vec![]))
    }

    pub fn trace_len(&self) -> usize {
        self.inner.lock().unwrap().trace.as_ref().map(|t| t.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::TaskDescription;

    fn desc() -> TaskDescription {
        TaskDescription::container("t", "noop:latest")
    }

    #[test]
    fn register_assigns_sequential_ids() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all(vec![desc(), desc(), desc()]);
        assert_eq!(ids, vec![TaskId(0), TaskId(1), TaskId(2)]);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.state_of(TaskId(1)), Some(TaskState::New));
    }

    #[test]
    fn legal_path_traced() {
        let reg = TaskRegistry::new();
        let id = reg.register(desc());
        for s in [
            TaskState::Validated,
            TaskState::Partitioned,
            TaskState::Submitted,
            TaskState::Running,
            TaskState::Done,
        ] {
            reg.transition(id, s).unwrap();
        }
        assert_eq!(reg.state_of(id), Some(TaskState::Done));
        assert_eq!(reg.trace_len(), 6); // New + 5 transitions
        assert!(reg.all_final());
    }

    #[test]
    fn illegal_transition_rejected_and_state_unchanged() {
        let reg = TaskRegistry::new();
        let id = reg.register(desc());
        let e = reg.transition(id, TaskState::Running).unwrap_err();
        assert!(matches!(e, StateError::IllegalTransition { .. }));
        assert_eq!(reg.state_of(id), Some(TaskState::New));
    }

    #[test]
    fn unknown_task_errors() {
        let reg = TaskRegistry::new();
        assert_eq!(
            reg.transition(TaskId(99), TaskState::Validated),
            Err(StateError::UnknownTask(TaskId(99)))
        );
        assert!(reg.state_of(TaskId(99)).is_none());
    }

    #[test]
    fn bulk_transition_is_atomic() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all(vec![desc(), desc()]);
        reg.transition(ids[1], TaskState::Validated).unwrap();
        // ids[0] is New (can go Validated), ids[1] already Validated
        // (cannot go Validated again) => whole bulk fails, nothing moves.
        let e = reg.transition_all(&ids, TaskState::Validated).unwrap_err();
        assert!(matches!(e, StateError::IllegalTransition { .. }));
        assert_eq!(reg.state_of(ids[0]), Some(TaskState::New));
    }

    #[test]
    fn requeue_for_failover_rewinds_non_final_tasks_only() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all(vec![desc(), desc(), desc()]);
        reg.transition_all(&ids, TaskState::Validated).unwrap();
        reg.transition_all(&ids, TaskState::Partitioned).unwrap();
        // The whole stranded slice rewinds to New and can run again.
        reg.requeue_for_failover(&ids).unwrap();
        for id in &ids {
            assert_eq!(reg.state_of(*id), Some(TaskState::New));
        }
        reg.transition_all(&ids, TaskState::Validated).unwrap();

        // A final task in the batch fails it atomically: exactly-once
        // means a Done task is never re-queued onto another provider.
        for s in [TaskState::Partitioned, TaskState::Submitted, TaskState::Running,
                  TaskState::Done] {
            reg.transition(ids[0], s).unwrap();
        }
        let e = reg.requeue_for_failover(&ids).unwrap_err();
        assert!(matches!(e, StateError::IllegalTransition { .. }));
        assert_eq!(reg.state_of(ids[1]), Some(TaskState::Validated), "nothing moved");
        // Unknown ids are rejected too.
        assert_eq!(
            reg.requeue_for_failover(&[TaskId(99)]),
            Err(StateError::UnknownTask(TaskId(99)))
        );
    }

    #[test]
    fn counts_by_state() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all(vec![desc(), desc(), desc()]);
        reg.transition(ids[0], TaskState::Validated).unwrap();
        let c = reg.counts();
        assert_eq!(c.get(&TaskState::New), Some(&2));
        assert_eq!(c.get(&TaskState::Validated), Some(&1));
    }

    /// Regression test for the ISSUE 9 hash-order hazard: `counts()`
    /// used to fold a `HashMap` iteration into a `HashMap`, so the order
    /// monitoring consumers observed could vary run-to-run. Both maps
    /// are ordered now — the per-state enumeration must come out in
    /// lifecycle (declaration) order, identically on every build.
    #[test]
    fn counts_enumerate_states_in_stable_order() {
        let build = || {
            let reg = TaskRegistry::new();
            let ids = reg.register_all((0..6).map(|_| desc()).collect());
            reg.transition(ids[0], TaskState::Validated).unwrap();
            reg.transition(ids[1], TaskState::Validated).unwrap();
            reg.transition(ids[1], TaskState::Partitioned).unwrap();
            for s in [
                TaskState::Validated,
                TaskState::Partitioned,
                TaskState::Submitted,
                TaskState::Running,
                TaskState::Done,
            ] {
                reg.transition(ids[2], s).unwrap();
            }
            reg.counts().into_iter().collect::<Vec<_>>()
        };
        let first = build();
        assert_eq!(
            first,
            vec![
                (TaskState::New, 3),
                (TaskState::Validated, 1),
                (TaskState::Partitioned, 1),
                (TaskState::Done, 1),
            ],
            "states must enumerate in lifecycle order with exact counts"
        );
        for _ in 0..10 {
            assert_eq!(build(), first, "enumeration order must not vary across runs");
        }
    }

    #[test]
    fn concurrent_transitions_from_threads() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all((0..100).map(|_| desc()).collect());
        reg.transition_all(&ids, TaskState::Validated).unwrap();
        let mut handles = Vec::new();
        for chunk in ids.chunks(25) {
            let reg = reg.clone();
            let chunk = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                for id in chunk {
                    reg.transition(id, TaskState::Partitioned).unwrap();
                    reg.transition(id, TaskState::Submitted).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counts().get(&TaskState::Submitted), Some(&100));
    }

    #[test]
    fn descriptions_of_resolves_in_order_with_one_lock() {
        let reg = TaskRegistry::new();
        let ids = reg.register_all(vec![
            TaskDescription::container("a", "img"),
            TaskDescription::container("b", "img"),
            TaskDescription::container("c", "img"),
        ]);
        let descs = reg.descriptions_of(&ids).unwrap();
        assert_eq!(descs.len(), 3);
        assert_eq!(descs[0].name, "a");
        assert_eq!(descs[2].name, "c");
        // Handles are shared with the registry, not deep copies.
        assert!(Arc::ptr_eq(&descs[1], &reg.description_of(ids[1]).unwrap()));
        // Unknown ids error rather than silently skipping.
        let e = reg.descriptions_of(&[ids[0], TaskId(999)]).unwrap_err();
        assert_eq!(e, StateError::UnknownTask(TaskId(999)));
    }

    #[test]
    fn register_all_shared_hands_back_registry_handles() {
        let reg = TaskRegistry::new();
        let tasks = reg.register_all_shared(vec![
            TaskDescription::container("x", "img"),
            TaskDescription::container("y", "img"),
        ]);
        assert_eq!(tasks[0].0, TaskId(0));
        assert_eq!(tasks[1].1.name, "y");
        // Same Arc the registry holds — no copy was made.
        assert!(Arc::ptr_eq(&tasks[0].1, &reg.description_of(tasks[0].0).unwrap()));
        assert_eq!(reg.trace_len(), 2);
        assert_eq!(reg.state_of(TaskId(1)), Some(TaskState::New));
    }

    #[test]
    fn virtual_timestamps_recorded() {
        let reg = TaskRegistry::new();
        let id = reg.register(desc());
        reg.transition(id, TaskState::Validated).unwrap();
        reg.transition(id, TaskState::Partitioned).unwrap();
        reg.transition(id, TaskState::Submitted).unwrap();
        reg.transition(id, TaskState::Running).unwrap();
        reg.transition_virtual(id, TaskState::Done, Some(42.5)).unwrap();
        let j = reg.trace_json();
        let arr = j.as_arr().unwrap();
        let last = arr.last().unwrap();
        assert_eq!(last.get("virtual_s").unwrap().as_f64(), Some(42.5));
    }
}
