//! Data Manager: inter- and cross-cloud/HPC data operations.
//!
//! Paper §3.1: "The manager implements data operations like copy, move,
//! link, delete, and list, both locally and remotely ... supports
//! integration with different data management services as backends and
//! exposes their operations via a unified API."
//!
//! Two backends:
//! * [`LocalFs`] — a *real* filesystem backend rooted in a sandbox
//!   directory (all paths are confined; `..` escapes are rejected).
//! * [`SimObjectStore`] — a simulated remote object store with a bandwidth
//!   model, standing in for the cloud/HPC storage services (17.2 PB on
//!   Jetstream2 etc.) we do not have.
//!
//! The unified entry point is [`DataManager`], which routes `site://path`
//! URIs to registered backends and can build staging plans across sites
//! (e.g. FACTS pre-staging input data on each target platform, §5.4).
//!
//! This module also hosts the broker's **bulk serialization data path**
//! (§Perf, PR 3): the shard/span types and scoped-thread fan-out that the
//! CaaS/FaaS/HPC managers share to serialize task batches in parallel and
//! frame the bulk submission payload copy-free from the shard buffers.

use crate::broker::provider_proxy::CircuitBreaker;
use crate::util::json::{push_u64, write_str_into};
use crate::util::json_scan::JsonScanner;
use crate::util::prng::Prng;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Bulk serialization data path: shard/span types + parallel fan-out
// ---------------------------------------------------------------------------

/// Below this many items a shard is not worth a thread: spawning costs
/// tens of microseconds, serializing 64 manifests costs about the same.
const MIN_ITEMS_PER_SHARD: usize = 64;

/// Bulk payloads below this size are framed serially — the memcpy is
/// cheaper than the scoped-thread fan-in.
const PAR_FRAME_MIN_BYTES: usize = 1 << 20;

/// Thread knob for the broker's serialize phase (ISSUE 3 tentpole).
///
/// `threads == 1` is the serial reference path (byte-identical output is
/// guaranteed for *any* thread count — see `serialize_sharded`);
/// `threads == 0` — the `Default` — resolves to the machine's available
/// parallelism.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SerializeOptions {
    pub threads: usize,
}

impl SerializeOptions {
    /// The serial reference path: exactly today's single-buffer loop.
    pub fn serial() -> SerializeOptions {
        SerializeOptions { threads: 1 }
    }

    pub fn with_threads(threads: usize) -> SerializeOptions {
        SerializeOptions { threads }
    }

    /// Resolve the knob: `0` means available parallelism.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// Shard count for a batch of `items`: capped by the thread knob and
    /// floored so every shard carries at least [`MIN_ITEMS_PER_SHARD`]
    /// items — batches too small to amortize a spawn stay on one thread.
    pub fn shards_for(&self, items: usize) -> usize {
        if items == 0 {
            return 0;
        }
        self.effective_threads().min((items / MIN_ITEMS_PER_SHARD).max(1))
    }
}

/// One shard of a serialized batch: items `[first, first + spans.len())`
/// written back to back into `buf` with single `,` separators *between*
/// items. `spans` are buf-relative `(start, end)` byte ranges of each
/// item, so the separators live in the gaps between spans.
///
/// Concatenating shard buffers joined by `,` reproduces the serial
/// serialization of the whole batch byte for byte — the invariant the
/// bulk framing and the cross-thread equivalence tests rely on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestShard {
    /// Batch index of the first item in this shard.
    pub first: usize,
    pub buf: String,
    pub spans: Vec<(usize, usize)>,
}

impl ManifestShard {
    /// Total serialized item bytes in this shard (separators excluded).
    pub fn item_bytes(&self) -> usize {
        self.spans.iter().map(|(s, e)| e - s).sum()
    }
}

/// Split `0..items` into at most `shards` contiguous, non-empty, balanced
/// ranges, in order. `shard_ranges(10, 3)` → `[(0,4), (4,7), (7,10)]`.
pub fn shard_ranges(items: usize, shards: usize) -> Vec<(usize, usize)> {
    if items == 0 || shards == 0 {
        return Vec::new();
    }
    let shards = shards.min(items);
    let base = items / shards;
    let extra = items % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for i in 0..shards {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Run `f(lo, hi)` over contiguous shard ranges of `0..items` — one
/// scoped `std::thread` per range when there is more than one, inline
/// otherwise (no thread pool, no new deps) — returning per-range results
/// in range order. The shared fan-out under `serialize_sharded` and the
/// partitioner's Disk-mode manifest writer.
pub fn sharded_map<R, F>(items: usize, shards: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    let ranges = shard_ranges(items, shards);
    if ranges.len() <= 1 {
        return ranges.iter().map(|&(lo, hi)| f(lo, hi)).collect();
    }
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(lo, hi)| scope.spawn(move || f(lo, hi)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker thread panicked"))
            .collect()
    })
}

/// Serialize a batch into shards via [`sharded_map`]. `write_one`
/// appends exactly one item's serialized form to the shard buffer and
/// receives the item's batch index; the shard loop records spans and
/// writes the `,` separators, so the concatenated output is
/// byte-identical to the serial path *by construction* for every thread
/// count.
pub fn serialize_sharded<T, F>(
    items: &[T],
    opts: SerializeOptions,
    bytes_per_item_hint: usize,
    write_one: F,
) -> Vec<ManifestShard>
where
    T: Sync,
    F: Fn(&mut String, &T, usize) + Sync,
{
    sharded_map(items.len(), opts.shards_for(items.len()), |lo, hi| {
        let mut buf = String::with_capacity((hi - lo) * bytes_per_item_hint);
        let mut spans = Vec::with_capacity(hi - lo);
        for (off, item) in items[lo..hi].iter().enumerate() {
            if off > 0 {
                buf.push(',');
            }
            let start = buf.len();
            write_one(&mut buf, item, lo + off);
            spans.push((start, buf.len()));
        }
        ManifestShard { first: lo, buf, spans }
    })
}

/// Exact byte length of [`frame_bulk`]'s output for these shards
/// (computed from the buffer lengths — this is what sizes the frame).
pub fn framed_len(shards: &[ManifestShard]) -> usize {
    let body: usize = shards.iter().map(|s| s.buf.len()).sum();
    body + shards.len().saturating_sub(1) + 2
}

/// Expected framed length derived from the **span tables** alone (item
/// bytes + one separator between items + brackets). Independent of the
/// buffer lengths that size [`frame_bulk`]'s output, so asserting the
/// shipped byte count against this catches span/buffer accounting bugs
/// that a `framed_len` comparison would tautologically miss.
pub fn expected_framed_len(shards: &[ManifestShard]) -> usize {
    let items: usize = shards.iter().map(|s| s.spans.len()).sum();
    let bytes: usize = shards.iter().map(ManifestShard::item_bytes).sum();
    if items == 0 {
        2
    } else {
        bytes + items + 1
    }
}

/// Frame the bulk submission payload `[item0,item1,...]` directly from
/// the shard buffers: the output buffer is sized exactly from the span
/// tables and each shard is written into its own disjoint window — one
/// bulk copy per shard, never per manifest (§Perf: this replaces the
/// per-manifest `push_str` re-copy in the managers' submit phase). Large
/// payloads copy their windows on scoped threads.
///
/// The framed bytes are identical for every thread count, including the
/// serial `threads == 1` path; the empty batch frames as `[]`.
pub fn frame_bulk(shards: &[ManifestShard], opts: SerializeOptions) -> Vec<u8> {
    let total = framed_len(shards);
    let mut out = vec![0u8; total];
    out[0] = b'[';
    out[total - 1] = b']';
    let body = &mut out[1..total - 1];
    let parallel = opts.effective_threads() > 1
        && shards.len() > 1
        && body.len() >= PAR_FRAME_MIN_BYTES;
    if parallel {
        std::thread::scope(|scope| {
            let mut rest = body;
            for (i, shard) in shards.iter().enumerate() {
                let window = shard.buf.len() + usize::from(i > 0);
                // `take` moves the full-lifetime slice out of `rest` so
                // the split halves live long enough to cross into the
                // scoped threads (a plain reborrow would end each loop
                // iteration).
                let (win, tail) = std::mem::take(&mut rest).split_at_mut(window);
                rest = tail;
                scope.spawn(move || {
                    let mut at = 0;
                    if i > 0 {
                        win[0] = b',';
                        at = 1;
                    }
                    write_str_into(&mut win[at..], &shard.buf);
                });
            }
        });
    } else {
        let mut at = 0;
        for (i, shard) in shards.iter().enumerate() {
            if i > 0 {
                body[at] = b',';
                at += 1;
            }
            at += write_str_into(&mut body[at..], &shard.buf);
        }
        debug_assert_eq!(at, body.len());
    }
    out
}

/// Terminal sink for a framed bulk payload: stands in for the provider
/// API ingest shared by all three managers. Opaque to the optimizer (the
/// submit phase must not be dead-code-eliminated) and returns the byte
/// count it accepted, which managers assert against the expected framed
/// length (ISSUE 3 satellite: `bulk_len` asserted, not just hinted).
pub fn submit_bulk(payload: &[u8]) -> usize {
    std::hint::black_box(payload).len()
}

/// Deterministic ack document the provider echoes for an accepted bulk
/// payload (ISSUE 10 ingest layer). A pure function of the payload
/// bytes — no PRNG, no clock — so arming it costs the healthy path
/// nothing (`ProviderFaultSpec::none()` byte/draw identity is
/// unaffected). The provider side lazily scans the payload it just
/// accepted with [`JsonScanner`] (item count via the top-level-array
/// span iterator, id spot-checks via dotted-path extraction — never a
/// tree) and echoes
///
/// ```json
/// {"ack":"hydra/v1","count":N,"bytes":B,"first_id":…,"last_id":…}
/// ```
///
/// where `first_id`/`last_id` are the raw id scalar of the first/last
/// item — `uid` for HPC task dicts, `metadata.labels."hydra/pod-id"`
/// for pod manifests, `payload.hydra_task_id` for FaaS invocations —
/// or `null` when the payload is empty or carries no known id field.
/// Malformed payloads ack the well-formed item prefix only, which the
/// manager-side count check then flags as a mismatch.
pub fn provider_ack(payload: &[u8]) -> String {
    let scanner = JsonScanner::new(payload);
    let mut count: u64 = 0;
    let mut first: Option<(usize, usize)> = None;
    let mut last: Option<(usize, usize)> = None;
    for item in scanner.items() {
        match item {
            Ok(span) => {
                if first.is_none() {
                    first = Some(span);
                }
                last = Some(span);
                count += 1;
            }
            Err(_) => break,
        }
    }
    let mut ack = String::with_capacity(96);
    ack.push_str("{\"ack\":\"hydra/v1\",\"count\":");
    push_u64(&mut ack, count);
    ack.push_str(",\"bytes\":");
    push_u64(&mut ack, payload.len() as u64);
    ack.push_str(",\"first_id\":");
    push_item_id(&mut ack, payload, first);
    ack.push_str(",\"last_id\":");
    push_item_id(&mut ack, payload, last);
    ack.push('}');
    ack
}

/// Echo the raw id scalar of the item at `span` (or `null`). The raw
/// bytes are copied verbatim — a string id stays quoted, a numeric id
/// stays bare — so the manager-side spot-check compares exactly what
/// was framed.
fn push_item_id(ack: &mut String, payload: &[u8], span: Option<(usize, usize)>) {
    let raw = span.and_then(|(s, e)| {
        let item = JsonScanner::new(&payload[s..e]);
        item.path_raw(&["uid"])
            .or_else(|| item.path_raw(&["metadata", "labels", "hydra/pod-id"]))
            .or_else(|| item.path_raw(&["payload", "hydra_task_id"]))
    });
    match raw.and_then(|r| std::str::from_utf8(r).ok()) {
        Some(r) => ack.push_str(r),
        None => ack.push_str("null"),
    }
}

/// Outcome of [`ProviderEndpoint::submit_acked`]: the accepted byte
/// count plus the provider's echoed ack document, which the managers
/// scan (count + id spot-checks) before trusting the submit.
#[derive(Debug, Clone)]
pub struct SubmitReceipt {
    /// Byte count the provider accepted — identical to what
    /// [`ProviderEndpoint::submit`] returns, so the framed-length
    /// accounting asserts are unchanged.
    pub bytes: usize,
    /// Raw ack JSON ([`provider_ack`] of the accepted payload).
    pub ack: String,
}

// ---------------------------------------------------------------------------
// Fallible provider control plane (ISSUE 7)
// ---------------------------------------------------------------------------

/// Salt for the dedicated provider-fault stream: decorrelated from the
/// schedule and pilot-fault streams for the same seed, stable across
/// runs (same pattern as `sim::hpc`'s `FAULT_STREAM_SALT`).
pub const PROVIDER_FAULT_STREAM_SALT: u64 = 0xFA11_BACC_0FF5;

/// Provider-API fault model (ISSUE 7). Every knob is off at zero; the
/// stochastic draws come from a dedicated PRNG stream
/// (`seed ^ PROVIDER_FAULT_STREAM_SALT`) so [`ProviderFaultSpec::none`]
/// consumes nothing and the healthy submit path stays byte-identical to
/// the infallible-sink reference (`tests/pilot_equivalence.rs`).
///
/// The model clocks in **simulated backoff seconds**: the endpoint's
/// clock starts at 0 and advances only while retries back off, so an
/// `outage_window` of `(0.0, 0.3)` is ridden out by a few retries while
/// `(0.0, 1e9)` is a hard outage that exhausts any retry budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProviderFaultSpec {
    /// Provider API down for `[t0, t1)` on the endpoint's backoff clock.
    pub outage_window: Option<(f64, f64)>,
    /// Probability each submit attempt fails transiently (5xx-style).
    pub transient_error_p: f64,
    /// Accepted-bytes quota between throttle rejections: a submit that
    /// would push the window past this many bytes is rejected once and
    /// the window resets (the quota refills while the retry backs off).
    /// `0` = no throttling.
    pub throttle_after_bytes: usize,
}

impl ProviderFaultSpec {
    /// All fault sources off — the healthy reference provider.
    pub fn none() -> ProviderFaultSpec {
        ProviderFaultSpec {
            outage_window: None,
            transient_error_p: 0.0,
            throttle_after_bytes: 0,
        }
    }

    pub fn is_none(&self) -> bool {
        self.outage_window.is_none()
            && self.transient_error_p == 0.0 // hydra-lint: allow(float-eq) — 0.0 sentinel
            && self.throttle_after_bytes == 0
    }

    pub fn validate(&self) -> Result<(), String> {
        if let Some((t0, t1)) = self.outage_window {
            if !t0.is_finite() || t0 < 0.0 || t1.is_nan() || t1 < t0 {
                return Err(format!("outage_window ({t0}, {t1}) must satisfy 0 <= t0 <= t1"));
            }
        }
        if !(0.0..=1.0).contains(&self.transient_error_p) {
            return Err(format!(
                "transient_error_p must be in [0, 1], got {}",
                self.transient_error_p
            ));
        }
        Ok(())
    }
}

impl Default for ProviderFaultSpec {
    fn default() -> ProviderFaultSpec {
        ProviderFaultSpec::none()
    }
}

/// Retry discipline for provider submits: exponential backoff with
/// seeded jitter, bounded by an attempt budget and a backoff deadline.
/// The default policy is a no-op on a healthy provider (no draws, no
/// simulated time) and retries transient faults ~5 times over ~1.5 s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Submit attempts per bulk before the error is terminal (>= 1).
    pub max_attempts: u32,
    /// Backoff before the first retry, in simulated seconds.
    pub base_backoff_s: f64,
    /// Backoff growth factor per retry (>= 1).
    pub multiplier: f64,
    /// Jitter fraction in [0, 1): each wait is scaled by a seeded factor
    /// in `[1 - jitter, 1 + jitter)` to decorrelate retry storms.
    pub jitter: f64,
    /// Total simulated backoff budget; exceeding it is terminal.
    pub deadline_s: f64,
}

impl RetryPolicy {
    pub fn validate(&self) -> Result<(), String> {
        if self.max_attempts == 0 {
            return Err("max_attempts must be >= 1".into());
        }
        if !self.base_backoff_s.is_finite() || self.base_backoff_s < 0.0 {
            return Err(format!("base_backoff_s must be finite and >= 0, got {}",
                               self.base_backoff_s));
        }
        if !self.multiplier.is_finite() || self.multiplier < 1.0 {
            return Err(format!("multiplier must be finite and >= 1, got {}", self.multiplier));
        }
        if !(0.0..1.0).contains(&self.jitter) {
            return Err(format!("jitter must be in [0, 1), got {}", self.jitter));
        }
        if !self.deadline_s.is_finite() || self.deadline_s <= 0.0 {
            return Err(format!("deadline_s must be finite and > 0, got {}", self.deadline_s));
        }
        Ok(())
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_backoff_s: 0.05,
            multiplier: 2.0,
            jitter: 0.1,
            deadline_s: 300.0,
        }
    }
}

/// Why a submit attempt (or the whole submit) failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitFailure {
    /// The provider API is inside its outage window.
    Outage,
    /// Transient (5xx-style) rejection.
    Transient,
    /// The accepted-bytes quota was exceeded.
    Throttle,
    /// The per-provider circuit breaker fast-failed the attempt.
    CircuitOpen,
    /// The retry policy's simulated backoff budget ran out.
    DeadlineExceeded,
}

impl std::fmt::Display for SubmitFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitFailure::Outage => write!(f, "provider API outage"),
            SubmitFailure::Transient => write!(f, "transient submit error"),
            SubmitFailure::Throttle => write!(f, "throttled (bytes quota exceeded)"),
            SubmitFailure::CircuitOpen => write!(f, "circuit breaker open"),
            SubmitFailure::DeadlineExceeded => write!(f, "retry deadline exceeded"),
        }
    }
}

/// Terminal outcome of a bulk submit after the retry policy gave up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmitError {
    pub reason: SubmitFailure,
    /// Attempts made, including the failing one.
    pub attempts: u32,
    /// Total simulated backoff charged before giving up.
    pub backoff_s: f64,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} after {} attempt(s), {:.3}s backoff",
            self.reason, self.attempts, self.backoff_s
        )
    }
}

impl std::error::Error for SubmitError {}

/// Fallible provider-API ingest: wraps [`submit_bulk`] with the seeded
/// fault model, the retry/backoff policy, and the provider's circuit
/// breaker (ISSUE 7 tentpole). One endpoint per manager execution; the
/// breaker is shared across executions through
/// [`ProviderHandle`](crate::broker::provider_proxy::ProviderHandle).
///
/// Healthy path guarantee: with [`ProviderFaultSpec::none`] no PRNG is
/// even constructed, every submit succeeds on the first attempt, and all
/// counters stay zero — byte- and draw-identical to calling
/// [`submit_bulk`] directly.
#[derive(Debug)]
pub struct ProviderEndpoint {
    fault: ProviderFaultSpec,
    retry: RetryPolicy,
    breaker: CircuitBreaker,
    /// Dedicated fault stream; `None` when the spec is all-off so the
    /// healthy path consumes nothing.
    prng: Option<Prng>,
    /// Simulated clock: advances only while retries back off.
    clock_s: f64,
    /// Accepted bytes since the last throttle rejection.
    window_bytes: usize,
    submit_retries: usize,
    backoff_s_total: f64,
    circuit_opens: usize,
}

impl ProviderEndpoint {
    pub fn new(
        fault: ProviderFaultSpec,
        retry: RetryPolicy,
        breaker: CircuitBreaker,
        seed: u64,
    ) -> ProviderEndpoint {
        let prng = if fault.is_none() {
            None
        } else {
            Some(Prng::new(seed ^ PROVIDER_FAULT_STREAM_SALT))
        };
        ProviderEndpoint {
            fault,
            retry,
            breaker,
            prng,
            clock_s: 0.0,
            window_bytes: 0,
            submit_retries: 0,
            backoff_s_total: 0.0,
            circuit_opens: 0,
        }
    }

    /// Submit one framed bulk payload, retrying per the policy. Returns
    /// the byte count the provider API accepted (the same count
    /// [`submit_bulk`] reports — byte-accounting asserts are unaffected).
    pub fn submit(&mut self, payload: &[u8]) -> Result<usize, SubmitError> {
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            if !self.breaker.allow() {
                return Err(self.terminal(SubmitFailure::CircuitOpen, attempt));
            }
            match self.attempt_failure(payload.len()) {
                None => {
                    self.breaker.record_success();
                    self.window_bytes += payload.len();
                    return Ok(submit_bulk(payload));
                }
                Some(reason) => {
                    if self.breaker.record_failure() {
                        self.circuit_opens += 1;
                    }
                    if attempt >= self.retry.max_attempts {
                        return Err(self.terminal(reason, attempt));
                    }
                    let wait = self.backoff_for(attempt);
                    if self.backoff_s_total + wait > self.retry.deadline_s {
                        return Err(self.terminal(SubmitFailure::DeadlineExceeded, attempt));
                    }
                    self.clock_s += wait;
                    self.backoff_s_total += wait;
                    self.submit_retries += 1;
                }
            }
        }
    }

    /// [`Self::submit`] plus the provider's echoed ack (ISSUE 10):
    /// the accepted payload is lazily re-scanned into a deterministic
    /// [`provider_ack`] document for the manager to verify. Ack
    /// construction consumes no PRNG draws and happens only after a
    /// successful submit, so retry/backoff behavior and the healthy
    /// path's byte/draw identity are untouched.
    pub fn submit_acked(&mut self, payload: &[u8]) -> Result<SubmitReceipt, SubmitError> {
        let bytes = self.submit(payload)?;
        Ok(SubmitReceipt { bytes, ack: provider_ack(payload) })
    }

    /// Fault checks for one attempt, in fixed order: outage, throttle,
    /// transient. `None` = the attempt succeeds.
    fn attempt_failure(&mut self, len: usize) -> Option<SubmitFailure> {
        if self.fault.is_none() {
            return None;
        }
        if let Some((t0, t1)) = self.fault.outage_window {
            if self.clock_s >= t0 && self.clock_s < t1 {
                return Some(SubmitFailure::Outage);
            }
        }
        if self.fault.throttle_after_bytes > 0
            && self.window_bytes + len > self.fault.throttle_after_bytes
        {
            self.window_bytes = 0; // quota refills while the retry backs off
            return Some(SubmitFailure::Throttle);
        }
        if self.fault.transient_error_p > 0.0 {
            let p = self.fault.transient_error_p;
            if self.prng.as_mut().expect("fault spec is armed").bool_with_p(p) {
                return Some(SubmitFailure::Transient);
            }
        }
        None
    }

    /// Exponential backoff with seeded jitter for the retry after
    /// `attempt` failures (1-based).
    fn backoff_for(&mut self, attempt: u32) -> f64 {
        let base = self.retry.base_backoff_s * self.retry.multiplier.powi(attempt as i32 - 1);
        if self.retry.jitter > 0.0 {
            let u = self.prng.as_mut().map(|p| p.uniform()).unwrap_or(0.5);
            base * (1.0 - self.retry.jitter + 2.0 * self.retry.jitter * u)
        } else {
            base
        }
    }

    fn terminal(&self, reason: SubmitFailure, attempts: u32) -> SubmitError {
        SubmitError { reason, attempts, backoff_s: self.backoff_s_total }
    }

    /// Retried attempts across all submits through this endpoint.
    pub fn submit_retries(&self) -> usize {
        self.submit_retries
    }

    /// Total simulated backoff in seconds — the managers charge this
    /// into the submit-phase OVH so resilience has a measurable cost.
    pub fn backoff_s(&self) -> f64 {
        self.backoff_s_total
    }

    pub fn backoff_ms(&self) -> u64 {
        (self.backoff_s_total * 1000.0).round() as u64
    }

    /// Closed→open transitions this endpoint drove on the breaker.
    pub fn circuit_opens(&self) -> usize {
        self.circuit_opens
    }

    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }
}

/// Data operation errors.
#[derive(Debug)]
pub enum DataError {
    UnknownSite(String),
    BadUri(String),
    NotFound(String),
    Escape(String),
    Io(String),
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataError::UnknownSite(s) => write!(f, "unknown site '{s}'"),
            DataError::BadUri(u) => write!(f, "bad data uri '{u}' (want site://path)"),
            DataError::NotFound(p) => write!(f, "no such object '{p}'"),
            DataError::Escape(p) => write!(f, "path '{p}' escapes the site sandbox"),
            DataError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for DataError {}

/// A storage backend: byte-addressed objects under relative paths.
pub trait StorageBackend: Send {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError>;
    fn get(&self, path: &str) -> Result<Vec<u8>, DataError>;
    fn delete(&mut self, path: &str) -> Result<(), DataError>;
    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError>;
    fn exists(&self, path: &str) -> bool;
    /// Simulated seconds to transfer `bytes` in or out of this backend
    /// (0 for local disk — its cost is the real I/O itself).
    fn transfer_secs(&self, bytes: u64) -> f64;
}

// ---------------------------------------------------------------------------
// LocalFs
// ---------------------------------------------------------------------------

/// Real filesystem backend rooted at a sandbox directory.
pub struct LocalFs {
    root: PathBuf,
}

impl LocalFs {
    pub fn new(root: impl Into<PathBuf>) -> Result<LocalFs, DataError> {
        let root = root.into();
        std::fs::create_dir_all(&root).map_err(|e| DataError::Io(e.to_string()))?;
        Ok(LocalFs { root })
    }

    fn resolve(&self, path: &str) -> Result<PathBuf, DataError> {
        let rel = Path::new(path);
        if rel.is_absolute()
            || rel
                .components()
                .any(|c| matches!(c, std::path::Component::ParentDir))
        {
            return Err(DataError::Escape(path.to_string()));
        }
        Ok(self.root.join(rel))
    }
}

impl StorageBackend for LocalFs {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError> {
        let p = self.resolve(path)?;
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent).map_err(|e| DataError::Io(e.to_string()))?;
        }
        std::fs::write(&p, data).map_err(|e| DataError::Io(e.to_string()))
    }

    fn get(&self, path: &str) -> Result<Vec<u8>, DataError> {
        let p = self.resolve(path)?;
        std::fs::read(&p).map_err(|_| DataError::NotFound(path.to_string()))
    }

    fn delete(&mut self, path: &str) -> Result<(), DataError> {
        let p = self.resolve(path)?;
        std::fs::remove_file(&p).map_err(|_| DataError::NotFound(path.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError> {
        // Walk the sandbox and filter by prefix (flat namespace view).
        fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    let p = e.path();
                    if p.is_dir() {
                        walk(&p, root, out);
                    } else if let Ok(rel) = p.strip_prefix(root) {
                        out.push(rel.to_string_lossy().replace('\\', "/"));
                    }
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.root, &self.root, &mut out);
        out.retain(|p| p.starts_with(prefix));
        out.sort();
        Ok(out)
    }

    fn exists(&self, path: &str) -> bool {
        self.resolve(path).map(|p| p.exists()).unwrap_or(false)
    }

    fn transfer_secs(&self, _bytes: u64) -> f64 {
        0.0
    }
}

// ---------------------------------------------------------------------------
// SimObjectStore
// ---------------------------------------------------------------------------

/// Simulated remote object store with a bandwidth/latency model.
pub struct SimObjectStore {
    objects: HashMap<String, Vec<u8>>,
    /// Sustained bandwidth in bytes/second.
    pub bandwidth_bps: f64,
    /// Per-request latency in seconds.
    pub latency_s: f64,
}

impl SimObjectStore {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> SimObjectStore {
        SimObjectStore { objects: HashMap::new(), bandwidth_bps, latency_s }
    }
}

impl StorageBackend for SimObjectStore {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError> {
        self.objects.insert(path.to_string(), data.to_vec());
        Ok(())
    }

    fn get(&self, path: &str) -> Result<Vec<u8>, DataError> {
        self.objects
            .get(path)
            .cloned()
            .ok_or_else(|| DataError::NotFound(path.to_string()))
    }

    fn delete(&mut self, path: &str) -> Result<(), DataError> {
        self.objects
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| DataError::NotFound(path.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError> {
        let mut v: Vec<String> = self
            .objects
            .keys() // hydra-lint: allow(hash-order) — collected then sorted two lines down
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        Ok(v)
    }

    fn exists(&self, path: &str) -> bool {
        self.objects.contains_key(path)
    }

    fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

// ---------------------------------------------------------------------------
// DataManager
// ---------------------------------------------------------------------------

/// Result of a transfer: bytes moved and the simulated seconds it took
/// (source egress + destination ingress).
#[derive(Debug, Clone, PartialEq)]
pub struct TransferReport {
    pub bytes: u64,
    pub virtual_secs: f64,
}

/// Unified multi-site data API, keyed by site name.
#[derive(Default)]
pub struct DataManager {
    sites: HashMap<String, Box<dyn StorageBackend>>,
}

impl DataManager {
    pub fn new() -> DataManager {
        DataManager { sites: HashMap::new() }
    }

    pub fn register(&mut self, site: impl Into<String>, backend: Box<dyn StorageBackend>) {
        self.sites.insert(site.into(), backend);
    }

    pub fn sites(&self) -> Vec<String> {
        // hydra-lint: allow(hash-order) — collected then sorted before anyone observes order
        let mut v: Vec<String> = self.sites.keys().cloned().collect();
        v.sort();
        v
    }

    fn split(uri: &str) -> Result<(&str, &str), DataError> {
        uri.split_once("://").ok_or_else(|| DataError::BadUri(uri.to_string()))
    }

    fn site(&self, name: &str) -> Result<&dyn StorageBackend, DataError> {
        self.sites
            .get(name)
            .map(|b| b.as_ref())
            .ok_or_else(|| DataError::UnknownSite(name.to_string()))
    }

    fn site_mut(&mut self, name: &str) -> Result<&mut Box<dyn StorageBackend>, DataError> {
        self.sites
            .get_mut(name)
            .ok_or_else(|| DataError::UnknownSite(name.to_string()))
    }

    pub fn put(&mut self, uri: &str, data: &[u8]) -> Result<TransferReport, DataError> {
        let (site, path) = Self::split(uri)?;
        let b = self.site_mut(site)?;
        b.put(path, data)?;
        Ok(TransferReport {
            bytes: data.len() as u64,
            virtual_secs: b.transfer_secs(data.len() as u64),
        })
    }

    pub fn get(&self, uri: &str) -> Result<Vec<u8>, DataError> {
        let (site, path) = Self::split(uri)?;
        self.site(site)?.get(path)
    }

    pub fn exists(&self, uri: &str) -> Result<bool, DataError> {
        let (site, path) = Self::split(uri)?;
        Ok(self.site(site)?.exists(path))
    }

    pub fn list(&self, uri_prefix: &str) -> Result<Vec<String>, DataError> {
        let (site, prefix) = Self::split(uri_prefix)?;
        self.site(site)?.list(prefix)
    }

    pub fn delete(&mut self, uri: &str) -> Result<(), DataError> {
        let (site, path) = Self::split(uri)?;
        self.site_mut(site)?.delete(path)
    }

    /// Copy across (or within) sites; returns the transfer cost.
    pub fn copy(&mut self, src: &str, dst: &str) -> Result<TransferReport, DataError> {
        let data = self.get(src)?;
        let (ssite, _) = Self::split(src)?;
        let egress = self.site(ssite)?.transfer_secs(data.len() as u64);
        let mut r = self.put(dst, &data)?;
        r.virtual_secs += egress;
        Ok(r)
    }

    /// Move = copy + delete source.
    pub fn mv(&mut self, src: &str, dst: &str) -> Result<TransferReport, DataError> {
        let r = self.copy(src, dst)?;
        self.delete(src)?;
        Ok(r)
    }

    /// Link: cheap alias within one site (object stores: server-side copy;
    /// local fs: content copy, as portable fallback).
    pub fn link(&mut self, src: &str, dst: &str) -> Result<(), DataError> {
        let (ssite, _) = Self::split(src)?;
        let (dsite, _) = Self::split(dst)?;
        if ssite != dsite {
            return Err(DataError::BadUri(format!(
                "link requires same site: {ssite} vs {dsite}"
            )));
        }
        let data = self.get(src)?;
        self.put(dst, &data)?;
        Ok(())
    }

    /// Stage one object onto many sites (FACTS pre-staging, §5.4): returns
    /// per-site transfer reports.
    pub fn stage_to_sites(
        &mut self,
        src: &str,
        targets: &[&str],
        dst_path: &str,
    ) -> Result<Vec<(String, TransferReport)>, DataError> {
        let mut out = Vec::new();
        for site in targets {
            let dst = format!("{site}://{dst_path}");
            let r = self.copy(src, &dst)?;
            out.push((site.to_string(), r));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hydra-data-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn manager(tag: &str) -> (DataManager, PathBuf) {
        let dir = tmpdir(tag);
        let mut m = DataManager::new();
        m.register("local", Box::new(LocalFs::new(dir.clone()).unwrap()));
        m.register("jet2", Box::new(SimObjectStore::new(100e6, 0.05)));
        m.register("aws", Box::new(SimObjectStore::new(50e6, 0.08)));
        (m, dir)
    }

    #[test]
    fn put_get_roundtrip_on_both_backends() {
        let (mut m, dir) = manager("rt");
        for uri in ["local://a/b.bin", "jet2://a/b.bin"] {
            m.put(uri, b"hello").unwrap();
            assert_eq!(m.get(uri).unwrap(), b"hello");
            assert!(m.exists(uri).unwrap());
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_filters_by_prefix() {
        let (mut m, dir) = manager("ls");
        m.put("jet2://facts/input/t.nc", b"1").unwrap();
        m.put("jet2://facts/input/s.nc", b"2").unwrap();
        m.put("jet2://other/x", b"3").unwrap();
        let l = m.list("jet2://facts/").unwrap();
        assert_eq!(l, vec!["facts/input/s.nc".to_string(), "facts/input/t.nc".to_string()]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn copy_across_sites_accumulates_transfer_cost() {
        let (mut m, dir) = manager("cp");
        let payload = vec![0u8; 1_000_000];
        m.put("jet2://d.bin", &payload).unwrap();
        let r = m.copy("jet2://d.bin", "aws://d.bin").unwrap();
        assert_eq!(r.bytes, 1_000_000);
        // egress at 100 MB/s + ingress at 50 MB/s + latencies
        let want = 0.05 + 1e6 / 100e6 + 0.08 + 1e6 / 50e6;
        assert!((r.virtual_secs - want).abs() < 1e-9, "{}", r.virtual_secs);
        assert!(m.exists("aws://d.bin").unwrap());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mv_removes_source() {
        let (mut m, dir) = manager("mv");
        m.put("jet2://x", b"d").unwrap();
        m.mv("jet2://x", "aws://x").unwrap();
        assert!(!m.exists("jet2://x").unwrap());
        assert!(m.exists("aws://x").unwrap());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn link_same_site_only() {
        let (mut m, dir) = manager("ln");
        m.put("jet2://orig", b"d").unwrap();
        m.link("jet2://orig", "jet2://alias").unwrap();
        assert_eq!(m.get("jet2://alias").unwrap(), b"d");
        assert!(m.link("jet2://orig", "aws://alias").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sandbox_escape_rejected() {
        let (mut m, dir) = manager("esc");
        assert!(matches!(m.put("local://../evil", b"x"), Err(DataError::Escape(_))));
        assert!(matches!(m.put("local:///abs", b"x"), Err(DataError::Escape(_))));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn errors_for_unknown_site_and_bad_uri() {
        let (m, dir) = manager("err");
        assert!(matches!(m.get("nope://x"), Err(DataError::UnknownSite(_))));
        assert!(matches!(m.get("no-scheme"), Err(DataError::BadUri(_))));
        assert!(matches!(m.get("jet2://missing"), Err(DataError::NotFound(_))));
        std::fs::remove_dir_all(dir).ok();
    }

    // -- bulk serialization data path ------------------------------------

    /// Toy writer: each item serializes as `<n>` so the expected bulk is
    /// trivial to compute by hand.
    fn num_shards(items: &[u64], opts: SerializeOptions) -> Vec<ManifestShard> {
        serialize_sharded(items, opts, 8, |out, item, idx| {
            assert_eq!(items[idx], *item, "index passed to write_one drifted");
            crate::util::json::push_u64(out, *item);
        })
    }

    #[test]
    fn shard_ranges_tile_exactly_and_balance() {
        for items in [0usize, 1, 2, 63, 64, 65, 1000, 4096] {
            for shards in [0usize, 1, 2, 3, 8, 64] {
                let r = shard_ranges(items, shards);
                if items == 0 || shards == 0 {
                    assert!(r.is_empty());
                    continue;
                }
                assert_eq!(r.len(), shards.min(items));
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, items);
                let mut cursor = 0;
                let mut sizes = Vec::new();
                for &(lo, hi) in &r {
                    assert_eq!(lo, cursor, "gap/overlap at {lo}");
                    assert!(hi > lo, "empty shard");
                    sizes.push(hi - lo);
                    cursor = hi;
                }
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn shards_for_respects_floor_and_knob() {
        let eight = SerializeOptions::with_threads(8);
        assert_eq!(eight.shards_for(0), 0);
        assert_eq!(eight.shards_for(1), 1);
        // Floor semantics: every shard must carry >= MIN_ITEMS_PER_SHARD
        // items, so batches under 2 floors stay serial.
        assert_eq!(eight.shards_for(64), 1);
        assert_eq!(eight.shards_for(127), 1);
        assert_eq!(eight.shards_for(128), 2);
        assert_eq!(eight.shards_for(4096), 8);
        assert_eq!(SerializeOptions::serial().shards_for(4096), 1);
        assert!(SerializeOptions::default().effective_threads() >= 1);
    }

    #[test]
    fn sharded_serialization_is_byte_identical_to_serial() {
        let items: Vec<u64> = (0..1000).map(|i| i * 7 + 3).collect();
        let serial_opts = SerializeOptions::serial();
        let serial = frame_bulk(&num_shards(&items, serial_opts), serial_opts);
        let mut expected = String::from("[");
        for (i, v) in items.iter().enumerate() {
            if i > 0 {
                expected.push(',');
            }
            expected.push_str(&v.to_string());
        }
        expected.push(']');
        assert_eq!(serial, expected.as_bytes());
        for threads in [2, 3, 8, 100] {
            let opts = SerializeOptions::with_threads(threads);
            let shards = num_shards(&items, opts);
            assert!(shards.len() <= threads);
            assert_eq!(frame_bulk(&shards, opts), serial, "threads={threads}");
        }
    }

    #[test]
    fn shard_spans_address_items_with_separators_between() {
        let items: Vec<u64> = (0..200).collect();
        let shards = num_shards(&items, SerializeOptions::with_threads(3));
        assert_eq!(shards.len(), 3);
        let mut seen = 0usize;
        for shard in &shards {
            assert_eq!(shard.first, seen);
            let mut cursor = 0usize;
            for (i, &(s, e)) in shard.spans.iter().enumerate() {
                // separators occupy exactly one byte between spans
                assert_eq!(s, if i == 0 { 0 } else { cursor + 1 });
                assert_eq!(&shard.buf[s..e], items[shard.first + i].to_string());
                cursor = e;
            }
            assert_eq!(cursor, shard.buf.len());
            assert_eq!(shard.item_bytes(), shard.buf.len() - (shard.spans.len() - 1));
            seen += shard.spans.len();
        }
        assert_eq!(seen, items.len());
    }

    #[test]
    fn empty_batch_frames_as_bracket_pair() {
        let shards = num_shards(&[], SerializeOptions::default());
        assert!(shards.is_empty());
        assert_eq!(frame_bulk(&shards, SerializeOptions::default()), b"[]");
        assert_eq!(framed_len(&shards), 2);
    }

    #[test]
    fn parallel_frame_path_matches_serial_frame() {
        // Force the scoped-thread framing branch with >1 MiB of body.
        let items: Vec<u64> = (0..3).collect();
        let opts = SerializeOptions::with_threads(3);
        let mut shards = num_shards(&items, SerializeOptions::with_threads(usize::MAX));
        assert_eq!(shards.len(), 1, "3 items stay on one shard");
        shards = vec![
            ManifestShard { first: 0, buf: "a".repeat(700_000), spans: vec![(0, 700_000)] },
            ManifestShard {
                first: 1,
                buf: "b".repeat(700_000),
                spans: vec![(0, 700_000)],
            },
        ];
        let par = frame_bulk(&shards, opts);
        let ser = frame_bulk(&shards, SerializeOptions::serial());
        assert_eq!(par, ser);
        assert_eq!(par.len(), framed_len(&shards));
        assert_eq!(par[0], b'[');
        assert_eq!(par[700_001], b',');
        assert_eq!(*par.last().unwrap(), b']');
    }

    #[test]
    fn submit_bulk_reports_accepted_bytes() {
        assert_eq!(submit_bulk(b"[]"), 2);
        assert_eq!(submit_bulk(&[]), 0);
    }

    #[test]
    fn provider_ack_echoes_count_bytes_and_ids() {
        let payload = br#"[{"uid":"task.000001","cpu":1},{"uid":"task.000007","cpu":2}]"#;
        let ack = provider_ack(payload);
        let s = JsonScanner::new(ack.as_bytes());
        assert!(s.validate().is_ok(), "ack must itself be valid JSON: {ack}");
        assert_eq!(s.path_str(&["ack"]), Some("hydra/v1"));
        assert_eq!(s.path_u64(&["count"]), Some(2));
        assert_eq!(s.path_u64(&["bytes"]), Some(payload.len() as u64));
        assert_eq!(s.path_str(&["first_id"]), Some("task.000001"));
        assert_eq!(s.path_str(&["last_id"]), Some("task.000007"));
    }

    #[test]
    fn provider_ack_handles_numeric_and_nested_ids() {
        // FaaS invocation items carry payload.hydra_task_id; pod
        // manifests carry metadata.labels."hydra/pod-id".
        let faas = br#"[{"function":"f","payload":{"hydra_task_id":9}}]"#;
        let s_ack = provider_ack(faas);
        let s = JsonScanner::new(s_ack.as_bytes());
        assert_eq!(s.path_u64(&["first_id"]), Some(9));
        assert_eq!(s.path_u64(&["last_id"]), Some(9));
        let pod = br#"[{"metadata":{"name":"hydra-pod-00000003","labels":{"app":"hydra","hydra/pod-id":3}}}]"#;
        let p_ack = provider_ack(pod);
        let p = JsonScanner::new(p_ack.as_bytes());
        assert_eq!(p.path_u64(&["first_id"]), Some(3));
    }

    #[test]
    fn provider_ack_empty_and_unknown_payloads() {
        let ack = provider_ack(b"[]");
        let s = JsonScanner::new(ack.as_bytes());
        assert_eq!(s.path_u64(&["count"]), Some(0));
        assert_eq!(s.path_u64(&["bytes"]), Some(2));
        assert_eq!(s.path_raw(&["first_id"]), Some(&b"null"[..]));
        // Items without a known id field ack with null ids but still count.
        let ack = provider_ack(b"[1,2,3]");
        let s = JsonScanner::new(ack.as_bytes());
        assert_eq!(s.path_u64(&["count"]), Some(3));
        assert_eq!(s.path_raw(&["last_id"]), Some(&b"null"[..]));
    }

    #[test]
    fn provider_ack_is_deterministic_and_draw_free() {
        // Same bytes in, same ack out — and an endpoint with faults off
        // produces it without constructing a PRNG (submit_acked goes
        // through the same healthy path as submit).
        let payload = br#"[{"uid":"task.000002"}]"#;
        assert_eq!(provider_ack(payload), provider_ack(payload));
        let mut ep = ProviderEndpoint::new(
            ProviderFaultSpec::none(),
            RetryPolicy::default(),
            CircuitBreaker::default(),
            1234,
        );
        let receipt = ep.submit_acked(payload).unwrap();
        assert_eq!(receipt.bytes, payload.len());
        assert_eq!(receipt.ack, provider_ack(payload));
        assert_eq!(ep.submit_retries(), 0);
        assert_eq!(ep.backoff_s(), 0.0); // hydra-lint: allow(float-eq) — exact zero sentinel
    }

    #[test]
    fn expected_framed_len_cross_checks_span_accounting() {
        // On well-formed shards the span-derived expectation matches the
        // buffer-derived frame size...
        for items in [0usize, 1, 200, 1000] {
            let v: Vec<u64> = (0..items as u64).collect();
            let shards = num_shards(&v, SerializeOptions::with_threads(3));
            assert_eq!(expected_framed_len(&shards), framed_len(&shards), "items={items}");
            assert_eq!(
                frame_bulk(&shards, SerializeOptions::serial()).len(),
                expected_framed_len(&shards)
            );
        }
        // ...and, unlike framed_len, it disagrees when a span table drops
        // bytes that are still in the buffer — the bug class the managers'
        // submit-phase assert exists to catch.
        let mut shards = num_shards(&[10u64, 20, 30], SerializeOptions::serial());
        shards[0].spans.pop();
        assert_ne!(expected_framed_len(&shards), framed_len(&shards));
    }

    // -- fallible provider endpoint (ISSUE 7) ----------------------------

    use crate::broker::provider_proxy::CircuitState;

    fn endpoint(fault: ProviderFaultSpec, retry: RetryPolicy) -> ProviderEndpoint {
        ProviderEndpoint::new(fault, retry, CircuitBreaker::default(), 11)
    }

    #[test]
    fn healthy_endpoint_is_a_transparent_sink() {
        let mut ep = endpoint(ProviderFaultSpec::none(), RetryPolicy::default());
        for _ in 0..10 {
            assert_eq!(ep.submit(b"[1,2,3]").unwrap(), 7);
        }
        assert_eq!(ep.submit_retries(), 0);
        assert_eq!(ep.backoff_s(), 0.0);
        assert_eq!(ep.circuit_opens(), 0);
        assert_eq!(ep.breaker().state(), CircuitState::Closed);
    }

    #[test]
    fn transient_errors_retry_with_growing_backoff() {
        // p = 1 fails every attempt: 4 retries then a terminal error.
        let fault = ProviderFaultSpec { transient_error_p: 1.0, ..ProviderFaultSpec::none() };
        let retry = RetryPolicy { jitter: 0.0, ..RetryPolicy::default() };
        let mut ep = endpoint(fault, retry);
        let e = ep.submit(b"[]").unwrap_err();
        assert_eq!(e.reason, SubmitFailure::Transient);
        assert_eq!(e.attempts, 5);
        // 0.05 + 0.1 + 0.2 + 0.4 (exponential, no jitter)
        assert!((e.backoff_s - 0.75).abs() < 1e-12, "{}", e.backoff_s);
        assert_eq!(ep.submit_retries(), 4);
        assert_eq!(ep.backoff_ms(), 750);

        // A moderate rate rides through on retries: across many submits
        // some retries happen but every submit eventually succeeds. A
        // generous attempt budget + breaker threshold keep this
        // independent of the exact draw sequence.
        let fault = ProviderFaultSpec { transient_error_p: 0.4, ..ProviderFaultSpec::none() };
        let retry = RetryPolicy { max_attempts: 64, ..RetryPolicy::default() };
        let mut ep =
            ProviderEndpoint::new(fault, retry, CircuitBreaker::with_threshold(1000), 11);
        for _ in 0..50 {
            assert_eq!(ep.submit(b"[]").unwrap(), 2);
        }
        assert!(ep.submit_retries() > 0);
        assert!(ep.backoff_s() > 0.0);
    }

    #[test]
    fn outage_window_rides_out_or_exhausts_attempts() {
        // A short outage is ridden out: backoff advances the clock past t1.
        let fault = ProviderFaultSpec {
            outage_window: Some((0.0, 0.12)),
            ..ProviderFaultSpec::none()
        };
        let retry = RetryPolicy { jitter: 0.0, ..RetryPolicy::default() };
        let mut ep = endpoint(fault, retry);
        assert_eq!(ep.submit(b"[]").unwrap(), 2);
        assert!(ep.submit_retries() >= 2, "clock must back off past the window");

        // A hard outage exhausts the attempt budget.
        let fault = ProviderFaultSpec {
            outage_window: Some((0.0, 1e9)),
            ..ProviderFaultSpec::none()
        };
        let mut ep = endpoint(fault, retry);
        let e = ep.submit(b"[]").unwrap_err();
        assert_eq!(e.reason, SubmitFailure::Outage);
        assert_eq!(e.attempts, 5);
    }

    #[test]
    fn throttle_rejects_once_then_quota_refills() {
        let fault = ProviderFaultSpec { throttle_after_bytes: 10, ..ProviderFaultSpec::none() };
        let mut ep = endpoint(fault, RetryPolicy::default());
        assert_eq!(ep.submit(b"12345678").unwrap(), 8); // window = 8
        // 8 + 8 > 10: rejected once, window resets, retry succeeds.
        assert_eq!(ep.submit(b"12345678").unwrap(), 8);
        assert_eq!(ep.submit_retries(), 1);
        // A payload larger than the whole quota can never land.
        let e = ep.submit(&[b'x'; 64]).unwrap_err();
        assert_eq!(e.reason, SubmitFailure::Throttle);
    }

    #[test]
    fn circuit_opens_after_consecutive_failures_and_fast_fails() {
        let fault = ProviderFaultSpec { transient_error_p: 1.0, ..ProviderFaultSpec::none() };
        let retry = RetryPolicy { max_attempts: 10, jitter: 0.0, ..RetryPolicy::default() };
        let mut ep = endpoint(fault, retry);
        // 5 consecutive failures trip the breaker; the next allow() is a
        // fast-fail denial, terminal as CircuitOpen.
        let e = ep.submit(b"[]").unwrap_err();
        assert_eq!(e.reason, SubmitFailure::CircuitOpen);
        assert_eq!(ep.circuit_opens(), 1);
        assert_eq!(ep.breaker().state(), CircuitState::Open);
        // The denial moved the breaker toward half-open: the next submit
        // runs a probe attempt, which fails (p = 1) and re-opens the
        // circuit — visible as a second open transition.
        let e = ep.submit(b"[]").unwrap_err();
        assert_eq!(ep.circuit_opens(), 2, "half-open probe must run and re-open");
        assert_eq!(e.reason, SubmitFailure::CircuitOpen);
        assert_eq!(e.attempts, 2, "one probe attempt, then fast-fail");
    }

    #[test]
    fn deadline_bounds_total_backoff() {
        let fault = ProviderFaultSpec { transient_error_p: 1.0, ..ProviderFaultSpec::none() };
        let retry = RetryPolicy {
            max_attempts: 100,
            jitter: 0.0,
            deadline_s: 0.2,
            ..RetryPolicy::default()
        };
        let mut ep = endpoint(fault, retry);
        let e = ep.submit(b"[]").unwrap_err();
        assert_eq!(e.reason, SubmitFailure::DeadlineExceeded);
        assert!(ep.backoff_s() <= 0.2);
    }

    #[test]
    fn endpoint_is_deterministic_per_seed() {
        let fault = ProviderFaultSpec { transient_error_p: 0.5, ..ProviderFaultSpec::none() };
        let run = |seed: u64| {
            let mut ep =
                ProviderEndpoint::new(fault, RetryPolicy::default(), CircuitBreaker::default(),
                                      seed);
            for _ in 0..30 {
                let _ = ep.submit(b"[0]");
            }
            (ep.submit_retries(), ep.backoff_s().to_bits())
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8), "different seeds must draw different streams");
    }

    #[test]
    fn specs_validate() {
        assert!(ProviderFaultSpec::none().validate().is_ok());
        assert!(ProviderFaultSpec::none().is_none());
        let bad = ProviderFaultSpec { transient_error_p: 1.5, ..ProviderFaultSpec::none() };
        assert!(bad.validate().is_err());
        assert!(!bad.is_none());
        let bad = ProviderFaultSpec {
            outage_window: Some((5.0, 1.0)),
            ..ProviderFaultSpec::none()
        };
        assert!(bad.validate().is_err());
        let bad = ProviderFaultSpec {
            outage_window: Some((-1.0, 1.0)),
            ..ProviderFaultSpec::none()
        };
        assert!(bad.validate().is_err());

        assert!(RetryPolicy::default().validate().is_ok());
        assert!(RetryPolicy { max_attempts: 0, ..RetryPolicy::default() }.validate().is_err());
        assert!(RetryPolicy { multiplier: 0.5, ..RetryPolicy::default() }.validate().is_err());
        assert!(RetryPolicy { jitter: 1.0, ..RetryPolicy::default() }.validate().is_err());
        assert!(RetryPolicy { deadline_s: 0.0, ..RetryPolicy::default() }.validate().is_err());
        assert!(RetryPolicy { base_backoff_s: -0.1, ..RetryPolicy::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn staging_to_multiple_sites() {
        let (mut m, dir) = manager("stage");
        m.put("local://facts-input.nc", &vec![1u8; 10_000]).unwrap();
        let reports = m
            .stage_to_sites("local://facts-input.nc", &["jet2", "aws"], "facts/in.nc")
            .unwrap();
        assert_eq!(reports.len(), 2);
        assert!(m.exists("jet2://facts/in.nc").unwrap());
        assert!(m.exists("aws://facts/in.nc").unwrap());
        assert!(reports[1].1.virtual_secs > reports[0].1.virtual_secs); // aws slower
        std::fs::remove_dir_all(dir).ok();
    }
}
