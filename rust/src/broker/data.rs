//! Data Manager: inter- and cross-cloud/HPC data operations.
//!
//! Paper §3.1: "The manager implements data operations like copy, move,
//! link, delete, and list, both locally and remotely ... supports
//! integration with different data management services as backends and
//! exposes their operations via a unified API."
//!
//! Two backends:
//! * [`LocalFs`] — a *real* filesystem backend rooted in a sandbox
//!   directory (all paths are confined; `..` escapes are rejected).
//! * [`SimObjectStore`] — a simulated remote object store with a bandwidth
//!   model, standing in for the cloud/HPC storage services (17.2 PB on
//!   Jetstream2 etc.) we do not have.
//!
//! The unified entry point is [`DataManager`], which routes `site://path`
//! URIs to registered backends and can build staging plans across sites
//! (e.g. FACTS pre-staging input data on each target platform, §5.4).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Data operation errors.
#[derive(Debug)]
pub enum DataError {
    UnknownSite(String),
    BadUri(String),
    NotFound(String),
    Escape(String),
    Io(String),
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataError::UnknownSite(s) => write!(f, "unknown site '{s}'"),
            DataError::BadUri(u) => write!(f, "bad data uri '{u}' (want site://path)"),
            DataError::NotFound(p) => write!(f, "no such object '{p}'"),
            DataError::Escape(p) => write!(f, "path '{p}' escapes the site sandbox"),
            DataError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for DataError {}

/// A storage backend: byte-addressed objects under relative paths.
pub trait StorageBackend: Send {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError>;
    fn get(&self, path: &str) -> Result<Vec<u8>, DataError>;
    fn delete(&mut self, path: &str) -> Result<(), DataError>;
    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError>;
    fn exists(&self, path: &str) -> bool;
    /// Simulated seconds to transfer `bytes` in or out of this backend
    /// (0 for local disk — its cost is the real I/O itself).
    fn transfer_secs(&self, bytes: u64) -> f64;
}

// ---------------------------------------------------------------------------
// LocalFs
// ---------------------------------------------------------------------------

/// Real filesystem backend rooted at a sandbox directory.
pub struct LocalFs {
    root: PathBuf,
}

impl LocalFs {
    pub fn new(root: impl Into<PathBuf>) -> Result<LocalFs, DataError> {
        let root = root.into();
        std::fs::create_dir_all(&root).map_err(|e| DataError::Io(e.to_string()))?;
        Ok(LocalFs { root })
    }

    fn resolve(&self, path: &str) -> Result<PathBuf, DataError> {
        let rel = Path::new(path);
        if rel.is_absolute()
            || rel
                .components()
                .any(|c| matches!(c, std::path::Component::ParentDir))
        {
            return Err(DataError::Escape(path.to_string()));
        }
        Ok(self.root.join(rel))
    }
}

impl StorageBackend for LocalFs {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError> {
        let p = self.resolve(path)?;
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent).map_err(|e| DataError::Io(e.to_string()))?;
        }
        std::fs::write(&p, data).map_err(|e| DataError::Io(e.to_string()))
    }

    fn get(&self, path: &str) -> Result<Vec<u8>, DataError> {
        let p = self.resolve(path)?;
        std::fs::read(&p).map_err(|_| DataError::NotFound(path.to_string()))
    }

    fn delete(&mut self, path: &str) -> Result<(), DataError> {
        let p = self.resolve(path)?;
        std::fs::remove_file(&p).map_err(|_| DataError::NotFound(path.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError> {
        // Walk the sandbox and filter by prefix (flat namespace view).
        fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    let p = e.path();
                    if p.is_dir() {
                        walk(&p, root, out);
                    } else if let Ok(rel) = p.strip_prefix(root) {
                        out.push(rel.to_string_lossy().replace('\\', "/"));
                    }
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.root, &self.root, &mut out);
        out.retain(|p| p.starts_with(prefix));
        out.sort();
        Ok(out)
    }

    fn exists(&self, path: &str) -> bool {
        self.resolve(path).map(|p| p.exists()).unwrap_or(false)
    }

    fn transfer_secs(&self, _bytes: u64) -> f64 {
        0.0
    }
}

// ---------------------------------------------------------------------------
// SimObjectStore
// ---------------------------------------------------------------------------

/// Simulated remote object store with a bandwidth/latency model.
pub struct SimObjectStore {
    objects: HashMap<String, Vec<u8>>,
    /// Sustained bandwidth in bytes/second.
    pub bandwidth_bps: f64,
    /// Per-request latency in seconds.
    pub latency_s: f64,
}

impl SimObjectStore {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> SimObjectStore {
        SimObjectStore { objects: HashMap::new(), bandwidth_bps, latency_s }
    }
}

impl StorageBackend for SimObjectStore {
    fn put(&mut self, path: &str, data: &[u8]) -> Result<(), DataError> {
        self.objects.insert(path.to_string(), data.to_vec());
        Ok(())
    }

    fn get(&self, path: &str) -> Result<Vec<u8>, DataError> {
        self.objects
            .get(path)
            .cloned()
            .ok_or_else(|| DataError::NotFound(path.to_string()))
    }

    fn delete(&mut self, path: &str) -> Result<(), DataError> {
        self.objects
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| DataError::NotFound(path.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, DataError> {
        let mut v: Vec<String> = self
            .objects
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        Ok(v)
    }

    fn exists(&self, path: &str) -> bool {
        self.objects.contains_key(path)
    }

    fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

// ---------------------------------------------------------------------------
// DataManager
// ---------------------------------------------------------------------------

/// Result of a transfer: bytes moved and the simulated seconds it took
/// (source egress + destination ingress).
#[derive(Debug, Clone, PartialEq)]
pub struct TransferReport {
    pub bytes: u64,
    pub virtual_secs: f64,
}

/// Unified multi-site data API, keyed by site name.
#[derive(Default)]
pub struct DataManager {
    sites: HashMap<String, Box<dyn StorageBackend>>,
}

impl DataManager {
    pub fn new() -> DataManager {
        DataManager { sites: HashMap::new() }
    }

    pub fn register(&mut self, site: impl Into<String>, backend: Box<dyn StorageBackend>) {
        self.sites.insert(site.into(), backend);
    }

    pub fn sites(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sites.keys().cloned().collect();
        v.sort();
        v
    }

    fn split(uri: &str) -> Result<(&str, &str), DataError> {
        uri.split_once("://").ok_or_else(|| DataError::BadUri(uri.to_string()))
    }

    fn site(&self, name: &str) -> Result<&dyn StorageBackend, DataError> {
        self.sites
            .get(name)
            .map(|b| b.as_ref())
            .ok_or_else(|| DataError::UnknownSite(name.to_string()))
    }

    fn site_mut(&mut self, name: &str) -> Result<&mut Box<dyn StorageBackend>, DataError> {
        self.sites
            .get_mut(name)
            .ok_or_else(|| DataError::UnknownSite(name.to_string()))
    }

    pub fn put(&mut self, uri: &str, data: &[u8]) -> Result<TransferReport, DataError> {
        let (site, path) = Self::split(uri)?;
        let b = self.site_mut(site)?;
        b.put(path, data)?;
        Ok(TransferReport { bytes: data.len() as u64, virtual_secs: b.transfer_secs(data.len() as u64) })
    }

    pub fn get(&self, uri: &str) -> Result<Vec<u8>, DataError> {
        let (site, path) = Self::split(uri)?;
        self.site(site)?.get(path)
    }

    pub fn exists(&self, uri: &str) -> Result<bool, DataError> {
        let (site, path) = Self::split(uri)?;
        Ok(self.site(site)?.exists(path))
    }

    pub fn list(&self, uri_prefix: &str) -> Result<Vec<String>, DataError> {
        let (site, prefix) = Self::split(uri_prefix)?;
        self.site(site)?.list(prefix)
    }

    pub fn delete(&mut self, uri: &str) -> Result<(), DataError> {
        let (site, path) = Self::split(uri)?;
        self.site_mut(site)?.delete(path)
    }

    /// Copy across (or within) sites; returns the transfer cost.
    pub fn copy(&mut self, src: &str, dst: &str) -> Result<TransferReport, DataError> {
        let data = self.get(src)?;
        let (ssite, _) = Self::split(src)?;
        let egress = self.site(ssite)?.transfer_secs(data.len() as u64);
        let mut r = self.put(dst, &data)?;
        r.virtual_secs += egress;
        Ok(r)
    }

    /// Move = copy + delete source.
    pub fn mv(&mut self, src: &str, dst: &str) -> Result<TransferReport, DataError> {
        let r = self.copy(src, dst)?;
        self.delete(src)?;
        Ok(r)
    }

    /// Link: cheap alias within one site (object stores: server-side copy;
    /// local fs: content copy, as portable fallback).
    pub fn link(&mut self, src: &str, dst: &str) -> Result<(), DataError> {
        let (ssite, _) = Self::split(src)?;
        let (dsite, _) = Self::split(dst)?;
        if ssite != dsite {
            return Err(DataError::BadUri(format!(
                "link requires same site: {ssite} vs {dsite}"
            )));
        }
        let data = self.get(src)?;
        self.put(dst, &data)?;
        Ok(())
    }

    /// Stage one object onto many sites (FACTS pre-staging, §5.4): returns
    /// per-site transfer reports.
    pub fn stage_to_sites(
        &mut self,
        src: &str,
        sites: &[&str],
        dst_path: &str,
    ) -> Result<Vec<(String, TransferReport)>, DataError> {
        let mut out = Vec::new();
        for site in sites {
            let dst = format!("{site}://{dst_path}");
            let r = self.copy(src, &dst)?;
            out.push((site.to_string(), r));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hydra-data-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn manager(tag: &str) -> (DataManager, PathBuf) {
        let dir = tmpdir(tag);
        let mut m = DataManager::new();
        m.register("local", Box::new(LocalFs::new(dir.clone()).unwrap()));
        m.register("jet2", Box::new(SimObjectStore::new(100e6, 0.05)));
        m.register("aws", Box::new(SimObjectStore::new(50e6, 0.08)));
        (m, dir)
    }

    #[test]
    fn put_get_roundtrip_on_both_backends() {
        let (mut m, dir) = manager("rt");
        for uri in ["local://a/b.bin", "jet2://a/b.bin"] {
            m.put(uri, b"hello").unwrap();
            assert_eq!(m.get(uri).unwrap(), b"hello");
            assert!(m.exists(uri).unwrap());
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_filters_by_prefix() {
        let (mut m, dir) = manager("ls");
        m.put("jet2://facts/input/t.nc", b"1").unwrap();
        m.put("jet2://facts/input/s.nc", b"2").unwrap();
        m.put("jet2://other/x", b"3").unwrap();
        let l = m.list("jet2://facts/").unwrap();
        assert_eq!(l, vec!["facts/input/s.nc".to_string(), "facts/input/t.nc".to_string()]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn copy_across_sites_accumulates_transfer_cost() {
        let (mut m, dir) = manager("cp");
        let payload = vec![0u8; 1_000_000];
        m.put("jet2://d.bin", &payload).unwrap();
        let r = m.copy("jet2://d.bin", "aws://d.bin").unwrap();
        assert_eq!(r.bytes, 1_000_000);
        // egress at 100 MB/s + ingress at 50 MB/s + latencies
        let want = 0.05 + 1e6 / 100e6 + 0.08 + 1e6 / 50e6;
        assert!((r.virtual_secs - want).abs() < 1e-9, "{}", r.virtual_secs);
        assert!(m.exists("aws://d.bin").unwrap());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mv_removes_source() {
        let (mut m, dir) = manager("mv");
        m.put("jet2://x", b"d").unwrap();
        m.mv("jet2://x", "aws://x").unwrap();
        assert!(!m.exists("jet2://x").unwrap());
        assert!(m.exists("aws://x").unwrap());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn link_same_site_only() {
        let (mut m, dir) = manager("ln");
        m.put("jet2://orig", b"d").unwrap();
        m.link("jet2://orig", "jet2://alias").unwrap();
        assert_eq!(m.get("jet2://alias").unwrap(), b"d");
        assert!(m.link("jet2://orig", "aws://alias").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sandbox_escape_rejected() {
        let (mut m, dir) = manager("esc");
        assert!(matches!(m.put("local://../evil", b"x"), Err(DataError::Escape(_))));
        assert!(matches!(m.put("local:///abs", b"x"), Err(DataError::Escape(_))));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn errors_for_unknown_site_and_bad_uri() {
        let (m, dir) = manager("err");
        assert!(matches!(m.get("nope://x"), Err(DataError::UnknownSite(_))));
        assert!(matches!(m.get("no-scheme"), Err(DataError::BadUri(_))));
        assert!(matches!(m.get("jet2://missing"), Err(DataError::NotFound(_))));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn staging_to_multiple_sites() {
        let (mut m, dir) = manager("stage");
        m.put("local://facts-input.nc", &vec![1u8; 10_000]).unwrap();
        let reports = m
            .stage_to_sites("local://facts-input.nc", &["jet2", "aws"], "facts/in.nc")
            .unwrap();
        assert_eq!(reports.len(), 2);
        assert!(m.exists("jet2://facts/in.nc").unwrap());
        assert!(m.exists("aws://facts/in.nc").unwrap());
        assert!(reports[1].1.virtual_secs > reports[0].1.virtual_secs); // aws slower
        std::fs::remove_dir_all(dir).ok();
    }
}
