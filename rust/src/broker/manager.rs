//! The open service-manager interface of the Service Proxy (paper §3.1).
//!
//! The paper's headline design claim is that the Service Proxy "exposes a
//! private interface to add new managers like, for example, a Function as
//! a Service manager". This module makes that interface a public Rust
//! trait: every workload manager — CaaS, HPC batch, FaaS, and whatever
//! comes next — implements [`ServiceManager`] and returns the same
//! unified [`ManagerRun`] report, and [`ManagerFactory`] holds the one
//! and only `ServiceKind` → manager dispatch in the codebase. Both the
//! [`ServiceProxy`](crate::broker::service_proxy::ServiceProxy) and the
//! [`WorkflowEngine`](crate::workflow::engine::WorkflowEngine) consume
//! managers exclusively through this factory, so adding a manager means
//! adding one `ServiceKind` variant, a [`RunDetail`]/[`ManagerReport`]
//! variant for its report, one `impl ServiceManager`, and one factory
//! arm — the proxy, the workflow engine, and every report consumer stay
//! untouched.
//!
//! Report unification: the managers' previously divergent report structs
//! collapse into `ManagerRun { metrics, bytes_serialized, bulk_bytes,
//! detail }`, with the provider-specific simulator reports preserved
//! inside [`RunDetail`]. [`ManagerReport`] wraps a run per service kind
//! for ergonomic matching on the brokered-run surface
//! ([`BrokerRun::reports`](crate::broker::service_proxy::BrokerRun)).

// This module is the extension surface third parties implement against,
// so it holds itself to a stricter documentation bar than the rest of
// the crate (see [lints] in Cargo.toml for the crate-wide set).
#![warn(missing_docs)]

use crate::api::resource::{ResourceRequest, ServiceKind};
use crate::api::task::{TaskDescription, TaskId};
use crate::api::ProviderConfig;
use crate::broker::caas::CaasManager;
use crate::broker::data::{SerializeOptions, SubmitError};
use crate::broker::faas::FaasManager;
use crate::broker::provider_proxy::CircuitBreaker;
use crate::broker::hpc::HpcManager;
use crate::broker::partitioner::{PartitionError, PartitionModel, Partitioner, PodBuildMode};
use crate::broker::state::{StateError, TaskRegistry};
use crate::metrics::RunMetrics;
use crate::sim::faas::FaasReport;
use crate::sim::hpc::MultiPilotReport;
use crate::sim::kubernetes::SimReport;
use crate::sim::provider::ProviderId;
use crate::sim::vm::ProvisionReport;
use std::sync::Arc;

/// Errors surfaced by any service manager (validation, partitioning, or
/// task-state bookkeeping). One error type for every manager: the broker
/// and workflow layers handle manager failure uniformly.
#[non_exhaustive]
#[derive(Debug)]
pub enum ManagerError {
    /// A task description failed validation before submission.
    InvalidTask(String),
    /// The resource request (or the credentials backing it) is invalid
    /// or bound to a different provider than this manager's connection.
    InvalidResource(String),
    /// The partitioner could not cut the workload into pods.
    Partition(PartitionError),
    /// A task-state transition violated the registry's lifecycle rules.
    State(StateError),
    /// The provider control plane rejected the bulk submit after the
    /// retry policy was exhausted (ISSUE 7). `retryable` classifies the
    /// failure for the broker: provider-local faults can be re-brokered
    /// to a surviving provider, the rest are terminal.
    Submit { message: String, retryable: bool, attempts: u32, backoff_ms: u64 },
    /// The provider's ack for an accepted bulk payload failed the
    /// manager's round-trip verification (ISSUE 10): the echoed item
    /// count or a first/last id spot-check disagrees with what was
    /// framed. **Never retryable** — the provider *accepted* the bytes,
    /// so resubmitting the same payload (here or on another provider)
    /// would only duplicate work; the mismatch signals payload
    /// corruption, which must surface, not be papered over.
    AckMismatch {
        /// What disagreed (expected vs echoed).
        message: String,
    },
}

impl std::fmt::Display for ManagerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManagerError::InvalidTask(m) => write!(f, "invalid task: {m}"),
            ManagerError::InvalidResource(m) => write!(f, "invalid resource: {m}"),
            ManagerError::Partition(e) => write!(f, "partitioning failed: {e}"),
            ManagerError::State(e) => write!(f, "state error: {e}"),
            ManagerError::Submit { message, retryable, .. } => {
                let class = if *retryable { "retryable" } else { "terminal" };
                write!(f, "submit failed ({class}): {message}")
            }
            ManagerError::AckMismatch { message } => {
                write!(f, "provider ack mismatch (terminal): {message}")
            }
        }
    }
}

impl std::error::Error for ManagerError {}

impl ManagerError {
    /// May the broker re-broker the workload slice to another provider?
    /// Only control-plane submit failures are provider-local; every
    /// other manager error would reproduce identically elsewhere.
    pub fn retryable(&self) -> bool {
        match self {
            ManagerError::Submit { retryable, .. } => *retryable,
            _ => false,
        }
    }
}

impl From<SubmitError> for ManagerError {
    fn from(e: SubmitError) -> Self {
        ManagerError::Submit {
            message: e.to_string(),
            retryable: true,
            attempts: e.attempts,
            backoff_ms: (e.backoff_s * 1000.0).round() as u64,
        }
    }
}

impl From<PartitionError> for ManagerError {
    fn from(e: PartitionError) -> Self {
        ManagerError::Partition(e)
    }
}

impl From<StateError> for ManagerError {
    fn from(e: StateError) -> Self {
        ManagerError::State(e)
    }
}

/// Shared constructor gate for every manager: validated credentials, a
/// valid resource request, and a resource bound to this provider
/// connection. Managers call this from `new` so the checks hold on both
/// the factory path and direct construction.
pub(crate) fn validate_binding(
    config: &ProviderConfig,
    resource: &ResourceRequest,
) -> Result<(), ManagerError> {
    config.credentials.validate().map_err(ManagerError::InvalidResource)?;
    resource.validate().map_err(ManagerError::InvalidResource)?;
    if resource.provider != config.id {
        return Err(ManagerError::InvalidResource(format!(
            "resource targets {} but manager is connected to {}",
            resource.provider, config.id
        )));
    }
    Ok(())
}

/// Provider-specific outcome of a manager run: the simulator report (and,
/// for CaaS, the cluster-provision report) behind the unified metrics.
/// `#[non_exhaustive]`: the next manager adds a variant here without a
/// breaking change.
#[non_exhaustive]
#[derive(Debug)]
pub enum RunDetail {
    /// Container-as-a-Service run on a provisioned Kubernetes cluster.
    Caas {
        /// Kubernetes scheduling simulation report (pod placements,
        /// node utilization, makespan).
        sim: SimReport,
        /// Cluster readiness (virtual seconds before the workload could
        /// start); reported separately from TPT, as in the paper.
        provision: ProvisionReport,
    },
    /// HPC batch run executed through a pilot fleet.
    Hpc {
        /// Pilot-fleet report: per-task records plus per-pilot lifecycle
        /// and utilization stats ([`PilotStat`](crate::sim::hpc::PilotStat)
        /// per staged pilot — one entry when `pilots == 1`).
        sim: MultiPilotReport,
    },
    /// Function-as-a-Service run on a concurrency-limited platform.
    Faas {
        /// FaaS invocation report (cold starts, concurrency, makespan).
        sim: FaasReport,
    },
}

impl RunDetail {
    /// The service kind that produced this detail.
    pub fn service(&self) -> ServiceKind {
        match self {
            RunDetail::Caas { .. } => ServiceKind::Caas,
            RunDetail::Hpc { .. } => ServiceKind::Batch,
            RunDetail::Faas { .. } => ServiceKind::Faas,
        }
    }

    /// The Kubernetes simulation report, if this is a CaaS run.
    pub fn caas_sim(&self) -> Option<&SimReport> {
        match self {
            RunDetail::Caas { sim, .. } => Some(sim),
            _ => None,
        }
    }

    /// The cluster-provision report, if this is a CaaS run.
    pub fn provision(&self) -> Option<&ProvisionReport> {
        match self {
            RunDetail::Caas { provision, .. } => Some(provision),
            _ => None,
        }
    }

    /// The pilot-fleet report, if this is an HPC batch run.
    pub fn hpc_sim(&self) -> Option<&MultiPilotReport> {
        match self {
            RunDetail::Hpc { sim } => Some(sim),
            _ => None,
        }
    }

    /// The FaaS invocation report, if this is a FaaS run.
    pub fn faas_sim(&self) -> Option<&FaasReport> {
        match self {
            RunDetail::Faas { sim } => Some(sim),
            _ => None,
        }
    }
}

/// Fault and retry accounting of one manager execution, uniform across
/// service kinds (ISSUE 6). Zero everywhere for a healthy run; the HPC
/// manager fills the retry fields from the pilot-fleet fault model, the
/// CaaS/FaaS managers report task-level failures only.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTally {
    /// Tasks whose final record carries `failed: true` (injected
    /// task-level failures). Previously filtered out of the HPC report
    /// and never surfaced.
    pub failed: usize,
    /// Task launches rolled back off dead pilots and re-queued.
    pub retried: usize,
    /// Tasks reported abandoned (retry budget exhausted or fleet dead).
    pub abandoned: usize,
    /// Resubmission bulks sent after pilot deaths.
    pub retry_waves: usize,
    /// Transport bytes of those resubmission bulks.
    pub retry_bulk_bytes: usize,
    /// Bulk-submit attempts retried against the provider control plane
    /// (ISSUE 7; populated by all three managers).
    pub submit_retries: usize,
    /// Simulated backoff charged into OVH while retrying, rounded to
    /// whole milliseconds (kept integral so the tally stays `Eq`).
    pub backoff_ms: u64,
    /// Circuit-breaker open transitions observed during this execution.
    pub circuit_opens: usize,
    /// Tasks completed on this provider after failing over from another
    /// (set by the broker on the failover leg, not by the manager).
    pub failed_over: usize,
}

/// Unified report of one manager execution — the same shape for every
/// service kind, replacing the three divergent per-manager report
/// structs. Byte accounting is uniform: `bytes_serialized` counts the
/// serialized item bytes (manifests / task dicts / invocations, bulk
/// envelope excluded), `bulk_bytes` the framed `[i0,i1,...]` payload the
/// provider-API sink accepted (resubmission bulks counted separately in
/// `faults.retry_bulk_bytes`).
#[derive(Debug)]
pub struct ManagerRun {
    /// Unified run metrics (task counts, OVH components, TPT/TTX).
    pub metrics: RunMetrics,
    /// Serialized item bytes (separators and brackets excluded).
    pub bytes_serialized: usize,
    /// Framed bulk payload bytes accepted by the provider-API sink.
    pub bulk_bytes: usize,
    /// Failure / retry / abandonment accounting (ISSUE 6).
    pub faults: FaultTally,
    /// Provider-specific simulator report behind the unified metrics.
    pub detail: RunDetail,
}

/// Per-provider report carried by a brokered run, keyed by service kind
/// for ergonomic matching. `#[non_exhaustive]`: grows with [`RunDetail`].
#[non_exhaustive]
#[derive(Debug)]
pub enum ManagerReport {
    /// Run served by the CaaS (Kubernetes) manager.
    Caas(ManagerRun),
    /// Run served by the HPC batch (pilot-fleet) manager.
    Hpc(ManagerRun),
    /// Run served by the FaaS manager.
    Faas(ManagerRun),
}

impl ManagerReport {
    /// The unified run behind the per-kind wrapper.
    pub fn run(&self) -> &ManagerRun {
        match self {
            ManagerReport::Caas(r) | ManagerReport::Hpc(r) | ManagerReport::Faas(r) => r,
        }
    }

    /// Shorthand for the wrapped run's unified metrics.
    pub fn metrics(&self) -> &RunMetrics {
        &self.run().metrics
    }
}

impl From<ManagerRun> for ManagerReport {
    /// Wrap a run under the variant matching its detail — the two can
    /// never disagree because this is the only constructor on the broker
    /// path.
    fn from(run: ManagerRun) -> ManagerReport {
        match run.detail.service() {
            ServiceKind::Caas => ManagerReport::Caas(run),
            ServiceKind::Batch => ManagerReport::Hpc(run),
            ServiceKind::Faas => ManagerReport::Faas(run),
        }
    }
}

/// A workload manager for one service on one provider connection: the
/// paper's §3.1 manager interface, opened as a public trait.
///
/// Implementations execute their slice of the workload end to end
/// (validate → translate/partition → serialize → bulk-submit → trace to
/// final states) and report the unified [`ManagerRun`]. Descriptions
/// arrive as registry-shared `Arc` handles (§Perf: no description clone
/// per manager hop). `Send` because the Service Proxy runs one manager
/// per provider thread.
pub trait ServiceManager: Send {
    /// The service kind this manager drives.
    fn service(&self) -> ServiceKind;

    /// Execute the workload slice end to end against this manager's
    /// provider, recording every task transition in `registry`.
    fn execute(
        &self,
        tasks: &[(TaskId, Arc<TaskDescription>)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError>;
}

impl ServiceManager for CaasManager {
    fn service(&self) -> ServiceKind {
        ServiceKind::Caas
    }

    fn execute(
        &self,
        tasks: &[(TaskId, Arc<TaskDescription>)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        CaasManager::execute(self, tasks, registry)
    }
}

impl ServiceManager for HpcManager {
    fn service(&self) -> ServiceKind {
        ServiceKind::Batch
    }

    fn execute(
        &self,
        tasks: &[(TaskId, Arc<TaskDescription>)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        HpcManager::execute(self, tasks, registry)
    }
}

impl ServiceManager for FaasManager {
    fn service(&self) -> ServiceKind {
        ServiceKind::Faas
    }

    fn execute(
        &self,
        tasks: &[(TaskId, Arc<TaskDescription>)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        FaasManager::execute(self, tasks, registry)
    }
}

/// The one place `ServiceKind` is dispatched to a manager implementation.
///
/// Holds the broker knobs a manager needs at construction time
/// (partitioning model, manifest build mode, serialize-phase fan-out) and
/// instantiates the right [`ServiceManager`] for a validated resource
/// request. Both `ServiceProxy::run` and the workflow engine build their
/// managers through here — adding a manager means adding one arm to
/// [`ManagerFactory::create`].
#[derive(Debug, Clone)]
pub struct ManagerFactory {
    /// Partitioning model handed to the CaaS manager's partitioner.
    pub partition_model: PartitionModel,
    /// Pod-manifest build mode (in-memory or per-provider disk staging).
    pub build_mode: PodBuildMode,
    /// Serialize-phase fan-out handed to every manager (`1` = serial
    /// reference path; bulk payload bytes are identical for any value).
    pub serialize: SerializeOptions,
}

impl Default for ManagerFactory {
    fn default() -> ManagerFactory {
        ManagerFactory {
            partition_model: PartitionModel::Mcpp { max_cpp: 16 },
            build_mode: PodBuildMode::Memory,
            serialize: SerializeOptions::default(),
        }
    }
}

impl ManagerFactory {
    /// A factory with explicit broker knobs (see [`ManagerFactory::default`]
    /// for the reference configuration).
    pub fn new(
        partition_model: PartitionModel,
        build_mode: PodBuildMode,
        serialize: SerializeOptions,
    ) -> ManagerFactory {
        ManagerFactory { partition_model, build_mode, serialize }
    }

    /// Disk staging is namespaced per provider, as the real Hydra keeps
    /// per-provider sandboxes.
    fn build_mode_for(&self, provider: ProviderId) -> PodBuildMode {
        match &self.build_mode {
            PodBuildMode::Memory => PodBuildMode::Memory,
            PodBuildMode::Disk { staging_dir } => PodBuildMode::Disk {
                staging_dir: staging_dir.join(provider.short_name()),
            },
        }
    }

    /// Instantiate the manager serving `resource.service` on the given
    /// provider connection — the single `ServiceKind` dispatch site.
    /// Each call gets a fresh circuit breaker; the broker path threads
    /// the provider handle's shared breaker through
    /// [`ManagerFactory::create_with_breaker`] instead.
    pub fn create(
        &self,
        config: ProviderConfig,
        resource: ResourceRequest,
        seed: u64,
    ) -> Result<Box<dyn ServiceManager>, ManagerError> {
        self.create_with_breaker(config, resource, seed, CircuitBreaker::default())
    }

    /// [`ManagerFactory::create`], but sharing an existing per-provider
    /// circuit breaker (clones share state), so consecutive manager
    /// executions against one connection observe the same circuit.
    pub fn create_with_breaker(
        &self,
        config: ProviderConfig,
        resource: ResourceRequest,
        seed: u64,
        breaker: CircuitBreaker,
    ) -> Result<Box<dyn ServiceManager>, ManagerError> {
        match resource.service {
            ServiceKind::Caas => {
                let partitioner =
                    Partitioner::new(self.partition_model, self.build_mode_for(resource.provider))
                        .with_serialize(self.serialize);
                let mgr = CaasManager::new(config, resource, partitioner, seed)?;
                Ok(Box::new(mgr.with_breaker(breaker)))
            }
            ServiceKind::Batch => {
                let mgr = HpcManager::new(config, resource, seed)?;
                Ok(Box::new(mgr.with_serialize(self.serialize).with_breaker(breaker)))
            }
            ServiceKind::Faas => {
                let mgr = FaasManager::new(config, resource, seed)?;
                Ok(Box::new(mgr.with_serialize(self.serialize).with_breaker(breaker)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::Payload;

    fn arc_tasks(
        reg: &TaskRegistry,
        descs: Vec<TaskDescription>,
    ) -> Vec<(TaskId, Arc<TaskDescription>)> {
        reg.register_all_shared(descs)
    }

    #[test]
    fn factory_creates_each_manager_kind() {
        let f = ManagerFactory::default();
        let cases = [
            (ResourceRequest::kubernetes(ProviderId::Aws, 1, 8), ServiceKind::Caas),
            (ResourceRequest::pilot(ProviderId::Bridges2, 1), ServiceKind::Batch),
            (ResourceRequest::faas(ProviderId::Aws, 16), ServiceKind::Faas),
        ];
        for (req, kind) in cases {
            let cfg = ProviderConfig::simulated(req.provider);
            let m = f.create(cfg, req, 1).unwrap();
            assert_eq!(m.service(), kind);
        }
    }

    #[test]
    fn factory_rejects_invalid_requests() {
        let f = ManagerFactory::default();
        // CaaS on an HPC platform, FaaS on an HPC platform, zero nodes,
        // zero pilots.
        for req in [
            ResourceRequest::kubernetes(ProviderId::Bridges2, 1, 8),
            ResourceRequest::faas(ProviderId::Bridges2, 16),
            ResourceRequest::pilot(ProviderId::Bridges2, 0),
            ResourceRequest::hpc(ProviderId::Bridges2, 1, 0),
        ] {
            let cfg = ProviderConfig::simulated(req.provider);
            assert!(f.create(cfg, req, 1).is_err());
        }
    }

    #[test]
    fn trait_objects_execute_like_concrete_managers() {
        // The same workload through a Box<dyn ServiceManager> produces a
        // unified run whose detail carries the kind-specific report.
        let f = ManagerFactory::default();
        let reg = TaskRegistry::new();
        let tasks = arc_tasks(
            &reg,
            (0..96)
                .map(|i| {
                    TaskDescription::function(format!("fn-{i}"), "pkg.handler")
                        .with_payload(Payload::Work(0.5))
                })
                .collect(),
        );
        let m = f
            .create(
                ProviderConfig::simulated(ProviderId::Aws),
                ResourceRequest::faas(ProviderId::Aws, 32),
                5,
            )
            .unwrap();
        let run = m.execute(&tasks, &reg).unwrap();
        assert_eq!(run.metrics.tasks, 96);
        assert_eq!(run.detail.service(), ServiceKind::Faas);
        assert!(run.detail.faas_sim().unwrap().cold_starts >= 1);
        assert!(run.bulk_bytes > run.bytes_serialized);
        assert!(reg.all_final());
        let report = ManagerReport::from(run);
        assert!(matches!(report, ManagerReport::Faas(_)));
        assert_eq!(report.metrics().tasks, 96);
    }

    #[test]
    fn report_wrapper_matches_detail_for_all_kinds() {
        let f = ManagerFactory::default();
        let cases = [
            (
                ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16),
                TaskDescription::container("c", "img"),
            ),
            (
                ResourceRequest::pilot(ProviderId::Bridges2, 1),
                TaskDescription::executable("e", "noop"),
            ),
            (
                ResourceRequest::faas(ProviderId::Azure, 8),
                TaskDescription::function("f", "pkg.handler"),
            ),
        ];
        for (req, desc) in cases {
            let reg = TaskRegistry::new();
            let tasks = arc_tasks(&reg, (0..8).map(|_| desc.clone()).collect());
            let cfg = ProviderConfig::simulated(req.provider);
            let kind = req.service;
            let run = f.create(cfg, req, 3).unwrap().execute(&tasks, &reg).unwrap();
            let report = ManagerReport::from(run);
            assert_eq!(report.run().detail.service(), kind);
        }
    }
}
