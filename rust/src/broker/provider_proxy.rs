//! Provider Proxy: credential validation and provider bring-up.
//!
//! Paper §3.1: "Provider Proxy collects information about the user and the
//! provider interfaces, verifying the user's credentials to guarantee the
//! successful startup of Hydra's engine and services."

use crate::api::provider::ProviderConfig;
use crate::sim::provider::ProviderId;
use crate::util::toml_lite;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A validated, ready-to-use provider connection.
#[derive(Debug, Clone)]
pub struct ProviderHandle {
    pub config: ProviderConfig,
    /// Deterministic token from the simulated auth handshake.
    pub session_token: u64,
    /// Per-provider circuit breaker shared by every manager execution
    /// against this connection (clones share state).
    pub breaker: CircuitBreaker,
}

/// Circuit breaker state (classic three-state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CircuitState {
    /// Normal operation; submits flow through.
    Closed,
    /// Tripped after K consecutive failures; submits fast-fail.
    Open,
    /// Cooled down; the next submit is a probe that closes (success)
    /// or re-opens (failure) the circuit.
    HalfOpen,
}

impl std::fmt::Display for CircuitState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CircuitState::Closed => write!(f, "closed"),
            CircuitState::Open => write!(f, "open"),
            CircuitState::HalfOpen => write!(f, "half-open"),
        }
    }
}

#[derive(Debug)]
struct BreakerCore {
    state: CircuitState,
    consecutive_failures: u32,
    threshold: u32,
    /// Fast-fail denials served while open; stands in for a cooldown
    /// clock so the breaker stays deterministic.
    denied: u32,
    opens: usize,
}

/// Per-provider circuit breaker: closed → open after `threshold`
/// consecutive submit failures → half-open probe after a deterministic
/// cooldown (one fast-fail denial stands in for elapsed time).
///
/// `Clone` shares the underlying state, so the handle's breaker and the
/// endpoints created from it observe the same circuit.
#[derive(Debug, Clone)]
pub struct CircuitBreaker {
    inner: Arc<Mutex<BreakerCore>>,
}

impl Default for CircuitBreaker {
    fn default() -> CircuitBreaker {
        CircuitBreaker::new()
    }
}

impl CircuitBreaker {
    pub const DEFAULT_THRESHOLD: u32 = 5;

    pub fn new() -> CircuitBreaker {
        CircuitBreaker::with_threshold(Self::DEFAULT_THRESHOLD)
    }

    pub fn with_threshold(threshold: u32) -> CircuitBreaker {
        assert!(threshold >= 1, "breaker threshold must be >= 1");
        CircuitBreaker {
            inner: Arc::new(Mutex::new(BreakerCore {
                state: CircuitState::Closed,
                consecutive_failures: 0,
                threshold,
                denied: 0,
                opens: 0,
            })),
        }
    }

    /// May a submit attempt proceed? While open, the first call
    /// fast-fails and the second transitions to half-open (the probe).
    pub fn allow(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.state {
            CircuitState::Closed | CircuitState::HalfOpen => true,
            CircuitState::Open => {
                g.denied += 1;
                if g.denied >= 2 {
                    g.state = CircuitState::HalfOpen;
                    g.denied = 0;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A submit landed: close the circuit and reset failure counting.
    pub fn record_success(&self) {
        let mut g = self.inner.lock().unwrap();
        g.state = CircuitState::Closed;
        g.consecutive_failures = 0;
        g.denied = 0;
    }

    /// A submit failed. Returns `true` iff this failure just opened the
    /// circuit (callers count `circuit_opens` off that edge).
    pub fn record_failure(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.state {
            CircuitState::HalfOpen => {
                g.state = CircuitState::Open;
                g.denied = 0;
                g.opens += 1;
                true
            }
            CircuitState::Closed => {
                g.consecutive_failures += 1;
                if g.consecutive_failures >= g.threshold {
                    g.state = CircuitState::Open;
                    g.denied = 0;
                    g.opens += 1;
                    true
                } else {
                    false
                }
            }
            CircuitState::Open => false,
        }
    }

    pub fn state(&self) -> CircuitState {
        self.inner.lock().unwrap().state
    }

    pub fn is_open(&self) -> bool {
        self.state() == CircuitState::Open
    }

    /// Lifetime count of closed/half-open → open transitions.
    pub fn opens(&self) -> usize {
        self.inner.lock().unwrap().opens
    }
}

#[derive(Debug)]
pub enum ProxyError {
    Config(String),
    Credentials { provider: ProviderId, reason: String },
    Duplicate(ProviderId),
    NoneEnabled,
}

impl std::fmt::Display for ProxyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProxyError::Config(m) => write!(f, "config error: {m}"),
            ProxyError::Credentials { provider, reason } => {
                write!(f, "{provider}: credential validation failed: {reason}")
            }
            ProxyError::Duplicate(p) => write!(f, "provider {p} configured twice"),
            ProxyError::NoneEnabled => write!(f, "no enabled providers in configuration"),
        }
    }
}

impl std::error::Error for ProxyError {}

/// The proxy: validates configs and hands out provider handles.
#[derive(Debug, Default)]
pub struct ProviderProxy {
    handles: BTreeMap<ProviderId, ProviderHandle>,
}

impl ProviderProxy {
    pub fn new() -> ProviderProxy {
        ProviderProxy { handles: BTreeMap::new() }
    }

    /// Validate and connect the given configs (disabled entries are
    /// skipped; duplicates and bad credentials are hard errors).
    pub fn connect(configs: Vec<ProviderConfig>) -> Result<ProviderProxy, ProxyError> {
        let mut proxy = ProviderProxy::new();
        for cfg in configs {
            if !cfg.enabled {
                continue;
            }
            if proxy.handles.contains_key(&cfg.id) {
                return Err(ProxyError::Duplicate(cfg.id));
            }
            cfg.credentials.validate().map_err(|reason| ProxyError::Credentials {
                provider: cfg.id,
                reason,
            })?;
            let session_token = cfg.credentials.handshake_token();
            proxy.handles.insert(
                cfg.id,
                ProviderHandle { config: cfg, session_token, breaker: CircuitBreaker::new() },
            );
        }
        if proxy.handles.is_empty() {
            return Err(ProxyError::NoneEnabled);
        }
        Ok(proxy)
    }

    /// Load + validate from a TOML config document.
    pub fn from_toml_str(text: &str) -> Result<ProviderProxy, ProxyError> {
        let doc = toml_lite::parse(text).map_err(|e| ProxyError::Config(e.to_string()))?;
        let configs = ProviderConfig::from_toml(&doc).map_err(ProxyError::Config)?;
        Self::connect(configs)
    }

    /// Connect all five simulated platforms (tests/examples).
    pub fn simulated_all() -> ProviderProxy {
        Self::connect(ProviderId::ALL.iter().map(|&id| ProviderConfig::simulated(id)).collect())
            .expect("simulated configs are valid")
    }

    /// Connect a chosen subset of simulated platforms.
    pub fn simulated(ids: &[ProviderId]) -> ProviderProxy {
        Self::connect(ids.iter().map(|&id| ProviderConfig::simulated(id)).collect())
            .expect("simulated configs are valid")
    }

    pub fn providers(&self) -> Vec<ProviderId> {
        self.handles.keys().copied().collect()
    }

    pub fn handle(&self, id: ProviderId) -> Option<&ProviderHandle> {
        self.handles.get(&id)
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::provider::Credentials;

    #[test]
    fn connects_simulated_providers() {
        let p = ProviderProxy::simulated_all();
        assert_eq!(p.len(), 5);
        assert!(p.handle(ProviderId::Aws).is_some());
        assert!(p.providers().windows(2).all(|w| w[0] < w[1]), "deterministic order");
    }

    #[test]
    fn bad_credentials_block_startup() {
        let mut cfg = ProviderConfig::simulated(ProviderId::Aws);
        cfg.credentials = Credentials::new("WRONG", "short");
        let e = ProviderProxy::connect(vec![cfg]).unwrap_err();
        assert!(matches!(e, ProxyError::Credentials { provider: ProviderId::Aws, .. }));
    }

    #[test]
    fn disabled_providers_skipped_but_not_all() {
        let mut a = ProviderConfig::simulated(ProviderId::Aws);
        a.enabled = false;
        let b = ProviderConfig::simulated(ProviderId::Azure);
        let p = ProviderProxy::connect(vec![a.clone(), b]).unwrap();
        assert_eq!(p.providers(), vec![ProviderId::Azure]);
        assert!(matches!(ProviderProxy::connect(vec![a]), Err(ProxyError::NoneEnabled)));
    }

    #[test]
    fn duplicates_rejected() {
        let a = ProviderConfig::simulated(ProviderId::Aws);
        let e = ProviderProxy::connect(vec![a.clone(), a]).unwrap_err();
        assert!(matches!(e, ProxyError::Duplicate(ProviderId::Aws)));
    }

    #[test]
    fn from_toml_end_to_end() {
        let p = ProviderProxy::from_toml_str(
            r#"
[provider.jet2]
access_key = "HK-jet2"
secret_key = "0123456789abcdef"

[provider.bridges2]
access_key = "HK-b2"
secret_key = "0123456789abcdef"
"#,
        )
        .unwrap();
        assert_eq!(p.providers(), vec![ProviderId::Jetstream2, ProviderId::Bridges2]);
    }

    #[test]
    fn breaker_opens_after_threshold_and_probes_half_open() {
        let b = CircuitBreaker::with_threshold(3);
        assert_eq!(b.state(), CircuitState::Closed);
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.record_failure(), "third consecutive failure opens");
        assert_eq!(b.state(), CircuitState::Open);
        assert_eq!(b.opens(), 1);
        // One fast-fail denial, then the half-open probe is allowed.
        assert!(!b.allow());
        assert!(b.allow());
        assert_eq!(b.state(), CircuitState::HalfOpen);
        // Probe failure re-opens immediately.
        assert!(b.record_failure());
        assert_eq!(b.state(), CircuitState::Open);
        assert_eq!(b.opens(), 2);
        // Probe success closes and resets failure counting.
        assert!(!b.allow());
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.state(), CircuitState::Closed);
        assert!(!b.record_failure(), "counter was reset by the success");
    }

    #[test]
    fn breaker_clones_share_state() {
        let b = CircuitBreaker::with_threshold(1);
        let c = b.clone();
        assert!(c.record_failure());
        assert!(b.is_open());
        assert_eq!(b.opens(), 1);
    }

    #[test]
    fn success_interleaved_resets_the_streak() {
        let b = CircuitBreaker::with_threshold(2);
        assert!(!b.record_failure());
        b.record_success();
        assert!(!b.record_failure());
        assert!(b.record_failure());
        assert!(b.is_open());
    }

    #[test]
    fn connected_handles_carry_closed_breakers() {
        let p = ProviderProxy::simulated(&[ProviderId::Aws]);
        let h = p.handle(ProviderId::Aws).unwrap();
        assert_eq!(h.breaker.state(), CircuitState::Closed);
        assert_eq!(h.breaker.opens(), 0);
    }

    #[test]
    fn toml_errors_propagate() {
        assert!(matches!(ProviderProxy::from_toml_str("bad ="), Err(ProxyError::Config(_))));
        assert!(matches!(
            ProviderProxy::from_toml_str("[provider.gcp]\naccess_key=\"a\"\nsecret_key=\"b\"\n"),
            Err(ProxyError::Config(_))
        ));
    }
}

#[cfg(test)]
mod shipped_config_tests {
    use super::*;

    #[test]
    fn shipped_example_config_parses_and_validates() {
        let text = include_str!("../../../configs/providers.toml");
        let proxy = ProviderProxy::from_toml_str(text).unwrap();
        assert_eq!(proxy.len(), 5, "all five platforms configured");
        for id in crate::sim::provider::ProviderId::ALL {
            assert!(proxy.handle(id).is_some(), "{id}");
        }
    }
}
