//! Brokering policies: binding tasks to providers.
//!
//! "User-specified brokering policies determine whether those tasks are
//! implemented as executables or containers and executed on cloud or HPC
//! resources" (paper §1). A policy maps a validated workload onto the set
//! of acquired providers; explicit per-task bindings always win.

use crate::api::resource::ServiceKind;
use crate::api::task::{TaskDescription, TaskId, TaskKind};
use crate::sim::provider::{PlatformProfile, ProviderId};
use std::collections::BTreeMap;

/// Placement policy across the acquired providers.
#[derive(Debug, Clone, PartialEq)]
pub enum BrokerPolicy {
    /// Cycle tasks across providers in order (the paper's equal split in
    /// Experiment 2).
    RoundRobin,
    /// Route by task kind onto the matching acquired service: containers
    /// to CaaS, executables to HPC batch, functions to FaaS (Experiment
    /// 3B's CON/EXEC split, extended to the open manager set).
    ByTaskKind,
    /// Weighted split proportional to the given weights.
    Weighted(Vec<(ProviderId, f64)>),
    /// Only explicit `task.on(provider)` bindings; unbound tasks error.
    ExplicitOnly,
}

#[derive(Debug, Clone, PartialEq)]
pub enum PolicyError {
    NoProviders,
    UnboundTask(TaskId),
    UnknownProvider { task: TaskId, provider: ProviderId },
    BadWeights(String),
    /// ByTaskKind had a task kind with no acquired service to run it.
    NoMatchingPlatform { task: TaskId, needed: &'static str },
}

impl std::fmt::Display for PolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyError::NoProviders => write!(f, "no providers acquired"),
            PolicyError::UnboundTask(t) => write!(f, "{t} has no provider binding"),
            PolicyError::UnknownProvider { task, provider } => {
                write!(f, "{task} bound to unacquired provider {provider}")
            }
            PolicyError::BadWeights(m) => write!(f, "bad weights: {m}"),
            PolicyError::NoMatchingPlatform { task, needed } => {
                write!(f, "{task} needs a {needed} service but none was acquired")
            }
        }
    }
}

impl std::error::Error for PolicyError {}

/// Assignment outcome: per-provider ordered task lists. A `BTreeMap`
/// keeps provider order deterministic.
pub type Assignment = BTreeMap<ProviderId, Vec<TaskId>>;

/// Performance-informed weights: proportional to each provider's
/// effective compute rate (cpu_speed x acquired cores). This is the
/// paper's §6 observation operationalized: "that information enables
/// Hydra's users to make binding decisions about tasks and resources
/// before starting the execution of the workflow".
pub fn perf_weighted(providers_with_cores: &[(ProviderId, u32)]) -> BrokerPolicy {
    BrokerPolicy::Weighted(
        providers_with_cores
            .iter()
            .map(|(p, cores)| {
                let profile = PlatformProfile::of(*p);
                (*p, profile.cpu_speed * *cores as f64)
            })
            .collect(),
    )
}

/// Bind every task to exactly one acquired provider.
///
/// `providers` carries the service kind acquired on each provider so
/// kind-aware policies (`ByTaskKind`) can route onto the matching
/// manager; kind-blind policies ignore it.
///
/// Generic over `Borrow<TaskDescription>` so the broker can pass
/// `Arc<TaskDescription>` handles shared with the registry (§Perf: no
/// description clones on the brokering path) while tests pass owned
/// descriptions.
///
/// Invariants (property-tested in `rust/tests/prop_invariants.rs`):
/// * every input task appears in exactly one provider list;
/// * only acquired providers appear;
/// * explicit bindings are honored verbatim.
pub fn assign<T: std::borrow::Borrow<TaskDescription>>(
    policy: &BrokerPolicy,
    tasks: &[(TaskId, T)],
    providers: &[(ProviderId, ServiceKind)],
) -> Result<Assignment, PolicyError> {
    if providers.is_empty() {
        return Err(PolicyError::NoProviders);
    }
    let mut out: Assignment = providers.iter().map(|(p, _)| (*p, Vec::new())).collect();

    // Pass 1: explicit bindings.
    let mut unbound: Vec<(TaskId, &TaskDescription)> = Vec::new();
    for (id, t) in tasks {
        let t = t.borrow();
        match t.provider {
            Some(p) => {
                out.get_mut(&p)
                    .ok_or(PolicyError::UnknownProvider { task: *id, provider: p })?
                    .push(*id);
            }
            None => unbound.push((*id, t)),
        }
    }

    // Pass 2: policy for the rest.
    match policy {
        BrokerPolicy::ExplicitOnly => {
            if let Some((id, _)) = unbound.first() {
                return Err(PolicyError::UnboundTask(*id));
            }
        }
        BrokerPolicy::RoundRobin => {
            for (i, (id, _)) in unbound.iter().enumerate() {
                let (p, _) = providers[i % providers.len()];
                out.get_mut(&p).unwrap().push(*id);
            }
        }
        BrokerPolicy::ByTaskKind => {
            let of_service = |kind: ServiceKind| -> Vec<ProviderId> {
                providers.iter().filter(|(_, s)| *s == kind).map(|(p, _)| *p).collect()
            };
            let caas = of_service(ServiceKind::Caas);
            let batch = of_service(ServiceKind::Batch);
            let faas = of_service(ServiceKind::Faas);
            let (mut ci, mut bi, mut fi) = (0usize, 0usize, 0usize);
            for (id, t) in &unbound {
                let (pool, cursor, needed) = match &t.kind {
                    TaskKind::Container { .. } => (&caas, &mut ci, "CaaS"),
                    TaskKind::Executable { .. } => (&batch, &mut bi, "HPC"),
                    TaskKind::Function { .. } => (&faas, &mut fi, "FaaS"),
                };
                if pool.is_empty() {
                    return Err(PolicyError::NoMatchingPlatform { task: *id, needed });
                }
                out.get_mut(&pool[*cursor % pool.len()]).unwrap().push(*id);
                *cursor += 1;
            }
        }
        BrokerPolicy::Weighted(weights) => {
            let total: f64 = weights.iter().map(|(_, w)| *w).sum();
            if weights.is_empty() || total <= 0.0 {
                return Err(PolicyError::BadWeights("weights must sum to > 0".into()));
            }
            for (p, w) in weights {
                if !providers.iter().any(|(q, _)| q == p) {
                    return Err(PolicyError::BadWeights(format!("{p} not acquired")));
                }
                if *w < 0.0 {
                    return Err(PolicyError::BadWeights(format!("{p}: negative weight")));
                }
            }
            // Largest-remainder apportionment, then round-robin the slack.
            let n = unbound.len();
            let mut quotas: Vec<(ProviderId, usize, f64)> = weights
                .iter()
                .map(|(p, w)| {
                    let exact = n as f64 * w / total;
                    (*p, exact.floor() as usize, exact - exact.floor())
                })
                .collect();
            let assigned: usize = quotas.iter().map(|(_, q, _)| q).sum();
            let mut slack = n - assigned;
            quotas.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
            for q in quotas.iter_mut() {
                if slack == 0 {
                    break;
                }
                q.1 += 1;
                slack -= 1;
            }
            let mut cursor = 0usize;
            for (p, take, _) in &quotas {
                for _ in 0..*take {
                    if cursor < unbound.len() {
                        out.get_mut(p).unwrap().push(unbound[cursor].0);
                        cursor += 1;
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Surviving providers a failed provider's slice can fail over to: same
/// acquired service kind (containers → another CaaS, executables →
/// another Batch provider), in acquisition order, the failed provider
/// excluded (ISSUE 7 cross-provider failover).
pub fn failover_targets(
    failed: ProviderId,
    kind: ServiceKind,
    providers: &[(ProviderId, ServiceKind)],
) -> Vec<ProviderId> {
    providers
        .iter()
        .filter(|(p, s)| *p != failed && *s == kind)
        .map(|(p, _)| *p)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::TaskDescription;

    fn con(i: u64) -> (TaskId, TaskDescription) {
        (TaskId(i), TaskDescription::container(format!("c{i}"), "noop:latest"))
    }

    fn exe(i: u64) -> (TaskId, TaskDescription) {
        (TaskId(i), TaskDescription::executable(format!("e{i}"), "sleep"))
    }

    fn fun(i: u64) -> (TaskId, TaskDescription) {
        (TaskId(i), TaskDescription::function(format!("f{i}"), "pkg.handler"))
    }

    /// Acquired providers with a CaaS service each (the common test case).
    fn caas(ps: &[ProviderId]) -> Vec<(ProviderId, ServiceKind)> {
        ps.iter().map(|&p| (p, ServiceKind::Caas)).collect()
    }

    fn total_assigned(a: &Assignment) -> usize {
        a.values().map(|v| v.len()).sum()
    }

    #[test]
    fn round_robin_splits_evenly() {
        let tasks: Vec<_> = (0..16).map(con).collect();
        let provs = [ProviderId::Aws, ProviderId::Azure, ProviderId::Jetstream2,
                     ProviderId::Chameleon];
        let a = assign(&BrokerPolicy::RoundRobin, &tasks, &caas(&provs)).unwrap();
        assert_eq!(total_assigned(&a), 16);
        for p in provs {
            assert_eq!(a[&p].len(), 4, "{p}");
        }
    }

    #[test]
    fn explicit_bindings_honored_under_any_policy() {
        let mut tasks: Vec<_> = (0..6).map(con).collect();
        tasks[3].1 = tasks[3].1.clone().on(ProviderId::Azure);
        let provs = caas(&[ProviderId::Aws, ProviderId::Azure]);
        let a = assign(&BrokerPolicy::RoundRobin, &tasks, &provs).unwrap();
        assert!(a[&ProviderId::Azure].contains(&TaskId(3)));
        assert_eq!(total_assigned(&a), 6);
    }

    #[test]
    fn by_task_kind_routes_each_kind_to_its_service() {
        let tasks: Vec<_> = vec![con(0), exe(1), con(2), exe(3), fun(4), fun(5)];
        let provs = [
            (ProviderId::Aws, ServiceKind::Caas),
            (ProviderId::Azure, ServiceKind::Faas),
            (ProviderId::Bridges2, ServiceKind::Batch),
        ];
        let a = assign(&BrokerPolicy::ByTaskKind, &tasks, &provs).unwrap();
        assert_eq!(a[&ProviderId::Aws], vec![TaskId(0), TaskId(2)]);
        assert_eq!(a[&ProviderId::Bridges2], vec![TaskId(1), TaskId(3)]);
        assert_eq!(a[&ProviderId::Azure], vec![TaskId(4), TaskId(5)]);
    }

    #[test]
    fn by_task_kind_errors_without_matching_service() {
        let provs = caas(&[ProviderId::Aws]);
        let e = assign(&BrokerPolicy::ByTaskKind, &[exe(0)], &provs).unwrap_err();
        assert!(matches!(e, PolicyError::NoMatchingPlatform { needed: "HPC", .. }));
        let e = assign(&BrokerPolicy::ByTaskKind, &[fun(0)], &provs).unwrap_err();
        assert!(matches!(e, PolicyError::NoMatchingPlatform { needed: "FaaS", .. }));
    }

    #[test]
    fn weighted_respects_proportions() {
        let tasks: Vec<_> = (0..100).map(con).collect();
        let provs = caas(&[ProviderId::Aws, ProviderId::Azure]);
        let a = assign(
            &BrokerPolicy::Weighted(vec![(ProviderId::Aws, 3.0), (ProviderId::Azure, 1.0)]),
            &tasks,
            &provs,
        )
        .unwrap();
        assert_eq!(a[&ProviderId::Aws].len(), 75);
        assert_eq!(a[&ProviderId::Azure].len(), 25);
    }

    #[test]
    fn weighted_largest_remainder_assigns_all() {
        let tasks: Vec<_> = (0..10).map(con).collect();
        let provs = caas(&[ProviderId::Aws, ProviderId::Azure, ProviderId::Jetstream2]);
        let a = assign(
            &BrokerPolicy::Weighted(vec![
                (ProviderId::Aws, 1.0),
                (ProviderId::Azure, 1.0),
                (ProviderId::Jetstream2, 1.0),
            ]),
            &tasks,
            &provs,
        )
        .unwrap();
        assert_eq!(total_assigned(&a), 10);
    }

    #[test]
    fn weighted_rejects_bad_configs() {
        let tasks = vec![con(0)];
        let provs = caas(&[ProviderId::Aws]);
        assert!(assign(&BrokerPolicy::Weighted(vec![]), &tasks, &provs).is_err());
        assert!(assign(
            &BrokerPolicy::Weighted(vec![(ProviderId::Azure, 1.0)]),
            &tasks,
            &provs
        )
        .is_err());
        assert!(assign(
            &BrokerPolicy::Weighted(vec![(ProviderId::Aws, -1.0), (ProviderId::Aws, 2.0)]),
            &tasks,
            &provs
        )
        .is_err());
    }

    #[test]
    fn explicit_only_requires_bindings() {
        let tasks = vec![con(0)];
        let provs = caas(&[ProviderId::Aws]);
        let e = assign(&BrokerPolicy::ExplicitOnly, &tasks, &provs).unwrap_err();
        assert_eq!(e, PolicyError::UnboundTask(TaskId(0)));
        let bound = vec![(TaskId(0), TaskDescription::container("t", "i").on(ProviderId::Aws))];
        assert!(assign(&BrokerPolicy::ExplicitOnly, &bound, &provs).is_ok());
    }

    #[test]
    fn binding_to_unacquired_provider_errors() {
        let tasks = vec![(TaskId(0), TaskDescription::container("t", "i").on(ProviderId::Azure))];
        let e = assign(&BrokerPolicy::RoundRobin, &tasks, &caas(&[ProviderId::Aws])).unwrap_err();
        assert!(matches!(e, PolicyError::UnknownProvider { .. }));
    }

    #[test]
    fn perf_weighted_prefers_faster_platforms() {
        let tasks: Vec<_> = (0..130).map(con).collect();
        let provs = [
            (ProviderId::Aws, ServiceKind::Caas),
            (ProviderId::Bridges2, ServiceKind::Batch),
        ];
        let policy = perf_weighted(&[(ProviderId::Aws, 16), (ProviderId::Bridges2, 128)]);
        let a = assign(&policy, &tasks, &provs).unwrap();
        // Bridges2 rate = 11*128 = 1408 vs AWS 16: ~99% of tasks.
        assert!(a[&ProviderId::Bridges2].len() > 120, "{}", a[&ProviderId::Bridges2].len());
        assert_eq!(a[&ProviderId::Aws].len() + a[&ProviderId::Bridges2].len(), 130);
    }

    #[test]
    fn failover_targets_match_service_kind_and_skip_the_failed_provider() {
        let provs = [
            (ProviderId::Jetstream2, ServiceKind::Caas),
            (ProviderId::Chameleon, ServiceKind::Caas),
            (ProviderId::Bridges2, ServiceKind::Batch),
            (ProviderId::Aws, ServiceKind::Faas),
        ];
        assert_eq!(
            failover_targets(ProviderId::Chameleon, ServiceKind::Caas, &provs),
            vec![ProviderId::Jetstream2]
        );
        assert_eq!(
            failover_targets(ProviderId::Jetstream2, ServiceKind::Caas, &provs),
            vec![ProviderId::Chameleon]
        );
        // The only Batch provider failing leaves nowhere to go.
        assert!(failover_targets(ProviderId::Bridges2, ServiceKind::Batch, &provs).is_empty());
        // Kind mismatches never cross: a dead CaaS never fails over to FaaS.
        assert!(!failover_targets(ProviderId::Chameleon, ServiceKind::Caas, &provs)
            .contains(&ProviderId::Aws));
    }

    #[test]
    fn no_providers_errors() {
        let none: [(TaskId, TaskDescription); 0] = [];
        assert_eq!(assign(&BrokerPolicy::RoundRobin, &none, &[]), Err(PolicyError::NoProviders));
    }

    #[test]
    fn assign_accepts_arc_shared_descriptions() {
        use std::sync::Arc;
        let tasks: Vec<(TaskId, Arc<TaskDescription>)> = (0..8)
            .map(|i| {
                let (id, t) = con(i);
                (id, Arc::new(t))
            })
            .collect();
        let provs = caas(&[ProviderId::Aws, ProviderId::Azure]);
        let a = assign(&BrokerPolicy::RoundRobin, &tasks, &provs).unwrap();
        assert_eq!(total_assigned(&a), 8);
        assert_eq!(a[&ProviderId::Aws].len(), 4);
    }
}
