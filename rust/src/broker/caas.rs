//! CaaS Manager: container workloads on (simulated) Kubernetes clusters.
//!
//! The manager implements the paper's §3.2 pipeline: it instantiates a
//! cluster from the `resource` request, partitions the workload into pods
//! that fit the available resources, serializes the pod manifests (disk or
//! memory — the paper's measured bottleneck and its prototyped fix),
//! submits the pods "to the service interface of each provider in a single
//! batch", then traces the concurrent execution of all tasks to a final
//! state and tears the resources down.
//!
//! Timing discipline (paper §5): everything the broker does is measured in
//! **real wall-clock time** and reported as OVH; everything the platform
//! does happens in **virtual time** on the simulator and is reported as
//! TPT/TTX.
//!
//! Implements the open manager interface (`broker::manager`): the broker
//! builds this manager through `ManagerFactory` and consumes the unified
//! `ManagerRun` report — the Kubernetes sim report and the cluster
//! provision report ride in `RunDetail::Caas`.

use crate::api::resource::ResourceRequest;
use crate::api::task::{TaskDescription, TaskId, TaskState};
use crate::api::ProviderConfig;
use crate::broker::data::ProviderEndpoint;
use crate::broker::manager::{FaultTally, ManagerError, ManagerRun, RunDetail};
use crate::broker::partitioner::{PartitionError, Partitioner, PodBuildMode, PreparedWorkload};
use crate::broker::provider_proxy::CircuitBreaker;
use crate::broker::state::TaskRegistry;
use crate::metrics::{Overhead, RunMetrics};
use crate::sim::kubernetes::{KubernetesSim, PodSpec};
use crate::sim::vm::{provision_cluster, ProvisionReport};
use crate::util::json_scan::JsonScanner;
use crate::util::prng::Prng;
use crate::util::Stopwatch;
use std::borrow::Borrow;

/// One CaaS manager instance per cloud provider connection.
pub struct CaasManager {
    pub config: ProviderConfig,
    pub resource: ResourceRequest,
    pub partitioner: Partitioner,
    pub seed: u64,
    /// When true, a task failure cancels the tasks that had not yet
    /// started (paper §3.2: managers "ensure graceful terminations ...
    /// upon failure of one or more tasks" when configured by the user).
    pub cancel_on_failure: bool,
    /// Injected per-container failure probability (0 = reliable platform).
    pub failure_rate: f64,
    /// Per-provider circuit breaker shared with the provider handle
    /// (clones share state; the factory threads the handle's breaker in).
    pub breaker: CircuitBreaker,
}

impl CaasManager {
    pub fn new(
        config: ProviderConfig,
        resource: ResourceRequest,
        partitioner: Partitioner,
        seed: u64,
    ) -> Result<CaasManager, ManagerError> {
        crate::broker::manager::validate_binding(&config, &resource)?;
        let failure_rate = resource.task_failure_rate;
        Ok(CaasManager {
            config,
            resource,
            partitioner,
            seed,
            cancel_on_failure: false,
            failure_rate,
            breaker: CircuitBreaker::default(),
        })
    }

    pub fn with_failure_handling(mut self, failure_rate: f64, cancel_on_failure: bool) -> Self {
        self.failure_rate = failure_rate;
        self.cancel_on_failure = cancel_on_failure;
        self
    }

    /// Share an existing per-provider circuit breaker.
    pub fn with_breaker(mut self, breaker: CircuitBreaker) -> Self {
        self.breaker = breaker;
        self
    }

    /// Provision the cluster (virtual time; happens once per manager).
    pub fn provision(&self) -> ProvisionReport {
        let mut rng = Prng::new(self.seed ^ 0x70_76);
        provision_cluster(&self.config.profile(), self.resource.nodes, &mut rng)
    }

    /// Execute a workload end to end: validate → partition → serialize →
    /// bulk submit → trace to completion → terminate.
    ///
    /// Generic over `Borrow<TaskDescription>`: the service proxy passes
    /// `Arc<TaskDescription>` handles shared with the registry (§Perf: no
    /// description clone per manager hop).
    pub fn execute<T: Borrow<TaskDescription>>(
        &self,
        tasks: &[(TaskId, T)],
        registry: &TaskRegistry,
    ) -> Result<ManagerRun, ManagerError> {
        let ids: Vec<TaskId> = tasks.iter().map(|(id, _)| *id).collect();

        // -- validate (gate to Validated) --------------------------------
        for (_, t) in tasks {
            t.borrow().validate().map_err(ManagerError::InvalidTask)?;
        }
        registry.transition_all(&ids, TaskState::Validated)?;

        let cluster = self.resource.cluster_spec();

        // -- OVH: partition ----------------------------------------------
        let sw = Stopwatch::start();
        let pods = self.partitioner.partition(tasks, &cluster, 0)?;
        let partition_s = sw.elapsed_secs();
        registry.transition_all(&ids, TaskState::Partitioned)?;

        // -- OVH: build + serialize manifests (sharded, §Perf) ------------
        // `build_manifests` consumes the pod vector and hands it back in
        // the prepared workload — the same allocation flows partition →
        // manifests → simulator with zero PodSpec copies — serializing
        // contiguous pod shards on scoped threads
        // (`self.partitioner.serialize` picks the fan-out).
        let sw = Stopwatch::start();
        let prepared = self.partitioner.build_manifests(pods, tasks)?;
        let serialize_s = sw.elapsed_secs();
        let n_pods = prepared.pods.len();
        let bytes_serialized = prepared.bytes_serialized;

        // -- OVH: frame + ship the bulk submission ------------------------
        // In Memory mode the bulk payload is framed directly from the
        // shard buffers — one copy per shard, never per manifest (§Perf);
        // in Disk mode the manifests are read back from the staging files
        // (the extra I/O round-trip the paper identifies as the
        // throughput limiter).
        let sw = Stopwatch::start();
        let bulk: Vec<u8> = match &self.partitioner.build_mode {
            PodBuildMode::Memory => prepared.frame_bulk(self.partitioner.serialize),
            PodBuildMode::Disk { .. } => {
                let mut bulk = Vec::with_capacity(bytes_serialized + n_pods + 1);
                bulk.push(b'[');
                for (i, path) in prepared.manifest_paths.iter().enumerate() {
                    if i > 0 {
                        bulk.push(b',');
                    }
                    let content = std::fs::read(path)
                        .map_err(|e| ManagerError::Partition(PartitionError::Io(e.to_string())))?;
                    bulk.extend_from_slice(&content);
                }
                bulk.push(b']');
                bulk
            }
        };
        let mut endpoint = ProviderEndpoint::new(
            self.resource.provider_fault,
            self.resource.retry,
            self.breaker.clone(),
            self.seed,
        );
        let receipt = endpoint.submit_acked(&bulk)?;
        let bulk_len = receipt.bytes;
        // Both modes ship every manifest byte plus the `[`/`,`/`]`
        // envelope; a mismatch means the framing dropped payload.
        let expected_bulk = if n_pods == 0 { 2 } else { bytes_serialized + n_pods + 1 };
        assert_eq!(bulk_len, expected_bulk, "bulk framing lost bytes");
        // -- ingest: verify the provider's ack round-trip (ISSUE 10) ------
        // Still inside the submit stopwatch window, so the lazy ack scan
        // is charged into OVH with the rest of the phase.
        verify_ack(&receipt.ack, &prepared.pods)?;
        // Simulated backoff is charged into OVH: resilience has a cost.
        let submit_s = sw.elapsed_secs() + endpoint.backoff_s();
        registry.transition_all(&ids, TaskState::Submitted)?;

        let PreparedWorkload { pods, .. } = prepared;

        // -- platform: simulate the execution (virtual time) -------------
        let mut sim = KubernetesSim::new(self.config.profile(), cluster, self.seed)
            .with_failure_rate(self.failure_rate);
        sim.submit(pods, 0.0);
        let report = sim.run();

        // -- trace tasks to final states ----------------------------------
        // Graceful termination: with cancel_on_failure, tasks that started
        // after the first failure are canceled rather than run to
        // completion (the manager tears the remaining workload down).
        let first_fail = report
            .tasks
            .iter()
            .filter(|r| r.failed)
            .map(|r| r.finished_s)
            .fold(f64::INFINITY, f64::min);
        for rec in &report.tasks {
            if rec.failed {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Running,
                    Some(rec.started_s),
                )?;
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Failed,
                    Some(rec.finished_s),
                )?;
            } else if self.cancel_on_failure && rec.started_s > first_fail {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Canceled,
                    Some(first_fail),
                )?;
            } else {
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Running,
                    Some(rec.started_s),
                )?;
                registry.transition_virtual(
                    TaskId(rec.task_id),
                    TaskState::Done,
                    Some(rec.finished_s),
                )?;
            }
        }

        let ovh = Overhead { partition_s, serialize_s, submit_s };
        let metrics = RunMetrics {
            provider: self.config.id,
            tasks: tasks.len(),
            pods: n_pods,
            ovh,
            tpt_s: report.makespan_s,
            ttx_s: report.makespan_s,
        };
        Ok(ManagerRun {
            metrics,
            bytes_serialized,
            bulk_bytes: bulk_len,
            // No pilot fleet on the CaaS path: task-level failures plus
            // the control-plane submit accounting.
            faults: FaultTally {
                failed: report.failed_tasks,
                submit_retries: endpoint.submit_retries(),
                backoff_ms: endpoint.backoff_ms(),
                circuit_opens: endpoint.circuit_opens(),
                ..FaultTally::default()
            },
            detail: RunDetail::Caas { sim: report, provision: self.provision() },
        })
    }
}

/// ISSUE 10 round-trip check: the provider's echoed ack must agree with
/// what this manager framed — item count equals the pod count, and the
/// first/last id echoes (the `hydra/pod-id` manifest label) match the
/// framed pods. Scanned lazily with [`JsonScanner`]; a disagreement
/// means the accepted payload differs from the framed one, which is
/// terminal (never retryable — the provider *took* the bytes).
fn verify_ack(ack: &str, pods: &[PodSpec]) -> Result<(), ManagerError> {
    let scan = JsonScanner::new(ack.as_bytes());
    let count = scan.path_u64(&["count"]);
    if count != Some(pods.len() as u64) {
        return Err(ManagerError::AckMismatch {
            message: format!("framed {} pod manifests, provider acked {count:?}", pods.len()),
        });
    }
    let (Some(first), Some(last)) = (pods.first(), pods.last()) else {
        return Ok(());
    };
    let checks = [
        ("first", first.id, scan.path_u64(&["first_id"])),
        ("last", last.id, scan.path_u64(&["last_id"])),
    ];
    for (which, want, got) in checks {
        if got != Some(want) {
            return Err(ManagerError::AckMismatch {
                message: format!("{which} pod id {want} not echoed, got {got:?}"),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::partitioner::PartitionModel;
    use crate::sim::provider::ProviderId;

    fn manager(model: PartitionModel) -> CaasManager {
        CaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 16),
            Partitioner::new(model, PodBuildMode::Memory),
            7,
        )
        .unwrap()
    }

    fn workload(reg: &TaskRegistry, n: usize) -> Vec<(TaskId, TaskDescription)> {
        (0..n)
            .map(|i| {
                let d = TaskDescription::container(format!("t{i}"), "noop:latest");
                (reg.register(d.clone()), d)
            })
            .collect()
    }

    #[test]
    fn executes_workload_to_done() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 64);
        let m = manager(PartitionModel::Mcpp { max_cpp: 16 });
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.metrics.tasks, 64);
        assert_eq!(r.metrics.pods, 4);
        assert!(r.metrics.ovh.total_s() > 0.0);
        assert!(r.metrics.tpt_s > 0.0);
        // Submit-phase sink accepted the full framed payload: every
        // manifest byte + 3 inter-pod commas + 2 brackets.
        assert_eq!(r.bulk_bytes, r.bytes_serialized + 4 + 1);
        assert!(reg.all_final());
    }

    #[test]
    fn scpp_creates_one_pod_per_task() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 20);
        let r = manager(PartitionModel::Scpp).execute(&tasks, &reg).unwrap();
        assert_eq!(r.metrics.pods, 20);
    }

    #[test]
    fn rejects_mismatched_provider() {
        let e = CaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Azure, 1, 8),
            Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory),
            0,
        );
        assert!(e.is_err());
    }

    #[test]
    fn rejects_invalid_task_before_any_transition() {
        let reg = TaskRegistry::new();
        let bad = TaskDescription::container("", "img");
        let id = reg.register(bad.clone());
        let m = manager(PartitionModel::Scpp);
        assert!(m.execute(&[(id, bad)], &reg).is_err());
        assert_eq!(reg.state_of(id), Some(TaskState::New));
    }

    #[test]
    fn disk_mode_roundtrips_manifests() {
        let dir = std::env::temp_dir().join(format!("hydra-caas-{}", std::process::id()));
        let m = CaasManager::new(
            ProviderConfig::simulated(ProviderId::Jetstream2),
            ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 8),
            Partitioner::new(PartitionModel::Scpp, PodBuildMode::Disk { staging_dir: dir.clone() }),
            3,
        )
        .unwrap();
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 12);
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.metrics.pods, 12);
        assert_eq!(r.bulk_bytes, r.bytes_serialized + 12 + 1);
        assert!(reg.all_final());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn provision_reports_cluster_readiness() {
        let m = manager(PartitionModel::Scpp);
        let p = m.provision();
        assert!(p.ready_s > 0.0);
        assert_eq!(p.node_ready_s.len(), 1);
    }

    #[test]
    fn failure_injection_traces_failed_tasks() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 200);
        let m = manager(PartitionModel::Scpp).with_failure_handling(0.2, false);
        let r = m.execute(&tasks, &reg).unwrap();
        let sim = r.detail.caas_sim().unwrap();
        assert!(sim.failed_tasks > 10, "expected ~40 failures, got {}", sim.failed_tasks);
        let counts = reg.counts();
        assert_eq!(counts.get(&TaskState::Failed), Some(&sim.failed_tasks));
        assert_eq!(
            counts.get(&TaskState::Done).copied().unwrap_or(0) + sim.failed_tasks,
            200
        );
        assert!(reg.all_final());
    }

    #[test]
    fn cancel_on_failure_cancels_later_tasks() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 400);
        let m = manager(PartitionModel::Scpp).with_failure_handling(0.05, true);
        m.execute(&tasks, &reg).unwrap();
        let counts = reg.counts();
        let canceled = counts.get(&TaskState::Canceled).copied().unwrap_or(0);
        assert!(canceled > 0, "graceful termination should cancel queued tasks: {counts:?}");
        assert!(reg.all_final());
    }

    #[test]
    fn zero_failure_rate_never_fails() {
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 100);
        let r = manager(PartitionModel::Scpp).execute(&tasks, &reg).unwrap();
        assert_eq!(r.detail.caas_sim().unwrap().failed_tasks, 0);
        assert_eq!(reg.counts().get(&TaskState::Done), Some(&100));
    }

    #[test]
    fn short_outage_is_ridden_out_and_surfaces_in_the_tally() {
        use crate::api::resource::ProviderFaultSpec;
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 64);
        let mut m = manager(PartitionModel::Scpp);
        // With default backoff (0.05s base, 2x, ±10% jitter) the clock
        // passes 0.12s after exactly two retries, for any jitter draw.
        m.resource = m.resource.clone().with_provider_faults(ProviderFaultSpec {
            outage_window: Some((0.0, 0.12)),
            ..ProviderFaultSpec::none()
        });
        let r = m.execute(&tasks, &reg).unwrap();
        assert_eq!(r.faults.submit_retries, 2);
        assert!(r.faults.backoff_ms > 0);
        // Two waits of >= 0.045s and >= 0.09s are charged into OVH.
        assert!(r.metrics.ovh.submit_s > 0.13, "backoff is charged into OVH");
        assert_eq!(r.faults.failed_over, 0, "failover is broker-level, not manager-level");
        assert_eq!(r.faults.circuit_opens, 0);
        assert!(reg.all_final());
    }

    #[test]
    fn hard_outage_errors_before_submitted_transition() {
        use crate::api::resource::ProviderFaultSpec;
        let reg = TaskRegistry::new();
        let tasks = workload(&reg, 16);
        let mut m = manager(PartitionModel::Scpp);
        m.resource = m.resource.clone().with_provider_faults(ProviderFaultSpec {
            outage_window: Some((0.0, 1e9)),
            ..ProviderFaultSpec::none()
        });
        let e = m.execute(&tasks, &reg).unwrap_err();
        assert!(e.retryable(), "control-plane outage is provider-local: {e}");
        // The slice failed before Submitted: every task is re-brokerable.
        for (id, _) in &tasks {
            assert_eq!(reg.state_of(*id), Some(TaskState::Partitioned));
        }
    }

    #[test]
    fn ack_verification_flags_mismatches() {
        let pod = |id: u64| PodSpec { id, containers: Vec::new() };
        let pods = [pod(0), pod(1), pod(2)];
        // A faithful ack passes.
        let good = r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":0,"last_id":2}"#;
        assert!(verify_ack(good, &pods).is_ok());
        // Count, first-id and last-id disagreements are each terminal.
        for bad in [
            r#"{"ack":"hydra/v1","count":2,"bytes":10,"first_id":0,"last_id":2}"#,
            r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":7,"last_id":2}"#,
            r#"{"ack":"hydra/v1","count":3,"bytes":10,"first_id":0,"last_id":null}"#,
        ] {
            let e = verify_ack(bad, &pods).unwrap_err();
            assert!(matches!(e, ManagerError::AckMismatch { .. }), "{bad}");
            assert!(!e.retryable(), "ack mismatch must never be re-brokered");
        }
        // Empty workload: count 0, no id spot-checks.
        assert!(verify_ack(r#"{"ack":"hydra/v1","count":0,"bytes":2,"first_id":null,"last_id":null}"#, &[]).is_ok());
    }

    #[test]
    fn ovh_grows_with_task_count() {
        // The Fig 2 (top) shape: OVH dominated by #tasks/#pods. Compare
        // 1K vs 8K tasks — wall time should grow clearly (not necessarily
        // 8x, but well beyond noise). Best-of-3 to shed scheduler hiccups.
        let m = manager(PartitionModel::Scpp);
        let best = |n: usize| {
            (0..3)
                .map(|_| {
                    let reg = TaskRegistry::new();
                    let t = workload(&reg, n);
                    m.execute(&t, &reg).unwrap().metrics.ovh.total_s()
                })
                .fold(f64::INFINITY, f64::min)
        };
        let o1 = best(1000);
        let o2 = best(8000);
        assert!(o2 > o1 * 3.0, "o1={o1} o2={o2}");
    }
}
