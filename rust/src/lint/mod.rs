//! `hydra-lint` — a determinism-invariant static analyzer for this crate.
//!
//! Every result in the reproduction hangs on deterministic replay: the
//! heap-vs-calendar queue identity suites, the `FaultSpec::none()` /
//! `ProviderFaultSpec::none()` byte-identity guarantees, and the
//! exactly-once ledgers. Those are enforced by *tests*, but tests cannot
//! see the hazards that have not happened yet — a `HashMap` iteration
//! whose order leaks into a trace, a wall-clock read in a sim path, an
//! unsalted PRNG stream that entangles two supposedly independent fault
//! injectors. `hydra-lint` encodes those invariants as five source-level
//! rules and gates CI on them, dylint-style but with zero new
//! dependencies (the scanner is ~600 lines over `std`).
//!
//! # Rules
//!
//! | id          | what it flags                                                      |
//! |-------------|--------------------------------------------------------------------|
//! | `wallclock` | `Instant::now` / `SystemTime` in library code                      |
//! | `hash-order`| `HashMap`/`HashSet` iteration in `sim/`, `broker/`, `workflow/`, `facts/` |
//! | `prng-salt` | unsalted `Prng::new` outside `util/prng.rs`; duplicate stream salts |
//! | `unwrap`    | `.unwrap()` / `.expect(` / `panic!` in non-test library code       |
//! | `float-eq`  | `==`/`!=` against an `f64` literal (compare `.to_bits()` instead)  |
//!
//! A sixth internal rule, `pragma`, fires on malformed suppression
//! pragmas and can itself never be suppressed.
//!
//! # Suppression
//!
//! A violation is suppressed by a scoped pragma in a plain `//` comment
//! (doc comments are never pragmas, which is how this paragraph can
//! quote the syntax):
//!
//! ```text
//! // hydra-lint: allow(wallclock) — Stopwatch is the wall-clock boundary
//! ```
//!
//! A trailing pragma covers its own line; a pragma on a line of its own
//! covers exactly the next line. The reason text is mandatory, and an
//! unknown rule id or missing reason is a `pragma` violation — a typo
//! cannot silently widen the allowance.
//!
//! # The ratchet
//!
//! Pre-existing debt (319 `unwrap` sites at introduction time) is carried
//! in `ci/lint_baseline.json` as per-rule per-file counts under the
//! `hydra-lint-baseline/v1` schema. The gate compares current counts
//! against the baseline: a count above baseline fails with file:line
//! diagnostics, a count below baseline passes with a warning to run
//! `cargo run --release --bin hydra_lint -- --refresh`, which rewrites
//! the baseline from the current tree so the ceiling only ever moves
//! down. The binary also writes a machine-readable
//! `hydra-lint-report/v1` JSON next to the other CI artifacts.

pub mod scan;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use self::scan::{Rule, SaltDef, Violation};
use crate::util::json::{self, Json};

/// Schema tag of `ci/lint_baseline.json`.
pub const BASELINE_SCHEMA: &str = "hydra-lint-baseline/v1";
/// Schema tag of the JSON report the binary writes.
pub const REPORT_SCHEMA: &str = "hydra-lint-report/v1";

/// Violation counts keyed by rule id, then crate-relative file path.
pub type Counts = BTreeMap<String, BTreeMap<String, usize>>;

/// The aggregate of scanning every `src/**/*.rs` file under a crate root.
#[derive(Debug)]
pub struct TreeScan {
    pub files_scanned: usize,
    /// All violations, sorted by (file, line, rule) — includes the
    /// crate-wide duplicate-salt findings.
    pub violations: Vec<Violation>,
}

/// Scan the crate rooted at `root` (the directory holding `src/`).
pub fn scan_tree(root: &Path) -> Result<TreeScan, String> {
    let mut files = Vec::new();
    walk_sorted(&root.join("src"), &mut files)?;
    let mut violations = Vec::new();
    let mut salts: Vec<SaltDef> = Vec::new();
    for path in &files {
        let rel = rel_path(root, path)?;
        let text =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let one = scan::scan_source(&rel, &text);
        violations.extend(one.violations);
        salts.extend(one.salts);
    }
    violations.extend(salt_violations(&salts));
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(TreeScan { files_scanned: files.len(), violations })
}

/// Depth-first walk in sorted order, collecting `.rs` files — sorted so
/// diagnostics, counts and the baseline serialize identically on every
/// platform.
fn walk_sorted(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        entries.push(ent.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_sorted(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Crate-root-relative path with `/` separators regardless of platform.
fn rel_path(root: &Path, path: &Path) -> Result<String, String> {
    let rel = path
        .strip_prefix(root)
        .map_err(|_| format!("{} is outside the crate root", path.display()))?;
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    Ok(parts.join("/"))
}

/// Cross-file salt-uniqueness check: every PRNG stream salt value must
/// be unique crate-wide, or two "independent" streams collapse into one.
/// A def whose site carries a `prng-salt` pragma is exempt.
pub fn salt_violations(salts: &[SaltDef]) -> Vec<Violation> {
    let mut by_value: BTreeMap<u64, Vec<&SaltDef>> = BTreeMap::new();
    for s in salts {
        by_value.entry(s.value).or_default().push(s);
    }
    let mut out = Vec::new();
    for (value, defs) in &by_value {
        if defs.len() < 2 {
            continue;
        }
        for d in defs {
            if d.allowed {
                continue;
            }
            let others: Vec<String> = defs
                .iter()
                .filter(|o| !(o.file == d.file && o.line == d.line))
                .map(|o| format!("{} ({}:{})", o.name, o.file, o.line))
                .collect();
            out.push(Violation {
                rule: Rule::PrngSalt,
                file: d.file.clone(),
                line: d.line,
                message: format!(
                    "PRNG stream salt {value:#x} ({}) is also used by {}; salts must be \
                     unique crate-wide",
                    d.name,
                    others.join(", ")
                ),
            });
        }
    }
    out
}

/// Fold violations into per-rule per-file counts. Every rule id appears
/// in the output (possibly with no files) so the baseline documents the
/// full rule set.
pub fn counts_of(violations: &[Violation]) -> Counts {
    let mut counts: Counts = BTreeMap::new();
    for r in Rule::ALL {
        counts.entry(r.id().to_string()).or_default();
    }
    for v in violations {
        *counts
            .entry(v.rule.id().to_string())
            .or_default()
            .entry(v.file.clone())
            .or_insert(0) += 1;
    }
    counts
}

/// Serialize counts as a `hydra-lint-baseline/v1` document.
pub fn baseline_json(counts: &Counts) -> Json {
    let mut rules = Json::obj();
    for (rule, files) in counts {
        let mut by_file = Json::obj();
        for (file, n) in files {
            by_file = by_file.set(file, *n);
        }
        rules = rules.set(rule, by_file);
    }
    Json::obj().set("schema", BASELINE_SCHEMA).set("counts", rules)
}

/// Parse a `hydra-lint-baseline/v1` document.
pub fn parse_baseline(text: &str) -> Result<Counts, String> {
    let doc = json::parse(text).map_err(|e| format!("baseline: {e}"))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == BASELINE_SCHEMA => {}
        other => return Err(format!("baseline: expected schema {BASELINE_SCHEMA}, got {other:?}")),
    }
    let Some(Json::Obj(rules)) = doc.get("counts") else {
        return Err("baseline: missing `counts` object".to_string());
    };
    let mut out: Counts = BTreeMap::new();
    for (rule, files) in rules {
        let Json::Obj(files) = files else {
            return Err(format!("baseline: counts.{rule} is not an object"));
        };
        let entry = out.entry(rule.clone()).or_default();
        for (file, n) in files {
            let Some(n) = n.as_usize() else {
                return Err(format!("baseline: counts.{rule}.\"{file}\" is not a count"));
            };
            entry.insert(file.clone(), n);
        }
    }
    Ok(out)
}

/// Outcome of ratcheting current counts against the baseline.
#[derive(Debug, Default)]
pub struct Gate {
    /// (rule, file) pairs above their baseline ceiling — failures.
    pub regressions: Vec<String>,
    /// Pairs below baseline — passes, with a nudge to `--refresh` so the
    /// ceiling ratchets down.
    pub tighten: Vec<String>,
}

impl Gate {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// The ratchet: counts may only go down. Missing entries count as zero
/// on both sides, so a violation in a brand-new file regresses.
pub fn gate(cur: &Counts, base: &Counts) -> Gate {
    let mut keys: BTreeSet<(&str, &str)> = BTreeSet::new();
    for (rule, files) in cur.iter().chain(base.iter()) {
        for file in files.keys() {
            keys.insert((rule, file));
        }
    }
    let mut out = Gate::default();
    for (rule, file) in keys {
        let c = cur.get(rule).and_then(|m| m.get(file)).copied().unwrap_or(0);
        let b = base.get(rule).and_then(|m| m.get(file)).copied().unwrap_or(0);
        if c > b {
            out.regressions
                .push(format!("{rule}: {file}: {c} violation(s), baseline allows {b}"));
        } else if c < b {
            out.tighten.push(format!(
                "{rule}: {file}: {c} violation(s) < baseline {b} — run \
                 `hydra_lint --refresh` to ratchet the ceiling down"
            ));
        }
    }
    out
}

/// The violations behind each regressed (rule, file) pair, for file:line
/// diagnostics. The baseline stores counts, not sites, so when a pair
/// regresses every current site of that rule in that file is listed.
pub fn regressed_sites<'a>(
    tree: &'a TreeScan,
    cur: &Counts,
    base: &Counts,
) -> Vec<&'a Violation> {
    let mut pairs: BTreeSet<(&str, &str)> = BTreeSet::new();
    for (rule, files) in cur {
        for (file, c) in files {
            let b = base.get(rule).and_then(|m| m.get(file)).copied().unwrap_or(0);
            if *c > b {
                pairs.insert((rule, file));
            }
        }
    }
    tree.violations.iter().filter(|v| pairs.contains(&(v.rule.id(), v.file.as_str()))).collect()
}

/// Build the machine-readable `hydra-lint-report/v1` document.
pub fn report_json(tree: &TreeScan, cur: &Counts, outcome: &Gate) -> Json {
    let mut totals = Json::obj();
    for (rule, files) in cur {
        totals = totals.set(rule, files.values().sum::<usize>());
    }
    let violations: Vec<Json> = tree
        .violations
        .iter()
        .map(|v| {
            Json::obj()
                .set("rule", v.rule.id())
                .set("file", v.file.as_str())
                .set("line", v.line)
                .set("message", v.message.as_str())
        })
        .collect();
    let strs = |v: &[String]| Json::Arr(v.iter().map(|s| Json::from(s.as_str())).collect());
    Json::obj()
        .set("schema", REPORT_SCHEMA)
        .set("status", if outcome.passed() { "pass" } else { "fail" })
        .set("files_scanned", tree.files_scanned)
        .set("totals", totals)
        .set("regressions", strs(&outcome.regressions))
        .set("tighten", strs(&outcome.tighten))
        .set("violations", violations)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(entries: &[(&str, &str, usize)]) -> Counts {
        let mut c: Counts = BTreeMap::new();
        for (rule, file, n) in entries {
            c.entry(rule.to_string()).or_default().insert(file.to_string(), *n);
        }
        c
    }

    #[test]
    fn ratchet_equal_counts_pass() {
        let cur = counts(&[("unwrap", "src/a.rs", 3), ("float-eq", "src/b.rs", 1)]);
        let g = gate(&cur, &cur.clone());
        assert!(g.passed());
        assert!(g.tighten.is_empty());
    }

    #[test]
    fn ratchet_plus_one_fails_naming_the_pair() {
        let base = counts(&[("unwrap", "src/a.rs", 3)]);
        let cur = counts(&[("unwrap", "src/a.rs", 4)]);
        let g = gate(&cur, &base);
        assert!(!g.passed());
        assert_eq!(g.regressions.len(), 1);
        assert!(g.regressions[0].contains("unwrap"));
        assert!(g.regressions[0].contains("src/a.rs"));
        assert!(g.regressions[0].contains("baseline allows 3"));
    }

    #[test]
    fn ratchet_new_file_fails_even_with_slack_elsewhere() {
        let base = counts(&[("unwrap", "src/a.rs", 10)]);
        let cur = counts(&[("unwrap", "src/a.rs", 1), ("unwrap", "src/new.rs", 1)]);
        let g = gate(&cur, &base);
        assert!(!g.passed(), "per-file ceilings must not be fungible");
        assert!(g.regressions[0].contains("src/new.rs"));
    }

    #[test]
    fn ratchet_minus_one_passes_and_warns_to_refresh() {
        let base = counts(&[("unwrap", "src/a.rs", 3)]);
        let cur = counts(&[("unwrap", "src/a.rs", 2)]);
        let g = gate(&cur, &base);
        assert!(g.passed());
        assert_eq!(g.tighten.len(), 1);
        assert!(g.tighten[0].contains("--refresh"));
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let cur = counts(&[
            ("unwrap", "src/a.rs", 3),
            ("unwrap", "src/b.rs", 1),
            ("wallclock", "src/util/mod.rs", 1),
        ]);
        let text = baseline_json(&cur).to_string_pretty();
        let parsed = parse_baseline(&text).unwrap();
        assert_eq!(parsed, cur);
    }

    #[test]
    fn baseline_rejects_wrong_schema_and_shape() {
        assert!(parse_baseline(r#"{"schema":"other/v1","counts":{}}"#).is_err());
        assert!(parse_baseline(r#"{"schema":"hydra-lint-baseline/v1"}"#).is_err());
        assert!(
            parse_baseline(r#"{"schema":"hydra-lint-baseline/v1","counts":{"unwrap":3}}"#)
                .is_err()
        );
        assert!(parse_baseline("not json").is_err());
    }

    fn salt(name: &str, value: u64, file: &str, line: usize, allowed: bool) -> SaltDef {
        SaltDef { name: name.to_string(), value, file: file.to_string(), line, allowed }
    }

    #[test]
    fn duplicate_salts_flag_every_unallowed_site() {
        let salts = vec![
            salt("A_SALT", 0xAA, "src/sim/a.rs", 10, false),
            salt("B_SALT", 0xAA, "src/sim/b.rs", 20, false),
            salt("C_SALT", 0xCC, "src/sim/c.rs", 30, false),
        ];
        let vs = salt_violations(&salts);
        assert_eq!(vs.len(), 2);
        assert!(vs.iter().all(|v| v.rule == Rule::PrngSalt));
        assert!(vs[0].message.contains("B_SALT"), "{}", vs[0].message);
        assert!(vs[1].message.contains("A_SALT"), "{}", vs[1].message);
    }

    #[test]
    fn pragmaed_salt_duplicate_is_exempt_but_peer_is_not() {
        let salts = vec![
            salt("A_SALT", 0xAA, "src/sim/a.rs", 10, true),
            salt("B_SALT", 0xAA, "src/sim/b.rs", 20, false),
        ];
        let vs = salt_violations(&salts);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].file, "src/sim/b.rs");
    }

    #[test]
    fn counts_fold_per_rule_per_file_and_list_every_rule() {
        let vs = vec![
            Violation {
                rule: Rule::Unwrap,
                file: "src/a.rs".to_string(),
                line: 1,
                message: String::new(),
            },
            Violation {
                rule: Rule::Unwrap,
                file: "src/a.rs".to_string(),
                line: 9,
                message: String::new(),
            },
            Violation {
                rule: Rule::FloatEq,
                file: "src/b.rs".to_string(),
                line: 2,
                message: String::new(),
            },
        ];
        let c = counts_of(&vs);
        assert_eq!(c["unwrap"]["src/a.rs"], 2);
        assert_eq!(c["float-eq"]["src/b.rs"], 1);
        for r in Rule::ALL {
            assert!(c.contains_key(r.id()), "rule {r} missing from counts");
        }
    }

    #[test]
    fn regressed_sites_lists_only_offending_pairs() {
        let tree = TreeScan {
            files_scanned: 2,
            violations: vec![
                Violation {
                    rule: Rule::Unwrap,
                    file: "src/a.rs".to_string(),
                    line: 4,
                    message: String::new(),
                },
                Violation {
                    rule: Rule::Unwrap,
                    file: "src/b.rs".to_string(),
                    line: 7,
                    message: String::new(),
                },
            ],
        };
        let cur = counts(&[("unwrap", "src/a.rs", 1), ("unwrap", "src/b.rs", 1)]);
        let base = counts(&[("unwrap", "src/a.rs", 1)]);
        let sites = regressed_sites(&tree, &cur, &base);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].file, "src/b.rs");
        assert_eq!(sites[0].line, 7);
    }

    #[test]
    fn report_carries_schema_status_and_totals() {
        let tree = TreeScan { files_scanned: 3, violations: Vec::new() };
        let cur = counts(&[("unwrap", "src/a.rs", 2)]);
        let ok = Gate::default();
        let doc = report_json(&tree, &cur, &ok);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(REPORT_SCHEMA));
        assert_eq!(doc.get("status").and_then(Json::as_str), Some("pass"));
        assert_eq!(doc.at(&["totals", "unwrap"]).and_then(Json::as_usize), Some(2));
        let bad =
            Gate { regressions: vec!["unwrap: src/a.rs: ...".to_string()], tighten: Vec::new() };
        let doc = report_json(&tree, &cur, &bad);
        assert_eq!(doc.get("status").and_then(Json::as_str), Some("fail"));
    }

    /// The tree self-check: the committed baseline must admit the tree as
    /// it stands. This is what makes `cargo test` catch a lint regression
    /// even before the dedicated CI step runs.
    #[test]
    fn lint_tree_is_clean_under_committed_baseline() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let tree = scan_tree(root).unwrap();
        assert!(tree.files_scanned > 20, "walk found only {} files", tree.files_scanned);
        let text = fs::read_to_string(root.join("ci/lint_baseline.json")).unwrap();
        let base = parse_baseline(&text).unwrap();
        let g = gate(&counts_of(&tree.violations), &base);
        assert!(
            g.passed(),
            "lint regressions vs ci/lint_baseline.json:\n{}",
            g.regressions.join("\n")
        );
    }
}
