//! The `hydra-lint` source scanner: a comment- and string-literal-aware
//! line/token pass over one Rust source file.
//!
//! This is deliberately *not* a Rust parser. The five determinism rules
//! (see [`crate::lint`] module docs) are all expressible as token
//! patterns once three classes of noise are removed:
//!
//! 1. **Comments and string/char literals** are blanked out (replaced by
//!    spaces, structure preserved) by a small state machine that
//!    understands line comments, nested block comments, string escapes,
//!    raw strings (`r"…"`, `r#"…"#`), byte strings, char literals
//!    (including `'\u{8}'` and `b','`) and lifetimes (`'a`), so a
//!    `".unwrap()"` inside a test fixture string never counts.
//! 2. **`#[cfg(test)]` regions** are excluded entirely: the attribute
//!    arms a flag and the next `{` opens a region tracked by brace depth
//!    on the blanked text. Library rules do not apply to test code.
//! 3. **Suppression pragmas** are read from plain `//` comments (doc
//!    comments are never pragmas, so documentation can quote the
//!    syntax). A trailing pragma suppresses its own line; a pragma on a
//!    line of its own suppresses the next line. Every pragma must name
//!    a rule and carry a reason; a malformed one is itself a violation
//!    ([`Rule::Pragma`]) so a typo cannot silently disable a rule.
//!
//! The scanner is intentionally simple enough to re-derive: the ratchet
//! baseline committed in `ci/lint_baseline.json` must stay reproducible
//! from `cargo run --release --bin hydra_lint -- --refresh` alone.

use std::fmt;

/// Path prefixes (relative to the crate root) where determinism is a
/// hard invariant: these modules feed the byte-identity equivalence
/// suites, so the hash-order rule applies to them.
pub const DETERMINISTIC_DIRS: [&str; 4] =
    ["src/sim/", "src/broker/", "src/workflow/", "src/facts/"];

/// The PRNG module itself is the one legitimate home of unsalted
/// `Prng::new` (seeding, forking).
const PRNG_MODULE: &str = "src/util/prng.rs";

/// A lint rule enforced by `hydra-lint`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    /// `Instant::now` / `SystemTime` in library code.
    Wallclock,
    /// Iteration over `HashMap`/`HashSet` in deterministic paths.
    HashOrder,
    /// Unsalted `Prng::new` streams / duplicate stream salts.
    PrngSalt,
    /// `.unwrap()` / `.expect(` / `panic!` in library code.
    Unwrap,
    /// `f64` comparison against a float literal with `==` / `!=`.
    FloatEq,
    /// A malformed `hydra-lint:` pragma (never suppressible).
    Pragma,
}

impl Rule {
    /// Every rule, in baseline/report order.
    pub const ALL: [Rule; 6] = [
        Rule::Wallclock,
        Rule::HashOrder,
        Rule::PrngSalt,
        Rule::Unwrap,
        Rule::FloatEq,
        Rule::Pragma,
    ];

    /// Stable identifier used in pragmas, the baseline, and reports.
    pub fn id(self) -> &'static str {
        match self {
            Rule::Wallclock => "wallclock",
            Rule::HashOrder => "hash-order",
            Rule::PrngSalt => "prng-salt",
            Rule::Unwrap => "unwrap",
            Rule::FloatEq => "float-eq",
            Rule::Pragma => "pragma",
        }
    }

    /// Parse a pragma rule id. Only the five suppressible rules resolve:
    /// a malformed-pragma violation cannot be pragma'd away.
    pub fn from_id(id: &str) -> Option<Rule> {
        match id {
            "wallclock" => Some(Rule::Wallclock),
            "hash-order" => Some(Rule::HashOrder),
            "prng-salt" => Some(Rule::PrngSalt),
            "unwrap" => Some(Rule::Unwrap),
            "float-eq" => Some(Rule::FloatEq),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One finding: rule, crate-relative file, 1-based line, and a message
/// that says what to do about it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// A PRNG stream-salt definition (named constant or inline literal),
/// collected per file and checked for crate-wide uniqueness by the
/// driver in [`crate::lint`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SaltDef {
    /// Constant name, or `<inline>` for a literal inside `Prng::new`.
    pub name: String,
    pub value: u64,
    pub file: String,
    pub line: usize,
    /// True when a `prng-salt` pragma covers the definition line.
    pub allowed: bool,
}

/// Result of scanning one file.
#[derive(Debug, Default)]
pub struct FileScan {
    pub violations: Vec<Violation>,
    pub salts: Vec<SaltDef>,
}

// ---------------------------------------------------------------------------
// Stripping: comments, strings, chars, test regions, pragmas
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct PragmaInfo {
    rules: Vec<Rule>,
    malformed: Option<String>,
}

#[derive(Debug)]
struct Stripped {
    /// Source lines with comments and string/char-literal contents
    /// replaced by spaces (newlines preserved).
    lines: Vec<String>,
    /// Per line: inside a `#[cfg(test)]` region.
    test: Vec<bool>,
    /// Per line: the `hydra-lint:` pragma found in a plain `//` comment.
    pragmas: Vec<Option<PragmaInfo>>,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Parse the text after `hydra-lint:` into suppressed rules, or a
/// malformed-pragma description.
fn parse_pragma(text: &str) -> PragmaInfo {
    let body = text.trim();
    let Some(rest) = body.strip_prefix("allow(") else {
        return PragmaInfo {
            rules: Vec::new(),
            malformed: Some("expected `allow(<rule>) — <reason>`".to_string()),
        };
    };
    let Some(close) = rest.find(')') else {
        return PragmaInfo { rules: Vec::new(), malformed: Some("unclosed allow(".to_string()) };
    };
    let mut rules = Vec::new();
    for raw in rest[..close].split(',') {
        let id = raw.trim();
        match Rule::from_id(id) {
            Some(r) => rules.push(r),
            None => {
                return PragmaInfo {
                    rules: Vec::new(),
                    malformed: Some(format!("unknown rule `{id}`")),
                };
            }
        }
    }
    if rules.is_empty() {
        return PragmaInfo { rules, malformed: Some("empty rule list".to_string()) };
    }
    let reason = rest[close + 1..]
        .trim_start()
        .trim_start_matches(['—', '–', '-', ':'])
        .trim();
    if reason.is_empty() {
        return PragmaInfo { rules: Vec::new(), malformed: Some("missing reason".to_string()) };
    }
    PragmaInfo { rules, malformed: None }
}

/// Blank comments and literals, collect pragmas, then mark test regions.
fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<String> = Vec::new();
    let mut pragmas: Vec<Option<PragmaInfo>> = Vec::new();
    let mut cur = String::new();
    let mut cur_pragma: Option<PragmaInfo> = None;

    // Modes: 0 = code, 1 = line comment, 2 = block comment, 3 = string,
    // 4 = raw string.
    let mut mode = 0u8;
    let mut block_depth = 0u32;
    let mut raw_hashes = 0usize;
    let mut comment_buf = String::new();
    let mut comment_is_doc = false;

    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if mode == 1 {
                finalize_comment(&comment_buf, comment_is_doc, &mut cur_pragma);
                comment_buf.clear();
                mode = 0;
            }
            lines.push(std::mem::take(&mut cur));
            pragmas.push(cur_pragma.take());
            i += 1;
            continue;
        }
        match mode {
            1 => {
                comment_buf.push(c);
                cur.push(' ');
                i += 1;
            }
            2 => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    block_depth -= 1;
                    cur.push_str("  ");
                    i += 2;
                    if block_depth == 0 {
                        mode = 0;
                    }
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    block_depth += 1;
                    cur.push_str("  ");
                    i += 2;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            3 => {
                if c == '\\' && i + 1 < n {
                    cur.push(' ');
                    if chars[i + 1] != '\n' {
                        cur.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    cur.push(' ');
                    mode = 0;
                    i += 1;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            4 => {
                if c == '"' && raw_close_len(&chars, i, raw_hashes) {
                    for _ in 0..=raw_hashes {
                        cur.push(' ');
                    }
                    i += 1 + raw_hashes;
                    mode = 0;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            _ => {
                // code
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    mode = 1;
                    comment_is_doc = i + 2 < n && (chars[i + 2] == '/' || chars[i + 2] == '!');
                    comment_buf.clear();
                    cur.push_str("  ");
                    i += 2;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    mode = 2;
                    block_depth = 1;
                    cur.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    mode = 3;
                    cur.push(' ');
                    i += 1;
                } else if let Some(h) = raw_string_open(&chars, i) {
                    // r"…", r#"…"#, br"…", b"…" — blank the prefix +
                    // opening quote, enter the right string mode.
                    let (prefix_len, hashes, is_raw) = h;
                    for _ in 0..prefix_len {
                        cur.push(' ');
                    }
                    i += prefix_len;
                    if is_raw {
                        mode = 4;
                        raw_hashes = hashes;
                    } else {
                        mode = 3;
                    }
                } else if c == '\'' {
                    match char_literal_len(&chars, i) {
                        Some(len) => {
                            for _ in 0..len {
                                cur.push(' ');
                            }
                            i += len;
                        }
                        None => {
                            // a lifetime: keep the tick as code
                            cur.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    cur.push(c);
                    i += 1;
                }
            }
        }
    }
    if mode == 1 {
        finalize_comment(&comment_buf, comment_is_doc, &mut cur_pragma);
    }
    lines.push(cur);
    pragmas.push(cur_pragma.take());

    let test = mark_test_regions(&lines);
    Stripped { lines, test, pragmas }
}

fn finalize_comment(buf: &str, is_doc: bool, slot: &mut Option<PragmaInfo>) {
    if is_doc {
        return;
    }
    if let Some(rest) = buf.trim_start().strip_prefix("hydra-lint:") {
        *slot = Some(parse_pragma(rest));
    }
}

/// At `chars[i] == '"'` inside a raw string with `hashes` hashes: does a
/// closing delimiter start here?
fn raw_close_len(chars: &[char], i: usize, hashes: usize) -> bool {
    if i + hashes >= chars.len() {
        return false;
    }
    (1..=hashes).all(|k| chars[i + k] == '#')
}

/// Detect a raw/byte string opener at `chars[i]`. Returns
/// `(prefix_len_including_quote, hashes, is_raw)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize, bool)> {
    if i > 0 && is_ident(chars[i - 1]) {
        return None;
    }
    let n = chars.len();
    let c = chars[i];
    // b"…"
    if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
        return Some((2, 0, false));
    }
    // r…  or  br…
    let r_at = if c == 'r' {
        Some(i)
    } else if c == 'b' && i + 1 < n && chars[i + 1] == 'r' {
        Some(i + 1)
    } else {
        None
    };
    let r = r_at?;
    let mut j = r + 1;
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && chars[j] == '"' {
        return Some((j + 1 - i, hashes, true));
    }
    None
}

/// At `chars[i] == '\''`: length of a char literal starting here, or
/// `None` when the tick opens a lifetime.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    let n = chars.len();
    let mut k = i + 1;
    if k >= n {
        return None;
    }
    if chars[k] == '\\' {
        k += 1;
        if k >= n {
            return None;
        }
        if chars[k] == 'u' {
            k += 1;
            if k < n && chars[k] == '{' {
                while k < n && chars[k] != '}' {
                    k += 1;
                }
                k += 1;
            }
        } else {
            k += 1;
        }
    } else if chars[k] == '\'' {
        return None;
    } else {
        k += 1;
    }
    if k < n && chars[k] == '\'' {
        Some(k + 1 - i)
    } else {
        None
    }
}

/// Mark lines inside `#[cfg(test)] mod … { … }` regions. The attribute
/// arms a flag; the next `{` (on the blanked text) opens the region,
/// and the matching `}` closes it.
fn mark_test_regions(lines: &[String]) -> Vec<bool> {
    let mut test = vec![false; lines.len()];
    let mut depth = 0i64;
    let mut pending = false;
    let mut in_test = false;
    let mut open_depth = 0i64;
    for (li, line) in lines.iter().enumerate() {
        if !in_test && line.contains("#[cfg(test)]") {
            pending = true;
        }
        let mut line_test = in_test;
        for c in line.chars() {
            if c == '{' {
                depth += 1;
                if pending && !in_test {
                    in_test = true;
                    pending = false;
                    open_depth = depth;
                    line_test = true;
                }
            } else if c == '}' {
                if in_test && depth == open_depth {
                    in_test = false;
                }
                depth -= 1;
            }
        }
        test[li] = line_test;
    }
    test
}

// ---------------------------------------------------------------------------
// Shared token helpers
// ---------------------------------------------------------------------------

fn occurrences(line: &str, needle: &str) -> Vec<usize> {
    let mut v = Vec::new();
    let mut from = 0usize;
    while from < line.len() {
        match line[from..].find(needle) {
            Some(p) => {
                v.push(from + p);
                from += p + needle.len();
            }
            None => break,
        }
    }
    v
}

/// Token boundary checks on the blanked (ASCII) line.
fn bounded(line: &str, pos: usize, len: usize, check_start: bool, check_end: bool) -> bool {
    let b = line.as_bytes();
    if check_start && pos > 0 && is_ident(b[pos - 1] as char) {
        return false;
    }
    if check_end && pos + len < b.len() && is_ident(b[pos + len] as char) {
        return false;
    }
    true
}

fn suppressed(s: &Stripped, li: usize, rule: Rule) -> bool {
    if let Some(Some(p)) = s.pragmas.get(li) {
        if p.malformed.is_none() && p.rules.contains(&rule) {
            return true;
        }
    }
    if li > 0 {
        if let Some(Some(p)) = s.pragmas.get(li - 1) {
            let standalone = s.lines[li - 1].trim().is_empty();
            if standalone && p.malformed.is_none() && p.rules.contains(&rule) {
                return true;
            }
        }
    }
    false
}

/// Read the identifier ending just before byte `end` (exclusive),
/// skipping whitespace backwards first — across line boundaries, so
/// multiline method chains resolve their receiver.
fn ident_ending_before(lines: &[String], mut li: usize, mut end: usize) -> Option<String> {
    loop {
        let b = lines[li].as_bytes();
        while end > 0 && (b[end - 1] as char).is_ascii_whitespace() {
            end -= 1;
        }
        if end > 0 {
            let mut start = end;
            while start > 0 && is_ident(b[start - 1] as char) {
                start -= 1;
            }
            if start == end {
                return None;
            }
            return Some(lines[li][start..end].to_string());
        }
        if li == 0 {
            return None;
        }
        li -= 1;
        end = lines[li].len();
    }
}

/// Parse an integer literal (`0x…` hex or decimal, `_` separators).
fn parse_int_literal(text: &str) -> Option<u64> {
    let t = text.trim();
    if let Some(hex) = t.strip_prefix("0x") {
        let digits: String = hex
            .chars()
            .take_while(|c| c.is_ascii_hexdigit() || *c == '_')
            .filter(|c| *c != '_')
            .collect();
        if digits.is_empty() {
            return None;
        }
        return u64::from_str_radix(&digits, 16).ok();
    }
    let digits: String = t
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '_')
        .filter(|c| *c != '_')
        .collect();
    if digits.is_empty() {
        return None;
    }
    digits.parse().ok()
}

// ---------------------------------------------------------------------------
// The rules
// ---------------------------------------------------------------------------

fn in_deterministic_dir(rel: &str) -> bool {
    DETERMINISTIC_DIRS.iter().any(|d| rel.starts_with(d))
}

/// Scan one file. `rel_path` is crate-root-relative with `/` separators
/// (e.g. `src/broker/state.rs`) — it selects per-directory rule scope.
pub fn scan_source(rel_path: &str, src: &str) -> FileScan {
    let s = strip(src);
    let mut out = FileScan::default();

    for (li, p) in s.pragmas.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        if let Some(info) = p {
            if let Some(why) = &info.malformed {
                out.violations.push(Violation {
                    rule: Rule::Pragma,
                    file: rel_path.to_string(),
                    line: li + 1,
                    message: format!("malformed hydra-lint pragma: {why}"),
                });
            }
        }
    }

    rule_wallclock(rel_path, &s, &mut out.violations);
    rule_unwrap(rel_path, &s, &mut out.violations);
    rule_float_eq(rel_path, &s, &mut out.violations);
    if in_deterministic_dir(rel_path) {
        rule_hash_order(rel_path, &s, &mut out.violations);
    }
    if rel_path != PRNG_MODULE {
        rule_prng_salt(rel_path, &s, &mut out);
    }

    out.violations.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

fn rule_wallclock(rel: &str, s: &Stripped, out: &mut Vec<Violation>) {
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        for (needle, end_bound) in [("Instant::now", false), ("SystemTime", true)] {
            for pos in occurrences(line, needle) {
                if !bounded(line, pos, needle.len(), true, end_bound) {
                    continue;
                }
                if suppressed(s, li, Rule::Wallclock) {
                    continue;
                }
                let hint = if in_deterministic_dir(rel) {
                    "deterministic paths must derive time from the simulated clock"
                } else {
                    "wall-clock reads belong behind the Stopwatch boundary"
                };
                out.push(Violation {
                    rule: Rule::Wallclock,
                    file: rel.to_string(),
                    line: li + 1,
                    message: format!("`{needle}` in library code; {hint}"),
                });
            }
        }
    }
}

fn rule_unwrap(rel: &str, s: &Stripped, out: &mut Vec<Violation>) {
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        for (needle, start_bound) in [(".unwrap()", false), (".expect(", false), ("panic!", true)]
        {
            for pos in occurrences(line, needle) {
                if !bounded(line, pos, needle.len(), start_bound, false) {
                    continue;
                }
                if suppressed(s, li, Rule::Unwrap) {
                    continue;
                }
                out.push(Violation {
                    rule: Rule::Unwrap,
                    file: rel.to_string(),
                    line: li + 1,
                    message: format!(
                        "`{}` in library code; return an error instead of panicking",
                        needle.trim_end_matches('(')
                    ),
                });
            }
        }
    }
}

fn float_literal(tok: &str) -> bool {
    let t = tok.trim_end_matches("f64").trim_end_matches("f32");
    if t.is_empty() || !t.as_bytes()[0].is_ascii_digit() {
        return false;
    }
    if t.starts_with("0x") || t.starts_with("0b") || t.starts_with("0o") {
        return false;
    }
    t.contains('.') || t.contains('e') || t.contains('E')
}

fn token_after(line: &str, mut pos: usize) -> String {
    let b = line.as_bytes();
    while pos < b.len() && (b[pos] as char).is_ascii_whitespace() {
        pos += 1;
    }
    if pos < b.len() && b[pos] == b'-' {
        pos += 1;
    }
    let start = pos;
    while pos < b.len() && (is_ident(b[pos] as char) || b[pos] == b'.') {
        pos += 1;
    }
    line[start..pos].to_string()
}

fn token_before(line: &str, mut pos: usize) -> String {
    let b = line.as_bytes();
    while pos > 0 && (b[pos - 1] as char).is_ascii_whitespace() {
        pos -= 1;
    }
    let end = pos;
    while pos > 0 && (is_ident(b[pos - 1] as char) || b[pos - 1] == b'.') {
        pos -= 1;
    }
    line[pos..end].to_string()
}

fn rule_float_eq(rel: &str, s: &Stripped, out: &mut Vec<Violation>) {
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        let b = line.as_bytes();
        for op in ["==", "!="] {
            for pos in occurrences(line, op) {
                if op == "==" {
                    if pos > 0 && matches!(b[pos - 1], b'=' | b'!' | b'<' | b'>') {
                        continue;
                    }
                    if pos + 2 < b.len() && b[pos + 2] == b'=' {
                        continue;
                    }
                } else if pos + 2 < b.len() && b[pos + 2] == b'=' {
                    continue;
                }
                let hit = float_literal(&token_after(line, pos + 2))
                    || float_literal(&token_before(line, pos));
                if !hit || suppressed(s, li, Rule::FloatEq) {
                    continue;
                }
                out.push(Violation {
                    rule: Rule::FloatEq,
                    file: rel.to_string(),
                    line: li + 1,
                    message: "f64 `==`/`!=` against a float literal; byte-identity checks \
                              compare `.to_bits()`"
                        .to_string(),
                });
            }
        }
    }
}

const ITER_NEEDLES: [&str; 9] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
];

/// Collect names bound to `HashMap`/`HashSet` in this file: struct
/// fields and typed bindings (`name: HashMap<…>`, `&[mut] HashMap`) and
/// `name = HashMap::new()`-style initializers. File-scoped by design —
/// a collision with an unrelated local is resolved by renaming or a
/// pragma, both of which improve the code.
fn hash_bound_names(s: &Stripped) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        for needle in ["HashMap", "HashSet"] {
            for pos in occurrences(line, needle) {
                if !bounded(line, pos, needle.len(), true, true) {
                    continue;
                }
                if let Some(name) = binding_name_before(line, pos) {
                    if !names.contains(&name) {
                        names.push(name);
                    }
                }
            }
        }
    }
    names
}

/// Walk left from a `HashMap`/`HashSet` token over `path::` segments and
/// `&`/`mut`, then read the bound name behind `:` or `=`.
fn binding_name_before(line: &str, type_pos: usize) -> Option<String> {
    let b = line.as_bytes();
    let mut i = type_pos;
    // path segments: `std::collections::HashMap`
    while i >= 2 && b[i - 1] == b':' && b[i - 2] == b':' {
        i -= 2;
        while i > 0 && is_ident(b[i - 1] as char) {
            i -= 1;
        }
    }
    while i > 0 && (b[i - 1] as char).is_ascii_whitespace() {
        i -= 1;
    }
    // reference carriers: `&HashMap`, `&mut HashMap`
    loop {
        if i > 0 && b[i - 1] == b'&' {
            i -= 1;
            continue;
        }
        if i >= 3 && &line[i - 3..i] == "mut" && (i == 3 || !is_ident(b[i - 4] as char)) {
            i -= 3;
            while i > 0 && (b[i - 1] as char).is_ascii_whitespace() {
                i -= 1;
            }
            continue;
        }
        break;
    }
    while i > 0 && (b[i - 1] as char).is_ascii_whitespace() {
        i -= 1;
    }
    if i == 0 {
        return None;
    }
    if b[i - 1] == b':' && (i < 2 || b[i - 2] != b':') {
        // `name: HashMap<…>`
        i -= 1;
        while i > 0 && (b[i - 1] as char).is_ascii_whitespace() {
            i -= 1;
        }
        return read_ident_back(line, i);
    }
    if b[i - 1] == b'=' && (i < 2 || !matches!(b[i - 2], b'=' | b'!' | b'<' | b'>')) {
        // `let [mut] name = HashMap::new()`
        i -= 1;
        while i > 0 && (b[i - 1] as char).is_ascii_whitespace() {
            i -= 1;
        }
        return read_ident_back(line, i);
    }
    None
}

fn read_ident_back(line: &str, end: usize) -> Option<String> {
    let b = line.as_bytes();
    let mut start = end;
    while start > 0 && is_ident(b[start - 1] as char) {
        start -= 1;
    }
    if start == end {
        return None;
    }
    Some(line[start..end].to_string())
}

fn rule_hash_order(rel: &str, s: &Stripped, out: &mut Vec<Violation>) {
    let names = hash_bound_names(s);
    if names.is_empty() {
        return;
    }
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        for needle in ITER_NEEDLES {
            for pos in occurrences(line, needle) {
                let recv = match ident_ending_before(&s.lines, li, pos) {
                    Some(r) => r,
                    None => continue,
                };
                if !names.contains(&recv) || suppressed(s, li, Rule::HashOrder) {
                    continue;
                }
                out.push(Violation {
                    rule: Rule::HashOrder,
                    file: rel.to_string(),
                    line: li + 1,
                    message: format!(
                        "iteration over Hash{{Map,Set}} `{recv}` is nondeterministically \
                         ordered; sort the keys or switch to BTreeMap/BTreeSet"
                    ),
                });
            }
        }
        // `for x in [&]name` headers
        if let Some(fp) = line.find("for ") {
            if bounded(line, fp, 3, true, false) {
                if let Some(ip) = line[fp..].find(" in ") {
                    let tok = for_in_target(line, fp + ip + 4);
                    if let Some(last) = tok.rsplit('.').next() {
                        if names.iter().any(|n| n == last)
                            && !suppressed(s, li, Rule::HashOrder)
                        {
                            out.push(Violation {
                                rule: Rule::HashOrder,
                                file: rel.to_string(),
                                line: li + 1,
                                message: format!(
                                    "iteration over Hash{{Map,Set}} `{last}` is \
                                     nondeterministically ordered; sort the keys or switch \
                                     to BTreeMap/BTreeSet"
                                ),
                            });
                        }
                    }
                }
            }
        }
    }
}

fn for_in_target(line: &str, mut pos: usize) -> String {
    let b = line.as_bytes();
    while pos < b.len() && (b[pos] as char).is_ascii_whitespace() {
        pos += 1;
    }
    while pos < b.len() && b[pos] == b'&' {
        pos += 1;
    }
    if line[pos..].starts_with("mut ") {
        pos += 4;
    }
    let start = pos;
    while pos < b.len() && (is_ident(b[pos] as char) || b[pos] == b'.') {
        pos += 1;
    }
    line[start..pos].to_string()
}

fn rule_prng_salt(rel: &str, s: &Stripped, out: &mut FileScan) {
    for (li, line) in s.lines.iter().enumerate() {
        if s.test[li] {
            continue;
        }
        // Named salt constants: `const NAME_SALT: u64 = 0x…;`
        for pos in occurrences(line, "const ") {
            if !bounded(line, pos, 5, true, false) {
                continue;
            }
            let b = line.as_bytes();
            let mut j = pos + 6;
            while j < b.len() && (b[j] as char).is_ascii_whitespace() {
                j += 1;
            }
            let start = j;
            while j < b.len() && is_ident(b[j] as char) {
                j += 1;
            }
            let name = &line[start..j];
            if !name.contains("SALT") {
                continue;
            }
            let allowed = suppressed(s, li, Rule::PrngSalt);
            match line[j..].find('=').and_then(|e| parse_int_literal(&line[j + e + 1..])) {
                Some(value) => out.salts.push(SaltDef {
                    name: name.to_string(),
                    value,
                    file: rel.to_string(),
                    line: li + 1,
                    allowed,
                }),
                None => {
                    if !allowed {
                        out.violations.push(Violation {
                            rule: Rule::PrngSalt,
                            file: rel.to_string(),
                            line: li + 1,
                            message: format!(
                                "salt constant `{name}` must be an integer literal so \
                                 crate-wide uniqueness is checkable"
                            ),
                        });
                    }
                }
            }
        }
        // Prng::new(…) call sites
        for pos in occurrences(line, "Prng::new(") {
            if !bounded(line, pos, 4, true, false) {
                continue;
            }
            let arg = match call_args(&s.lines, li, pos + "Prng::new(".len()) {
                Some(a) => a,
                None => continue,
            };
            match arg.find('^') {
                Some(x) => {
                    if let Some(value) = parse_int_literal(&arg[x + 1..]) {
                        out.salts.push(SaltDef {
                            name: "<inline>".to_string(),
                            value,
                            file: rel.to_string(),
                            line: li + 1,
                            allowed: suppressed(s, li, Rule::PrngSalt),
                        });
                    }
                }
                None => {
                    if !suppressed(s, li, Rule::PrngSalt) {
                        out.violations.push(Violation {
                            rule: Rule::PrngSalt,
                            file: rel.to_string(),
                            line: li + 1,
                            message: "unsalted `Prng::new` stream; derive substreams as \
                                      `Prng::new(seed ^ STREAM_SALT)` with a unique salt"
                                .to_string(),
                        });
                    }
                }
            }
        }
    }
}

/// Collect the argument text of a call whose `(` sits at (`li`, just
/// before `pos`), following up to 10 lines for the matching `)`.
fn call_args(lines: &[String], li: usize, pos: usize) -> Option<String> {
    let mut depth = 1i32;
    let mut args = String::new();
    let mut line_idx = li;
    let mut col = pos;
    let mut budget = 10usize;
    loop {
        let b = lines[line_idx].as_bytes();
        while col < b.len() {
            let c = b[col] as char;
            if c == '(' {
                depth += 1;
            } else if c == ')' {
                depth -= 1;
                if depth == 0 {
                    return Some(args);
                }
            }
            args.push(c);
            col += 1;
        }
        args.push(' ');
        line_idx += 1;
        budget -= 1;
        if line_idx >= lines.len() || budget == 0 {
            return None;
        }
        col = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Violation> {
        scan_source(rel, src).violations
    }

    fn rules_at(vs: &[Violation]) -> Vec<(usize, Rule)> {
        vs.iter().map(|v| (v.line, v.rule)).collect()
    }

    #[test]
    fn wallclock_flags_instant_and_systemtime() {
        let src = "fn f() {\n    let t = std::time::Instant::now();\n    \
                   let s = std::time::SystemTime::now();\n}\n";
        let vs = lint("src/sim/foo.rs", src);
        assert_eq!(rules_at(&vs), vec![(2, Rule::Wallclock), (3, Rule::Wallclock)]);
        assert!(vs[0].message.contains("simulated clock"));
    }

    #[test]
    fn strings_and_comments_are_invisible() {
        let src = r##"
// Instant::now in a comment is fine, as is .unwrap() and panic!
/* block with SystemTime and == 0.0 */
fn f() {
    let s = "Instant::now() .unwrap() panic! == 0.0";
    let r = r#a"SystemTime .expect( inside raw"#a;
    let c = '"'; // a quote char literal must not open a string
    let u = s.len();
}
"##;
        let src = src.replace("#a", "#");
        assert_eq!(lint("src/sim/foo.rs", &src), vec![]);
    }

    #[test]
    fn char_literals_and_lifetimes_do_not_derail() {
        let src = "fn f<'a>(x: &'a str) -> &'a str {\n    let b = b'\"';\n    \
                   let e = '\\u{8}';\n    let q = '\\'';\n    \
                   let t = \"text\".unwrap();\n    x\n}\n";
        let vs = lint("src/util/x.rs", src);
        // Only the real .unwrap() on line 5 — the quote char literal did
        // not swallow the rest of the file into a string.
        assert_eq!(rules_at(&vs), vec![(5, Rule::Unwrap)]);
    }

    #[test]
    fn unwrap_rule_skips_test_modules_and_variants() {
        let src = "fn f(o: Option<u32>) -> u32 {\n    o.unwrap_or(3).max(o.unwrap_or_else(|| 4))\n\
                   }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                   Some(1).unwrap();\n        panic!(\"boom\");\n    }\n}\n";
        assert_eq!(lint("src/broker/x.rs", src), vec![]);
    }

    #[test]
    fn unwrap_rule_flags_library_sites() {
        let src = "fn f(o: Option<u32>) -> u32 {\n    let a = o.unwrap();\n    \
                   let b = o.expect(\"present\");\n    \
                   if a + b == 0 { panic!(\"no\") }\n    a\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(
            rules_at(&vs),
            vec![(2, Rule::Unwrap), (3, Rule::Unwrap), (4, Rule::Unwrap)]
        );
    }

    #[test]
    fn float_eq_flags_literal_comparisons_only() {
        let src = "fn f(x: f64, y: f64, n: u32) -> bool {\n    let a = x == 0.0;\n    \
                   let b = 1.5 != y;\n    let c = x >= 0.0;\n    let d = n == 3;\n    \
                   let e = x == y;\n    let g = x == 2e9;\n    a && b && c && d && e && g\n}\n";
        let vs = lint("src/util/x.rs", src);
        assert_eq!(
            rules_at(&vs),
            vec![(2, Rule::FloatEq), (3, Rule::FloatEq), (7, Rule::FloatEq)]
        );
    }

    #[test]
    fn float_eq_ignores_tuple_field_access() {
        let src = "fn f(t: (u32, u32)) -> bool {\n    t.0 == t.1\n}\n";
        assert_eq!(lint("src/util/x.rs", src), vec![]);
    }

    #[test]
    fn hash_order_flags_iteration_in_deterministic_dirs_only() {
        let src = "use std::collections::HashMap;\nstruct S {\n    tasks: HashMap<u64, u32>,\n\
                   }\nimpl S {\n    fn g(&self) -> usize {\n        \
                   self.tasks.values().count()\n    }\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(rules_at(&vs), vec![(7, Rule::HashOrder)]);
        assert!(vs[0].message.contains("tasks"));
        // The same file outside the deterministic dirs is exempt.
        assert_eq!(lint("src/util/x.rs", src), vec![]);
    }

    #[test]
    fn hash_order_resolves_multiline_chains_and_for_loops() {
        let src = "use std::collections::HashMap;\nfn f(objects: &HashMap<String, u32>) {\n    \
                   let _: Vec<_> = objects\n        .keys()\n        .collect();\n    \
                   for v in objects.values() {\n        let _ = v;\n    }\n    \
                   for (k, _) in objects {\n        let _ = k;\n    }\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(
            rules_at(&vs),
            vec![(4, Rule::HashOrder), (6, Rule::HashOrder), (9, Rule::HashOrder)]
        );
    }

    #[test]
    fn hash_order_leaves_btreemap_and_vecs_alone() {
        let src = "use std::collections::BTreeMap;\nfn f(m: &BTreeMap<u64, u32>, v: &[u32]) \
                   -> usize {\n    m.values().count() + v.iter().count()\n}\n";
        assert_eq!(lint("src/broker/x.rs", src), vec![]);
    }

    #[test]
    fn prng_salt_flags_unsalted_streams() {
        let src = "use crate::util::prng::Prng;\nfn f(seed: u64) -> Prng {\n    \
                   Prng::new(seed)\n}\nfn g(seed: u64) -> Prng {\n    \
                   Prng::new(seed ^ 0xABCD)\n}\n";
        let scan = scan_source("src/sim/x.rs", src);
        assert_eq!(rules_at(&scan.violations), vec![(3, Rule::PrngSalt)]);
        assert_eq!(scan.salts.len(), 1);
        assert_eq!(scan.salts[0].value, 0xABCD);
        // util/prng.rs itself is exempt (seeding + forking live there).
        assert_eq!(scan_source("src/util/prng.rs", src).violations, vec![]);
    }

    #[test]
    fn prng_salt_collects_named_constants() {
        let src = "const FAULT_STREAM_SALT: u64 = 0xFA17_5EED;\nconst OTHER: u64 = 3;\n\
                   const NOT_A_LITERAL_SALT: u64 = compute();\n";
        let scan = scan_source("src/sim/x.rs", src);
        assert_eq!(scan.salts.len(), 1);
        assert_eq!(scan.salts[0].name, "FAULT_STREAM_SALT");
        assert_eq!(scan.salts[0].value, 0xFA17_5EED);
        assert_eq!(rules_at(&scan.violations), vec![(3, Rule::PrngSalt)]);
    }

    #[test]
    fn pragma_suppresses_same_line_and_next_line() {
        let src = "fn f(o: Option<u32>) -> u32 {\n    \
                   o.unwrap() // hydra-lint: allow(unwrap) — boot path, config is pre-validated\n\
                   }\nfn g(o: Option<u32>) -> u32 {\n    \
                   // hydra-lint: allow(unwrap) — boot path, config is pre-validated\n    \
                   o.unwrap()\n}\n";
        assert_eq!(lint("src/broker/x.rs", src), vec![]);
    }

    #[test]
    fn pragma_scope_is_one_line_only() {
        let src = "fn g(o: Option<u32>) -> u32 {\n    \
                   // hydra-lint: allow(unwrap) — only the next line\n    \
                   let a = o.unwrap();\n    let b = o.unwrap();\n    a + b\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(rules_at(&vs), vec![(4, Rule::Unwrap)]);
    }

    #[test]
    fn malformed_pragmas_are_violations() {
        let missing_reason = "// hydra-lint: allow(unwrap)\nfn f() {}\n";
        let unknown_rule = "// hydra-lint: allow(uwnrap) — typo'd rule id\nfn f() {}\n";
        let no_allow = "// hydra-lint: suppress everything please\nfn f() {}\n";
        for src in [missing_reason, unknown_rule, no_allow] {
            let vs = lint("src/broker/x.rs", src);
            assert_eq!(rules_at(&vs), vec![(1, Rule::Pragma)], "{src}");
        }
        // …and a malformed pragma suppresses nothing.
        let src = "fn f(o: Option<u32>) -> u32 {\n    \
                   // hydra-lint: allow(unwrap)\n    o.unwrap()\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(rules_at(&vs), vec![(2, Rule::Pragma), (3, Rule::Unwrap)]);
    }

    #[test]
    fn doc_comments_never_parse_as_pragmas() {
        let src = "/// Quoting the syntax: // hydra-lint: allow(unwrap)\n\
                   //! hydra-lint: allow(unwrap)\nfn f() {}\n";
        assert_eq!(lint("src/broker/x.rs", src), vec![]);
    }

    #[test]
    fn pragma_with_multiple_rules() {
        let src = "fn f(x: f64) -> bool {\n    \
                   // hydra-lint: allow(float-eq, unwrap) — exact sentinel + boot path\n    \
                   x == 0.0 && Some(1).unwrap() == 1\n}\n";
        assert_eq!(lint("src/broker/x.rs", src), vec![]);
    }

    #[test]
    fn cfg_test_region_tracks_nested_braces() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {\n        \
                   if true {\n            Some(1).unwrap();\n        }\n    }\n}\n\
                   fn after(o: Option<u32>) -> u32 {\n    o.unwrap()\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(rules_at(&vs), vec![(11, Rule::Unwrap)]);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn f(o: Option<u32>) -> u32 {\n    o.unwrap()\n}\n";
        let vs = lint("src/broker/x.rs", src);
        assert_eq!(rules_at(&vs), vec![(3, Rule::Unwrap)]);
    }

    #[test]
    fn parse_int_literal_forms() {
        assert_eq!(parse_int_literal("0xFA17_5EED_0D1E;"), Some(0xFA17_5EED_0D1E));
        assert_eq!(parse_int_literal(" 42;"), Some(42));
        assert_eq!(parse_int_literal("compute()"), None);
        assert_eq!(parse_int_literal("0x"), None);
    }
}
