//! Workflow engine: DAG specifications and level-synchronous execution.
//!
//! Experiment 4 needs more than independent workloads: "Hydra has to
//! deploy a stack on both cloud and HPC platforms that enables the
//! execution of workflows, not just workloads" — Argo on the Kubernetes
//! side, RADICAL-EnTK on the HPC side. This module is the stand-in for
//! both: a validated DAG of steps executed wave-by-wave (level-synchronous
//! scheduling, the same stage-barrier model EnTK uses for FACTS) through
//! any Hydra service manager.

pub mod dag;
pub mod engine;

pub use dag::{Step, WorkflowError, WorkflowSpec};
pub use engine::{WorkflowEngine, WorkflowRunReport};
