//! Workflow DAG specification and validation.

use crate::api::task::TaskDescription;

/// One step of a workflow: a task template plus dependencies on earlier
/// steps (by index into `WorkflowSpec::steps`).
#[derive(Debug, Clone)]
pub struct Step {
    pub name: String,
    pub task: TaskDescription,
    pub deps: Vec<usize>,
}

impl Step {
    pub fn new(name: impl Into<String>, task: TaskDescription) -> Step {
        Step { name: name.into(), task, deps: Vec::new() }
    }

    pub fn after(mut self, dep: usize) -> Step {
        self.deps.push(dep);
        self
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum WorkflowError {
    Empty,
    BadDependency { step: usize, dep: usize },
    Cycle { involving: usize },
    DuplicateName(String),
}

impl std::fmt::Display for WorkflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkflowError::Empty => write!(f, "workflow has no steps"),
            WorkflowError::BadDependency { step, dep } => {
                write!(f, "step {step} depends on out-of-range step {dep}")
            }
            WorkflowError::Cycle { involving } => {
                write!(f, "dependency cycle involving step {involving}")
            }
            WorkflowError::DuplicateName(n) => write!(f, "duplicate step name '{n}'"),
        }
    }
}

impl std::error::Error for WorkflowError {}

/// A named DAG of steps.
#[derive(Debug, Clone)]
pub struct WorkflowSpec {
    pub name: String,
    pub steps: Vec<Step>,
}

impl WorkflowSpec {
    pub fn new(name: impl Into<String>) -> WorkflowSpec {
        WorkflowSpec { name: name.into(), steps: Vec::new() }
    }

    pub fn step(mut self, s: Step) -> WorkflowSpec {
        self.steps.push(s);
        self
    }

    /// Structural validation: non-empty, in-range deps, unique names,
    /// acyclic.
    pub fn validate(&self) -> Result<(), WorkflowError> {
        if self.steps.is_empty() {
            return Err(WorkflowError::Empty);
        }
        let mut names = std::collections::HashSet::new();
        for (i, s) in self.steps.iter().enumerate() {
            if !names.insert(s.name.clone()) {
                return Err(WorkflowError::DuplicateName(s.name.clone()));
            }
            for &d in &s.deps {
                if d >= self.steps.len() {
                    return Err(WorkflowError::BadDependency { step: i, dep: d });
                }
            }
        }
        self.levels().map(|_| ())
    }

    /// Topological levels: level k contains every step whose longest
    /// dependency chain has length k. Steps within a level are
    /// independent and run concurrently (one submission wave each).
    pub fn levels(&self) -> Result<Vec<Vec<usize>>, WorkflowError> {
        let n = self.steps.len();
        let mut level = vec![usize::MAX; n]; // MAX = unassigned
        let mut remaining = n;
        let mut progressed = true;
        while remaining > 0 && progressed {
            progressed = false;
            for i in 0..n {
                if level[i] != usize::MAX {
                    continue;
                }
                let deps = &self.steps[i].deps;
                if deps.iter().any(|&d| d < n && level[d] == usize::MAX) {
                    continue;
                }
                let lvl = deps
                    .iter()
                    .filter(|&&d| d < n)
                    .map(|&d| level[d] + 1)
                    .max()
                    .unwrap_or(0);
                level[i] = lvl;
                remaining -= 1;
                progressed = true;
            }
        }
        if remaining > 0 {
            let involving = (0..n).find(|&i| level[i] == usize::MAX).unwrap();
            return Err(WorkflowError::Cycle { involving });
        }
        let max_level = level.iter().copied().max().unwrap_or(0);
        let mut out = vec![Vec::new(); max_level + 1];
        for (i, &l) in level.iter().enumerate() {
            out[l].push(i);
        }
        Ok(out)
    }

    /// Longest chain length (critical path in steps).
    pub fn depth(&self) -> Result<usize, WorkflowError> {
        Ok(self.levels()?.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::TaskDescription;

    fn t(name: &str) -> TaskDescription {
        TaskDescription::executable(name, "step")
    }

    fn chain4() -> WorkflowSpec {
        WorkflowSpec::new("facts")
            .step(Step::new("pre", t("pre")))
            .step(Step::new("fit", t("fit")).after(0))
            .step(Step::new("project", t("project")).after(1))
            .step(Step::new("post", t("post")).after(2))
    }

    #[test]
    fn chain_validates_with_four_levels() {
        let w = chain4();
        w.validate().unwrap();
        assert_eq!(w.levels().unwrap(), vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(w.depth().unwrap(), 4);
    }

    #[test]
    fn diamond_has_three_levels() {
        let w = WorkflowSpec::new("diamond")
            .step(Step::new("a", t("a")))
            .step(Step::new("b", t("b")).after(0))
            .step(Step::new("c", t("c")).after(0))
            .step(Step::new("d", t("d")).after(1).after(2));
        assert_eq!(w.levels().unwrap(), vec![vec![0], vec![1, 2], vec![3]]);
    }

    #[test]
    fn independent_steps_share_level_zero() {
        let w = WorkflowSpec::new("par")
            .step(Step::new("a", t("a")))
            .step(Step::new("b", t("b")))
            .step(Step::new("c", t("c")));
        assert_eq!(w.levels().unwrap(), vec![vec![0, 1, 2]]);
    }

    #[test]
    fn cycle_detected() {
        let w = WorkflowSpec::new("cycle")
            .step(Step::new("a", t("a")).after(1))
            .step(Step::new("b", t("b")).after(0));
        assert!(matches!(w.validate(), Err(WorkflowError::Cycle { .. })));
    }

    #[test]
    fn self_loop_detected() {
        let w = WorkflowSpec::new("self").step(Step::new("a", t("a")).after(0));
        assert!(matches!(w.validate(), Err(WorkflowError::Cycle { involving: 0 })));
    }

    #[test]
    fn bad_dep_and_duplicates_rejected() {
        let w = WorkflowSpec::new("bad").step(Step::new("a", t("a")).after(5));
        assert!(matches!(w.validate(), Err(WorkflowError::BadDependency { step: 0, dep: 5 })));
        let w = WorkflowSpec::new("dup")
            .step(Step::new("x", t("x")))
            .step(Step::new("x", t("x")));
        assert!(matches!(w.validate(), Err(WorkflowError::DuplicateName(_))));
        assert!(matches!(WorkflowSpec::new("empty").validate(), Err(WorkflowError::Empty)));
    }
}
