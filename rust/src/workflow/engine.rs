//! Level-synchronous workflow execution over Hydra service managers.
//!
//! Runs N instances of one `WorkflowSpec` on a single provider: every
//! dependency level becomes one bulk submission wave across all instances
//! (EnTK-style stage barriers; see module docs in `workflow`). Broker-side
//! OVH accumulates over waves in real time; platform-side TTX accumulates
//! the virtual makespans.
//!
//! Wave managers are instantiated through the broker's `ManagerFactory` —
//! the engine is service-agnostic and consumes the unified `ManagerRun`
//! report, so any manager the factory knows (CaaS, HPC batch, FaaS, ...)
//! can execute workflow waves without engine changes.

use crate::api::resource::ResourceRequest;
use crate::api::task::{TaskDescription, TaskId};
use crate::api::ProviderConfig;
use crate::broker::data::SerializeOptions;
use crate::broker::manager::ManagerFactory;
use crate::broker::partitioner::{PartitionModel, PodBuildMode};
use crate::broker::service_proxy::BrokerError;
use crate::broker::state::TaskRegistry;
use crate::metrics::Overhead;
use crate::sim::provider::ProviderId;
use crate::workflow::dag::WorkflowSpec;

/// Result of executing N workflow instances on one provider.
#[derive(Debug, Clone)]
pub struct WorkflowRunReport {
    pub provider: ProviderId,
    pub instances: usize,
    pub waves: usize,
    /// Broker overhead accumulated across waves (real seconds).
    pub ovh: Overhead,
    /// Total workflow execution time: sum of wave makespans (virtual s).
    pub ttx_s: f64,
    /// Virtual makespan of each wave.
    pub wave_ttx_s: Vec<f64>,
    pub tasks: usize,
}

impl WorkflowRunReport {
    pub fn ovh_s(&self) -> f64 {
        self.ovh.total_s()
    }
}

/// Workflow executor bound to one provider connection.
pub struct WorkflowEngine {
    pub config: ProviderConfig,
    pub resource: ResourceRequest,
    pub partition_model: PartitionModel,
    pub build_mode: PodBuildMode,
    /// Serialize-phase fan-out for each wave's manager; defaults to
    /// available parallelism (same knob as `ServiceProxy::serialize`).
    pub serialize: SerializeOptions,
    pub seed: u64,
}

impl WorkflowEngine {
    pub fn new(config: ProviderConfig, resource: ResourceRequest) -> WorkflowEngine {
        WorkflowEngine {
            config,
            resource,
            partition_model: PartitionModel::Scpp,
            build_mode: PodBuildMode::Memory,
            serialize: SerializeOptions::default(),
            seed: 0xFAC7,
        }
    }

    /// Execute `instances` copies of `spec`, waves barrier-synchronized.
    ///
    /// `customize(instance, step, task)` lets the caller specialize each
    /// instance's task (e.g. attach measured FACTS compute durations).
    pub fn execute_many(
        &self,
        spec: &WorkflowSpec,
        instances: usize,
        registry: &TaskRegistry,
        mut customize: impl FnMut(usize, usize, TaskDescription) -> TaskDescription,
    ) -> Result<WorkflowRunReport, BrokerError> {
        spec.validate()
            .map_err(|e| BrokerError::Resource(format!("invalid workflow: {e}")))?;
        let levels = spec.levels().unwrap();

        // One factory for the whole run: the engine never dispatches on
        // the service kind itself.
        let factory =
            ManagerFactory::new(self.partition_model, self.build_mode.clone(), self.serialize);
        let manager_err = |e: &dyn std::fmt::Display| BrokerError::Manager {
            provider: self.config.id,
            message: e.to_string(),
        };

        let mut ovh = Overhead::default();
        let mut wave_ttx = Vec::with_capacity(levels.len());
        let mut total_tasks = 0usize;

        for (wave_idx, level) in levels.iter().enumerate() {
            // Build this wave's tasks across all instances.
            let mut descs: Vec<TaskDescription> = Vec::with_capacity(level.len() * instances);
            for inst in 0..instances {
                for &step_idx in level {
                    let t = spec.steps[step_idx].task.clone();
                    descs.push(customize(inst, step_idx, t));
                }
            }
            total_tasks += descs.len();
            // Move the wave's descriptions into the registry and share
            // them back as Arc handles (§Perf: no per-wave deep clone).
            let tasks: Vec<(TaskId, std::sync::Arc<TaskDescription>)> =
                registry.register_all_shared(descs);

            let seed = self.seed ^ (wave_idx as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
            let mgr = factory
                .create(self.config.clone(), self.resource.clone(), seed)
                .map_err(|e| manager_err(&e))?;
            let r = mgr.execute(&tasks, registry).map_err(|e| manager_err(&e))?;
            ovh.accumulate(&r.metrics.ovh);
            // The pilot fleet is acquired once for the whole workflow
            // run: later waves drop the staging cost up to the earliest
            // agent-ready (when execution could first start). With one
            // pilot this removes the whole queue-wait + boot, as before;
            // with several, the later waves still carry whatever part of
            // the slower pilots' staging delayed their tasks — that skew
            // is real schedule shape, not a fixed cost we can subtract.
            let adjusted = match r.detail.hpc_sim() {
                Some(sim) if wave_idx > 0 => {
                    (r.metrics.ttx_s - sim.first_agent_ready_s()).max(0.0)
                }
                _ => r.metrics.ttx_s,
            };
            wave_ttx.push(adjusted);
        }

        Ok(WorkflowRunReport {
            provider: self.config.id,
            instances,
            waves: levels.len(),
            ovh,
            ttx_s: wave_ttx.iter().sum(),
            wave_ttx_s: wave_ttx,
            tasks: total_tasks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::task::Payload;
    use crate::workflow::dag::Step;

    fn spec() -> WorkflowSpec {
        let t = |n: &str| {
            TaskDescription::executable(n, "step")
                .with_mem_mb(2048)
                .with_payload(Payload::Work(8.0))
        };
        WorkflowSpec::new("facts")
            .step(Step::new("pre", t("pre")))
            .step(Step::new("fit", t("fit")).after(0))
            .step(Step::new("project", t("project")).after(1))
            .step(Step::new("post", t("post")).after(2))
    }

    #[test]
    fn runs_chain_on_cloud() {
        let eng = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 16),
        );
        let reg = TaskRegistry::new();
        let r = eng.execute_many(&spec(), 8, &reg, |_, _, t| t).unwrap();
        assert_eq!(r.waves, 4);
        assert_eq!(r.tasks, 32);
        assert_eq!(r.wave_ttx_s.len(), 4);
        assert!(r.ttx_s > 0.0);
        assert!(reg.all_final());
    }

    #[test]
    fn runs_chain_on_hpc_charging_queue_once() {
        let eng = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Bridges2),
            ResourceRequest::pilot(ProviderId::Bridges2, 1),
        );
        let reg = TaskRegistry::new();
        let r = eng.execute_many(&spec(), 8, &reg, |_, _, t| t).unwrap();
        assert_eq!(r.waves, 4);
        // Wave 0 includes queue wait (~45 s) + boot; later waves must not.
        assert!(r.wave_ttx_s[0] > 40.0, "wave0 {}", r.wave_ttx_s[0]);
        for w in &r.wave_ttx_s[1..] {
            assert!(*w < 40.0, "later wave re-charged the queue: {w}");
        }
    }

    #[test]
    fn runs_chain_on_faas_through_the_factory() {
        // The engine is service-agnostic: a FaaS resource executes
        // workflow waves through the same factory path as CaaS/HPC.
        let eng = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::faas(ProviderId::Aws, 32),
        );
        let reg = TaskRegistry::new();
        let r = eng.execute_many(&spec(), 8, &reg, |_, _, t| t).unwrap();
        assert_eq!(r.waves, 4);
        assert_eq!(r.tasks, 32);
        assert!(r.ttx_s > 0.0);
        assert!(reg.all_final());
    }

    #[test]
    fn customize_sees_every_instance_and_step() {
        let eng = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Azure),
            ResourceRequest::kubernetes(ProviderId::Azure, 1, 8),
        );
        let reg = TaskRegistry::new();
        let mut seen = std::collections::HashSet::new();
        eng.execute_many(&spec(), 3, &reg, |inst, step, t| {
            seen.insert((inst, step));
            t
        })
        .unwrap();
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn invalid_spec_rejected() {
        let eng = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 8),
        );
        let reg = TaskRegistry::new();
        let bad = WorkflowSpec::new("empty");
        assert!(eng.execute_many(&bad, 1, &reg, |_, _, t| t).is_err());
    }

    #[test]
    fn bridges2_outruns_cloud_on_compute_heavy_chain() {
        // The Fig 5 ordering on a compute-heavy workflow.
        let reg = TaskRegistry::new();
        let aws = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 16),
        )
        .execute_many(&spec(), 16, &reg, |_, _, t| t)
        .unwrap();
        let reg2 = TaskRegistry::new();
        let b2 = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Bridges2),
            ResourceRequest::pilot(ProviderId::Bridges2, 1),
        )
        .execute_many(&spec(), 16, &reg2, |_, _, t| t)
        .unwrap();
        // Exclude the one-off queue wait when comparing steady execution.
        let b2_exec = b2.ttx_s - b2.wave_ttx_s[0].min(80.0);
        assert!(
            b2_exec < aws.ttx_s,
            "bridges2 {} (exec {b2_exec}) vs aws {}",
            b2.ttx_s,
            aws.ttx_s
        );
    }
}
