//! bench_quick — the perf-trajectory smoke harness.
//!
//! A fast subset of Experiments 1–2 (4K tasks, fixed seeds) plus the
//! Kubernetes scheduling microbench (16K pods, indexed vs linear-scan
//! scheduler), emitting machine-readable `BENCH_quick.json` so every PR
//! from this one onward leaves a comparable perf record (ROADMAP "Open
//! items" → perf trajectory). Runs in seconds; wired into `rust/smoke.sh`
//! after build + tests.
//!
//! Reported quantities:
//! * **OVH** (ms) and **TH** (task/s) — broker-side cost/throughput for
//!   the 4K-task points (the paper's Fig 2/3 metrics).
//! * **events/s** — simulator event throughput for the 16K-pod
//!   scheduling microbench, for the indexed scheduler and the seed's
//!   linear scan, with the speedup and a determinism cross-check
//!   (identical `TaskRecord`s from both schedulers).

use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::kubernetes::{
    ClusterSpec, ContainerSpec, KubernetesSim, PodSpec, SchedulerKind,
};
use hydra::sim::provider::ProviderId;
use hydra::util::json::Json;
use hydra::util::stats::Summary;
use hydra::util::Stopwatch;

/// Fixed seeds: the trajectory must be comparable across PRs.
const SEEDS: [u64; 3] = [0xBEEF, 0xC0DE, 0xD00D];
const POINT_TASKS: usize = 4000;
const MICRO_PODS: usize = 16_000;
const MICRO_NODES: u32 = 256;
const MICRO_VCPUS: u32 = 16;
const MICRO_SEED: u64 = 7;

struct Point {
    name: &'static str,
    ovh_ms: Summary,
    th_tps: Summary,
    tpt_s: Summary,
    pods: usize,
}

fn noop_containers(n: usize) -> Vec<TaskDescription> {
    (0..n)
        .map(|i| TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"))
        .collect()
}

fn run_point(
    name: &'static str,
    providers: &[ProviderId],
    model: PartitionModel,
) -> Point {
    let mut ovh = Vec::new();
    let mut th = Vec::new();
    let mut tpt = Vec::new();
    let mut pods = 0usize;
    for &seed in &SEEDS {
        let mut b = Hydra::builder().partition_model(model).seed(seed);
        for &p in providers {
            b = b
                .simulated_provider(p)
                .resource(ResourceRequest::kubernetes(p, 1, 16));
        }
        let hydra = b.build().expect("simulated providers must build");
        let run = hydra
            .submit(noop_containers(POINT_TASKS), &BrokerPolicy::RoundRobin)
            .expect("noop workload must broker");
        ovh.push(run.aggregate.ovh_s * 1e3);
        th.push(run.aggregate.th_tps);
        tpt.push(run.aggregate.tpt_s);
        pods = run.aggregate.pods;
    }
    Point {
        name,
        ovh_ms: Summary::of(&ovh),
        th_tps: Summary::of(&th),
        tpt_s: Summary::of(&tpt),
        pods,
    }
}

fn micro_pods() -> Vec<PodSpec> {
    (0..MICRO_PODS as u64)
        .map(|i| PodSpec { id: i, containers: vec![ContainerSpec::noop(i + 1)] })
        .collect()
}

struct MicroRun {
    wall_s: f64,
    events: u64,
    events_per_s: f64,
    makespan_s: f64,
}

fn run_micro(kind: SchedulerKind) -> (MicroRun, Vec<hydra::sim::kubernetes::TaskRecord>) {
    let profile = hydra::sim::provider::PlatformProfile::of(ProviderId::Jetstream2);
    let cluster = ClusterSpec::uniform(MICRO_NODES, MICRO_VCPUS);
    let mut sim = KubernetesSim::new(profile, cluster, MICRO_SEED).with_scheduler(kind);
    sim.submit(micro_pods(), 0.0);
    let sw = Stopwatch::start();
    let report = sim.run();
    let wall_s = sw.elapsed_secs();
    assert_eq!(report.pods_completed, MICRO_PODS, "{kind:?}: pods lost");
    let events_per_s = if wall_s > 0.0 {
        report.events_processed as f64 / wall_s
    } else {
        f64::INFINITY
    };
    (
        MicroRun {
            wall_s,
            events: report.events_processed,
            events_per_s,
            makespan_s: report.makespan_s,
        },
        report.tasks,
    )
}

fn point_json(p: &Point) -> Json {
    Json::obj()
        .set("name", p.name)
        .set("tasks", POINT_TASKS)
        .set("pods", p.pods)
        .set("ovh_ms_mean", p.ovh_ms.mean)
        .set("ovh_ms_std", p.ovh_ms.std)
        .set("th_tps_mean", p.th_tps.mean)
        .set("th_tps_std", p.th_tps.std)
        .set("tpt_s_mean", p.tpt_s.mean)
}

fn micro_json(m: &MicroRun) -> Json {
    Json::obj()
        .set("wall_s", m.wall_s)
        .set("events", m.events)
        .set("events_per_s", m.events_per_s)
        .set("makespan_s", m.makespan_s)
}

fn main() {
    println!("bench_quick: perf-trajectory smoke (fixed seeds {SEEDS:?})");
    println!("\n--- broker points ({POINT_TASKS} noop tasks) ---");
    println!(
        "{:<16} {:>8} {:>16} {:>14} {:>10}",
        "POINT", "PODS", "OVH (ms)", "TH (task/s)", "TPT (s)"
    );
    let points = [
        run_point("exp1_mcpp_4k", &[ProviderId::Jetstream2], PartitionModel::Mcpp { max_cpp: 16 }),
        run_point("exp1_scpp_4k", &[ProviderId::Jetstream2], PartitionModel::Scpp),
        run_point("exp2_clouds_4k", &ProviderId::CLOUDS, PartitionModel::Mcpp { max_cpp: 16 }),
    ];
    for p in &points {
        println!(
            "{:<16} {:>8} {:>8.2} ±{:>5.2} {:>14.0} {:>10.1}",
            p.name, p.pods, p.ovh_ms.mean, p.ovh_ms.std, p.th_tps.mean, p.tpt_s.mean
        );
    }

    println!(
        "\n--- scheduling microbench ({MICRO_PODS} pods, {MICRO_NODES} nodes x {MICRO_VCPUS} vCPUs, seed {MICRO_SEED}) ---"
    );
    let (linear, linear_records) = run_micro(SchedulerKind::LinearScan);
    let (indexed, indexed_records) = run_micro(SchedulerKind::Indexed);
    let records_identical = linear_records == indexed_records;
    let speedup = linear.wall_s / indexed.wall_s.max(1e-12);
    println!(
        "{:<12} {:>10} {:>12} {:>14}",
        "SCHEDULER", "WALL (s)", "EVENTS", "EVENTS/s"
    );
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "linear", linear.wall_s, linear.events, linear.events_per_s
    );
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "indexed", indexed.wall_s, indexed.events, indexed.events_per_s
    );
    println!(
        "speedup: {speedup:.2}x | identical TaskRecords: {records_identical} | \
         virtual makespan {:.1}s (both)",
        indexed.makespan_s
    );
    assert!(
        records_identical,
        "indexed scheduler diverged from the linear-scan reference"
    );

    let doc = Json::obj()
        .set("schema", "hydra-bench-quick/v1")
        .set("seeds", Json::Arr(SEEDS.iter().map(|&s| Json::Num(s as f64)).collect()))
        .set("points", Json::Arr(points.iter().map(point_json).collect()))
        .set(
            "sched_microbench",
            Json::obj()
                .set("pods", MICRO_PODS)
                .set("nodes", MICRO_NODES as u64)
                .set("vcpus_per_node", MICRO_VCPUS as u64)
                .set("seed", MICRO_SEED)
                .set("linear", micro_json(&linear))
                .set("indexed", micro_json(&indexed))
                .set("speedup", speedup)
                .set("records_identical", records_identical),
        );
    let path = "BENCH_quick.json";
    std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_quick.json");
    println!("\n(wrote {path})");
}
