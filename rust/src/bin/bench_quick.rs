//! bench_quick — the perf-trajectory smoke harness.
//!
//! A fast subset of Experiments 1–2 (4K tasks, fixed seeds) plus the
//! Kubernetes scheduling microbench (16K pods, indexed vs linear-scan
//! scheduler), emitting machine-readable `BENCH_quick.json` so every PR
//! from this one onward leaves a comparable perf record (ROADMAP "Open
//! items" → perf trajectory). Runs in seconds; wired into `rust/smoke.sh`
//! after build + tests.
//!
//! Reported quantities:
//! * **OVH** (ms), **SER** (ms, the serialize phase alone) and **TH**
//!   (task/s) — broker-side cost/throughput for the 4K-task points (the
//!   paper's Fig 2/3 metrics). `exp_faas_4k` brokers a mixed
//!   CaaS+HPC+FaaS workload under `ByTaskKind` — all three service
//!   managers concurrently through the `ManagerFactory` (ISSUE 4).
//!   `exp_hpc_multipilot_4k` brokers 4K executables onto **4 concurrent
//!   Bridges2 pilots** (ISSUE 5: per-pilot sharded bulk submission +
//!   capacity-index placement), with a cross-check that the 4-pilot run
//!   completes exactly the task set the single-pilot reference completes.
//!   `exp_hpc_faulty_4k` re-runs that shape with pilot 2 killed 5 s
//!   after its agent materializes (ISSUE 6: fault-tolerant fleets),
//!   cross-checking that the survivors re-run the dead pilot's tasks and
//!   complete exactly the healthy run's task set. `exp_failover_4k`
//!   brokers the mixed workload across two CaaS providers with one
//!   control plane down for the whole run (ISSUE 7: fallible provider
//!   endpoints + cross-provider failover), cross-checking that the dead
//!   provider's slice fails over and the completion set matches the
//!   healthy run's.
//! * **serialize microbench** — threads=1 vs threads=N manifest
//!   serialization + bulk framing on the 4K-task SCPP point (ISSUE 3
//!   tentpole), with a byte-identity cross-check on the framed payload.
//! * **events/s** — simulator event throughput for the 16K-pod
//!   scheduling microbench, for the indexed scheduler and the seed's
//!   linear scan, with the speedup and a determinism cross-check
//!   (identical `TaskRecord`s from both schedulers). The same point is
//!   then re-run heap-queue vs calendar-queue (ISSUE 8: the event-queue
//!   backends of `sim::event`) with its own speedup + identity check;
//!   the 100K/1M-task deep end of that axis lives in `bench_scale`.

use hydra::api::resource::FaultSpec;
use hydra::api::task::{TaskId, TaskState};
use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::partitioner::Partitioner;
use hydra::broker::{
    BrokerPolicy, BrokerRun, Hydra, PartitionModel, PodBuildMode, ProviderFaultSpec, RetryPolicy,
    SerializeOptions,
};
use hydra::sim::event::EventQueueKind;
use hydra::sim::kubernetes::{ClusterSpec, ContainerSpec, KubernetesSim, PodSpec, SchedulerKind};
use hydra::sim::provider::ProviderId;
use hydra::util::json::{parse, Json};
use hydra::util::json_scan::JsonScanner;
use hydra::util::stats::Summary;
use hydra::util::Stopwatch;

/// Fixed seeds: the trajectory must be comparable across PRs.
const SEEDS: [u64; 3] = [0xBEEF, 0xC0DE, 0xD00D];
const POINT_TASKS: usize = 4000;
const MICRO_PODS: usize = 16_000;
const MICRO_NODES: u32 = 256;
const MICRO_VCPUS: u32 = 16;
const MICRO_SEED: u64 = 7;

struct Point {
    name: &'static str,
    ovh_ms: Summary,
    /// Serialize-phase window: max over concurrent providers, like OVH.
    serialize_ms: Summary,
    th_tps: Summary,
    tpt_s: Summary,
    pods: usize,
}

fn noop_containers(n: usize) -> Vec<TaskDescription> {
    (0..n)
        .map(|i| TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"))
        .collect()
}

/// Per-seed measurement shared by every broker point: build, submit,
/// fold the aggregate into the point summaries.
fn measure_point(
    name: &'static str,
    build: impl Fn(u64) -> Hydra,
    tasks: impl Fn() -> Vec<TaskDescription>,
    policy: &BrokerPolicy,
) -> Point {
    let mut ovh = Vec::new();
    let mut ser = Vec::new();
    let mut th = Vec::new();
    let mut tpt = Vec::new();
    let mut pods = 0usize;
    for &seed in &SEEDS {
        let hydra = build(seed);
        let run = hydra.submit(tasks(), policy).expect("bench workload must broker");
        ovh.push(run.aggregate.ovh_s * 1e3);
        let serialize_window = run
            .reports
            .values()
            .map(|r| r.metrics().ovh.serialize_s)
            .fold(0.0, f64::max);
        ser.push(serialize_window * 1e3);
        th.push(run.aggregate.th_tps);
        tpt.push(run.aggregate.tpt_s);
        pods = run.aggregate.pods;
    }
    Point {
        name,
        ovh_ms: Summary::of(&ovh),
        serialize_ms: Summary::of(&ser),
        th_tps: Summary::of(&th),
        tpt_s: Summary::of(&tpt),
        pods,
    }
}

fn run_point(name: &'static str, providers: &[ProviderId], model: PartitionModel) -> Point {
    measure_point(
        name,
        |seed| {
            let mut b = Hydra::builder().partition_model(model).seed(seed);
            for &p in providers {
                b = b
                    .simulated_provider(p)
                    .resource(ResourceRequest::kubernetes(p, 1, 16));
            }
            b.build().expect("simulated providers must build")
        },
        || noop_containers(POINT_TASKS),
        &BrokerPolicy::RoundRobin,
    )
}

/// ISSUE 4 point: a mixed CaaS+HPC+FaaS workload — one provider per
/// service kind, all three managers concurrently through the factory,
/// tasks routed by kind.
fn run_mixed_point(name: &'static str) -> Point {
    measure_point(
        name,
        |seed| {
            Hydra::builder()
                .partition_model(PartitionModel::Mcpp { max_cpp: 16 })
                .seed(seed)
                .simulated_provider(ProviderId::Jetstream2)
                .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
                .simulated_provider(ProviderId::Bridges2)
                .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
                .simulated_provider(ProviderId::Aws)
                .resource(ResourceRequest::faas(ProviderId::Aws, 64))
                .build()
                .expect("simulated providers must build")
        },
        mixed_tasks,
        &BrokerPolicy::ByTaskKind,
    )
}

/// The mixed-kind 4K workload shared by `exp_faas_4k` and
/// `exp_failover_4k`: containers, executables, and functions round-robin.
fn mixed_tasks() -> Vec<TaskDescription> {
    (0..POINT_TASKS)
        .map(|i| match i % 3 {
            0 => TaskDescription::container(format!("con-{i}"), "hydra/noop:latest"),
            1 => TaskDescription::executable(format!("exe-{i}"), "noop"),
            _ => TaskDescription::function(format!("fn-{i}"), "hydra.noop:handler"),
        })
        .collect()
}

/// ISSUE 7 configuration: the `exp_faas_4k` shape plus a second CaaS
/// provider (Chameleon). With `outage` armed, Chameleon's control plane
/// is down for the whole run, so its container slice must fail over to
/// Jetstream2 through the broker's re-brokering path.
fn failover_broker(seed: u64, outage: bool) -> Hydra {
    let mut chameleon = ResourceRequest::kubernetes(ProviderId::Chameleon, 1, 16);
    if outage {
        chameleon = chameleon
            .with_provider_faults(ProviderFaultSpec {
                outage_window: Some((0.0, 1e9)),
                ..ProviderFaultSpec::none()
            })
            .with_retry_policy(RetryPolicy { max_attempts: 2, ..RetryPolicy::default() });
    }
    Hydra::builder()
        .partition_model(PartitionModel::Mcpp { max_cpp: 16 })
        .seed(seed)
        .simulated_provider(ProviderId::Jetstream2)
        .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
        .simulated_provider(ProviderId::Chameleon)
        .resource(chameleon)
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
        .simulated_provider(ProviderId::Aws)
        .resource(ResourceRequest::faas(ProviderId::Aws, 64))
        .build()
        .expect("simulated providers must build")
}

fn run_failover_point(name: &'static str) -> Point {
    measure_point(name, |seed| failover_broker(seed, true), mixed_tasks, &BrokerPolicy::ByTaskKind)
}

/// Sorted ids of the tasks a brokered run drove to `Done`.
fn done_ids(hydra: &Hydra, run: &BrokerRun) -> Vec<u64> {
    let mut ids: Vec<u64> = run
        .assignment
        .values()
        .flatten()
        .filter(|id| hydra.registry().state_of(**id) == Some(TaskState::Done))
        .map(|id| id.0)
        .collect();
    ids.sort_unstable();
    ids
}

/// Resilience accounting of one outage run at a fixed seed, for the
/// completion-set cross-check against the healthy run.
struct FailoverCheck {
    completed: Vec<u64>,
    failover_legs: usize,
    failed_over: usize,
    abandoned: usize,
    submit_retries: usize,
    backoff_ms: u64,
    failover_bulk_bytes: usize,
}

fn failover_healthy_ids(seed: u64) -> Vec<u64> {
    let hydra = failover_broker(seed, false);
    let run = hydra
        .submit(mixed_tasks(), &BrokerPolicy::ByTaskKind)
        .expect("healthy failover reference must broker");
    assert!(run.failovers.is_empty(), "healthy run must not fail over");
    done_ids(&hydra, &run)
}

fn failover_faulty_check(seed: u64) -> FailoverCheck {
    let hydra = failover_broker(seed, true);
    let run = hydra
        .submit(mixed_tasks(), &BrokerPolicy::ByTaskKind)
        .expect("failover point must broker");
    let completed = done_ids(&hydra, &run);
    let tallies = run
        .reports
        .values()
        .map(|r| r.run().faults)
        .chain(run.failovers.iter().map(|f| f.report.run().faults));
    let (mut submit_retries, mut backoff_ms, mut failed_over) = (0usize, 0u64, 0usize);
    for f in tallies {
        submit_retries += f.submit_retries;
        backoff_ms += f.backoff_ms;
        failed_over += f.failed_over;
    }
    FailoverCheck {
        completed,
        failover_legs: run.failovers.len(),
        failed_over,
        abandoned: run.abandoned.len(),
        submit_retries,
        backoff_ms,
        failover_bulk_bytes: run.failovers.iter().map(|f| f.report.run().bulk_bytes).sum(),
    }
}

/// One configuration of the ISSUE 5 HPC point: `pilots` concurrent
/// Bridges2 pilots, 1 node each. The measured row and the
/// completion-set cross-check build from here so they can never drift
/// onto different shapes.
fn hpc_multipilot_broker(pilots: u32, seed: u64) -> Hydra {
    Hydra::builder()
        .seed(seed)
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::hpc(ProviderId::Bridges2, 1, pilots))
        .build()
        .expect("simulated providers must build")
}

fn hpc_multipilot_tasks() -> Vec<TaskDescription> {
    (0..POINT_TASKS)
        .map(|i| TaskDescription::executable(format!("exe-{i}"), "noop"))
        .collect()
}

/// ISSUE 5 point: 4K executable tasks on `pilots` concurrent Bridges2
/// pilots — the weak-scaling axis the multi-pilot HPC manager opens.
fn run_hpc_multipilot_point(name: &'static str, pilots: u32) -> Point {
    measure_point(
        name,
        |seed| hpc_multipilot_broker(pilots, seed),
        hpc_multipilot_tasks,
        &BrokerPolicy::RoundRobin,
    )
}

/// Sorted completed task ids of one multi-pilot HPC run at a fixed seed
/// (the completion-set cross-check between pilots=1 and pilots=4).
fn hpc_completed_ids(pilots: u32, seed: u64) -> Vec<u64> {
    let hydra = hpc_multipilot_broker(pilots, seed);
    let run = hydra
        .submit(hpc_multipilot_tasks(), &BrokerPolicy::RoundRobin)
        .expect("hpc point must broker");
    let report = run.reports.values().next().expect("one provider");
    let sim = report.run().detail.hpc_sim().expect("hpc detail");
    let mut ids: Vec<u64> = sim.tasks.iter().map(|t| t.task_id).collect();
    ids.sort_unstable();
    ids
}

/// ISSUE 6: the faulty HPC configuration — same 4K-executable shape as
/// `exp_hpc_multipilot_4k` on 4 pilots, but pilot 2 is killed 5 s after
/// its agent materializes (default retry budget). Survivors must re-run
/// the dead pilot's tasks, so the completion set matches the healthy
/// run's exactly.
fn hpc_faulty_broker(seed: u64) -> Hydra {
    Hydra::builder()
        .seed(seed)
        .simulated_provider(ProviderId::Bridges2)
        .resource(
            ResourceRequest::hpc(ProviderId::Bridges2, 1, 4)
                .with_faults(FaultSpec { injected_kill: Some((2, 5.0)), ..FaultSpec::none() }),
        )
        .build()
        .expect("simulated providers must build")
}

fn run_hpc_faulty_point(name: &'static str) -> Point {
    measure_point(name, hpc_faulty_broker, hpc_multipilot_tasks, &BrokerPolicy::RoundRobin)
}

/// Fault accounting of one faulty run at a fixed seed, for the
/// completion-set cross-check against the healthy pilots=4 run.
struct FaultCheck {
    completed: Vec<u64>,
    died: Vec<usize>,
    requeued: usize,
    retried: usize,
    retry_waves: usize,
    retry_bulk_bytes: usize,
    abandoned: usize,
}

fn hpc_faulty_check(seed: u64) -> FaultCheck {
    let hydra = hpc_faulty_broker(seed);
    let run = hydra
        .submit(hpc_multipilot_tasks(), &BrokerPolicy::RoundRobin)
        .expect("faulty hpc point must broker");
    let report = run.reports.values().next().expect("one provider");
    let faults = report.run().faults;
    let sim = report.run().detail.hpc_sim().expect("hpc detail");
    let mut completed: Vec<u64> = sim.tasks.iter().map(|t| t.task_id).collect();
    completed.sort_unstable();
    FaultCheck {
        completed,
        died: sim
            .pilots
            .iter()
            .enumerate()
            .filter(|(_, p)| p.died_at.is_some())
            .map(|(i, _)| i)
            .collect(),
        requeued: sim.pilots.iter().map(|p| p.tasks_requeued).sum(),
        retried: faults.retried,
        retry_waves: faults.retry_waves,
        retry_bulk_bytes: faults.retry_bulk_bytes,
        abandoned: faults.abandoned,
    }
}

/// ISSUE 3 tentpole row: threads=1 vs threads=N manifest serialization +
/// bulk framing for the 4K-task SCPP point (the serialization-heaviest
/// quick point: one manifest per task). Best-of-5 per configuration;
/// asserts the framed payloads are byte-identical.
struct SerializeMicro {
    threads: usize,
    serial_ms: f64,
    parallel_ms: f64,
    speedup: f64,
    bulk_bytes: usize,
    /// The framed payload itself — reused by the ingest microbench so
    /// both rows measure the exact same bytes.
    bulk: Vec<u8>,
}

fn run_serialize_micro() -> SerializeMicro {
    let tasks: Vec<(TaskId, TaskDescription)> = (0..POINT_TASKS)
        .map(|i| {
            (
                TaskId(i as u64),
                TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"),
            )
        })
        .collect();
    let cluster = ClusterSpec::uniform(1, 16);
    let time_with = |opts: SerializeOptions| -> (f64, Vec<u8>) {
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory).with_serialize(opts);
        let mut best = f64::INFINITY;
        let mut bulk = Vec::new();
        for _ in 0..5 {
            let pods = p.partition(&tasks, &cluster, 0).expect("noop tasks fit");
            let sw = Stopwatch::start();
            let prepared = p.build_manifests(pods, &tasks).expect("memory mode");
            let framed = prepared.frame_bulk(opts);
            best = best.min(sw.elapsed_secs());
            bulk = framed;
        }
        (best * 1e3, bulk)
    };
    let (serial_ms, serial_bulk) = time_with(SerializeOptions::serial());
    let auto = SerializeOptions::default();
    let (parallel_ms, parallel_bulk) = time_with(auto);
    assert_eq!(
        serial_bulk, parallel_bulk,
        "parallel serialization diverged from the serial reference"
    );
    SerializeMicro {
        threads: auto.effective_threads(),
        serial_ms,
        parallel_ms,
        speedup: serial_ms / parallel_ms.max(1e-9),
        bulk_bytes: serial_bulk.len(),
        bulk: serial_bulk,
    }
}

/// ISSUE 10 row: lazy scan (`util::json_scan`, zero-alloc) vs tree parse
/// (`util::json`) over the 4K-task SCPP framed payload — the ingest cost
/// a broker pays to spot-check a provider response. Both sides do the
/// same job: count the framed pod manifests and fold their
/// `hydra/pod-id` labels; the harness asserts the answers agree and that
/// the lazy path is at least as fast per byte. Best-of-5.
struct IngestMicro {
    bytes: usize,
    items: usize,
    lazy_ms: f64,
    tree_ms: f64,
    lazy_bps: f64,
    tree_bps: f64,
    speedup: f64,
}

fn run_ingest_micro(bulk: &[u8]) -> IngestMicro {
    const ID_PATH: [&str; 3] = ["metadata", "labels", "hydra/pod-id"];
    let lazy_pass = || -> (usize, u64) {
        let mut n = 0usize;
        let mut sum = 0u64;
        for span in JsonScanner::new(bulk).items() {
            // hydra-lint: allow(unwrap) — bench aborts on a malformed payload
            let (s, e) = span.expect("framed payload must scan");
            n += 1;
            if let Some(id) = JsonScanner::new(&bulk[s..e]).path_u64(&ID_PATH) {
                sum = sum.wrapping_add(id);
            }
        }
        (n, sum)
    };
    let tree_pass = || -> (usize, u64) {
        // hydra-lint: allow(unwrap) — bench aborts on a malformed payload
        let text = std::str::from_utf8(bulk).expect("framed payload is UTF-8");
        // hydra-lint: allow(unwrap) — bench aborts on a malformed payload
        let doc = parse(text).expect("framed payload must tree-parse");
        let mut sum = 0u64;
        let items = match doc.as_arr() {
            Some(items) => items,
            None => &[],
        };
        for item in items {
            if let Some(id) = item.at(&ID_PATH).and_then(Json::as_u64) {
                sum = sum.wrapping_add(id);
            }
        }
        (items.len(), sum)
    };
    let best_of_5 = |pass: &dyn Fn() -> (usize, u64)| -> (f64, usize, u64) {
        let mut best = f64::INFINITY;
        let mut out = (0usize, 0u64);
        for _ in 0..5 {
            let sw = Stopwatch::start();
            out = pass();
            best = best.min(sw.elapsed_secs());
        }
        (best * 1e3, out.0, out.1)
    };
    let (lazy_ms, lazy_n, lazy_sum) = best_of_5(&lazy_pass);
    let (tree_ms, tree_n, tree_sum) = best_of_5(&tree_pass);
    assert_eq!(lazy_n, tree_n, "lazy scan and tree parse disagree on item count");
    assert_eq!(lazy_sum, tree_sum, "lazy scan and tree parse disagree on pod ids");
    let bps = |ms: f64| bulk.len() as f64 / (ms / 1e3).max(1e-12);
    IngestMicro {
        bytes: bulk.len(),
        items: lazy_n,
        lazy_ms,
        tree_ms,
        lazy_bps: bps(lazy_ms),
        tree_bps: bps(tree_ms),
        speedup: tree_ms / lazy_ms.max(1e-9),
    }
}

fn micro_pods() -> Vec<PodSpec> {
    (0..MICRO_PODS as u64)
        .map(|i| PodSpec { id: i, containers: vec![ContainerSpec::noop(i + 1)] })
        .collect()
}

struct MicroRun {
    wall_s: f64,
    events: u64,
    events_per_s: f64,
    makespan_s: f64,
}

fn run_micro(
    kind: SchedulerKind,
    queue: EventQueueKind,
) -> (MicroRun, Vec<hydra::sim::kubernetes::TaskRecord>) {
    let profile = hydra::sim::provider::PlatformProfile::of(ProviderId::Jetstream2);
    let cluster = ClusterSpec::uniform(MICRO_NODES, MICRO_VCPUS);
    let mut sim = KubernetesSim::new(profile, cluster, MICRO_SEED)
        .with_scheduler(kind)
        .with_event_queue(queue);
    sim.submit(micro_pods(), 0.0);
    let sw = Stopwatch::start();
    let report = sim.run();
    let wall_s = sw.elapsed_secs();
    assert_eq!(report.pods_completed, MICRO_PODS, "{kind:?}: pods lost");
    let events_per_s = if wall_s > 0.0 {
        report.events_processed as f64 / wall_s
    } else {
        f64::INFINITY
    };
    (
        MicroRun {
            wall_s,
            events: report.events_processed,
            events_per_s,
            makespan_s: report.makespan_s,
        },
        report.tasks,
    )
}

fn point_json(p: &Point) -> Json {
    Json::obj()
        .set("name", p.name)
        .set("tasks", POINT_TASKS)
        .set("pods", p.pods)
        .set("ovh_ms_mean", p.ovh_ms.mean)
        .set("ovh_ms_std", p.ovh_ms.std)
        .set("serialize_ms_mean", p.serialize_ms.mean)
        .set("serialize_ms_std", p.serialize_ms.std)
        .set("th_tps_mean", p.th_tps.mean)
        .set("th_tps_std", p.th_tps.std)
        .set("tpt_s_mean", p.tpt_s.mean)
}

fn micro_json(m: &MicroRun) -> Json {
    Json::obj()
        .set("wall_s", m.wall_s)
        .set("events", m.events)
        .set("events_per_s", m.events_per_s)
        .set("makespan_s", m.makespan_s)
}

fn main() {
    println!("bench_quick: perf-trajectory smoke (fixed seeds {SEEDS:?})");
    println!("\n--- broker points ({POINT_TASKS} noop tasks) ---");
    println!(
        "{:<16} {:>8} {:>16} {:>10} {:>14} {:>10}",
        "POINT", "PODS", "OVH (ms)", "SER (ms)", "TH (task/s)", "TPT (s)"
    );
    let points = [
        run_point("exp1_mcpp_4k", &[ProviderId::Jetstream2], PartitionModel::Mcpp { max_cpp: 16 }),
        run_point("exp1_scpp_4k", &[ProviderId::Jetstream2], PartitionModel::Scpp),
        run_point("exp2_clouds_4k", &ProviderId::CLOUDS, PartitionModel::Mcpp { max_cpp: 16 }),
        run_mixed_point("exp_faas_4k"),
        run_hpc_multipilot_point("exp_hpc_multipilot_4k", 4),
        run_hpc_faulty_point("exp_hpc_faulty_4k"),
        run_failover_point("exp_failover_4k"),
    ];
    for p in &points {
        println!(
            "{:<16} {:>8} {:>8.2} ±{:>5.2} {:>10.2} {:>14.0} {:>10.1}",
            p.name,
            p.pods,
            p.ovh_ms.mean,
            p.ovh_ms.std,
            p.serialize_ms.mean,
            p.th_tps.mean,
            p.tpt_s.mean
        );
    }

    // ISSUE 5 acceptance: 4 pilots complete the same task set as the
    // single-pilot (serial-reference-equivalent) run.
    let one_pilot = hpc_completed_ids(1, SEEDS[0]);
    let four_pilots = hpc_completed_ids(4, SEEDS[0]);
    assert_eq!(one_pilot.len(), POINT_TASKS);
    assert_eq!(
        one_pilot, four_pilots,
        "pilots=4 diverged from the pilots=1 completion set"
    );
    println!(
        "exp_hpc_multipilot_4k: pilots=4 completes the same {POINT_TASKS}-task set as \
         pilots=1 (checked at seed {:#x})",
        SEEDS[0]
    );

    // ISSUE 6 acceptance: kill pilot 2 five seconds after its agent
    // comes up; the three survivors must complete exactly the healthy
    // run's task set — nothing duplicated, nothing abandoned.
    let fault = hpc_faulty_check(SEEDS[0]);
    assert_eq!(fault.died, vec![2], "exactly the injected pilot must die");
    assert!(fault.requeued >= 1, "the dead pilot must hand at least one task back");
    assert_eq!(fault.retried, fault.requeued, "retry accounting out of sync with the sim");
    assert_eq!(fault.abandoned, 0, "the default retry budget must absorb one pilot kill");
    assert!(fault.retry_bulk_bytes > 0, "retry waves must account transport bytes");
    assert_eq!(
        fault.completed, four_pilots,
        "faulty run lost or duplicated tasks vs the healthy pilots=4 run"
    );
    println!(
        "exp_hpc_faulty_4k: pilot 2 killed mid-run, {} tasks re-queued over {} wave(s) \
         ({} B resubmitted); completion set matches the healthy run (seed {:#x})",
        fault.requeued, fault.retry_waves, fault.retry_bulk_bytes, SEEDS[0]
    );

    // ISSUE 7 acceptance: one CaaS provider's control plane is down for
    // the whole run; its container slice must land on the surviving CaaS
    // provider with the completion set identical to the healthy run, at
    // least one task failed over, and the failover transport accounted.
    let healthy = failover_healthy_ids(SEEDS[0]);
    let failover = failover_faulty_check(SEEDS[0]);
    assert_eq!(healthy.len(), POINT_TASKS, "healthy failover reference lost tasks");
    assert_eq!(
        failover.completed, healthy,
        "outage run lost or duplicated tasks vs the healthy run"
    );
    assert!(failover.failed_over >= 1, "the dead provider's slice must fail over");
    assert_eq!(failover.abandoned, 0, "a surviving CaaS provider must absorb the slice");
    assert!(
        failover.failover_bulk_bytes > 0,
        "the failover leg must account its transport bytes"
    );
    println!(
        "exp_failover_4k: provider down mid-submit, {} tasks failed over in {} leg(s) \
         ({} B re-shipped, {} submit retries, {} ms backoff); completion set matches the \
         healthy run (seed {:#x})",
        failover.failed_over,
        failover.failover_legs,
        failover.failover_bulk_bytes,
        failover.submit_retries,
        failover.backoff_ms,
        SEEDS[0]
    );

    println!("\n--- serialize microbench ({POINT_TASKS} tasks, SCPP, best of 5) ---");
    let ser = run_serialize_micro();
    println!(
        "threads=1: {:.2}ms | threads={}: {:.2}ms | speedup {:.2}x | framed {} bytes \
         (byte-identical)",
        ser.serial_ms, ser.threads, ser.parallel_ms, ser.speedup, ser.bulk_bytes
    );

    // ISSUE 10: the ingest side of the same payload — lazy zero-alloc
    // scan vs full tree parse, identical answers, lazy at least as fast.
    println!("\n--- ingest microbench ({} B framed SCPP payload, best of 5) ---", ser.bulk_bytes);
    let ingest = run_ingest_micro(&ser.bulk);
    println!(
        "lazy scan: {:.2}ms ({:.1} MB/s) | tree parse: {:.2}ms ({:.1} MB/s) | \
         lazy {:.2}x | {} items id-checked (identical)",
        ingest.lazy_ms,
        ingest.lazy_bps / 1e6,
        ingest.tree_ms,
        ingest.tree_bps / 1e6,
        ingest.speedup,
        ingest.items
    );
    assert!(
        ingest.lazy_bps >= ingest.tree_bps,
        "lazy scan must ingest at least as many bytes/s as the tree parse"
    );

    println!(
        "\n--- scheduling microbench ({MICRO_PODS} pods, {MICRO_NODES} nodes x \
         {MICRO_VCPUS} vCPUs, seed {MICRO_SEED}) ---"
    );
    let (linear, linear_records) = run_micro(SchedulerKind::LinearScan, EventQueueKind::default());
    let (indexed, indexed_records) = run_micro(SchedulerKind::Indexed, EventQueueKind::default());
    let records_identical = linear_records == indexed_records;
    let speedup = linear.wall_s / indexed.wall_s.max(1e-12);
    println!(
        "{:<12} {:>10} {:>12} {:>14}",
        "SCHEDULER", "WALL (s)", "EVENTS", "EVENTS/s"
    );
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "linear", linear.wall_s, linear.events, linear.events_per_s
    );
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "indexed", indexed.wall_s, indexed.events, indexed.events_per_s
    );
    println!(
        "speedup: {speedup:.2}x | identical TaskRecords: {records_identical} | \
         virtual makespan {:.1}s (both)",
        indexed.makespan_s
    );
    assert!(
        records_identical,
        "indexed scheduler diverged from the linear-scan reference"
    );

    // ISSUE 8: the same point, indexed scheduler, heap queue (reference)
    // vs calendar queue (default) — the quick-tier view of the axis
    // bench_scale pushes to 1M tasks.
    let (q_heap, q_heap_records) = run_micro(SchedulerKind::Indexed, EventQueueKind::Heap);
    let (q_cal, q_cal_records) = run_micro(SchedulerKind::Indexed, EventQueueKind::Calendar);
    let queue_records_identical = q_heap_records == q_cal_records;
    let queue_speedup = q_cal.events_per_s / q_heap.events_per_s.max(1e-12);
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "queue:heap", q_heap.wall_s, q_heap.events, q_heap.events_per_s
    );
    println!(
        "{:<12} {:>10.3} {:>12} {:>14.0}",
        "queue:cal", q_cal.wall_s, q_cal.events, q_cal.events_per_s
    );
    println!(
        "queue speedup (events/s): {queue_speedup:.2}x | identical TaskRecords: \
         {queue_records_identical}"
    );
    assert!(
        queue_records_identical,
        "calendar event queue diverged from the heap reference"
    );

    let doc = Json::obj()
        .set("schema", "hydra-bench-quick/v1")
        .set("seeds", Json::Arr(SEEDS.iter().map(|&s| Json::Num(s as f64)).collect()))
        .set("points", Json::Arr(points.iter().map(point_json).collect()))
        .set(
            "serialize_microbench",
            Json::obj()
                .set("tasks", POINT_TASKS)
                .set("model", "SCPP")
                .set("threads", ser.threads)
                .set("serialize_ms_serial", ser.serial_ms)
                .set("serialize_ms_parallel", ser.parallel_ms)
                .set("speedup", ser.speedup)
                .set("bulk_bytes", ser.bulk_bytes)
                .set("bulk_identical", true),
        )
        .set(
            "ingest_microbench",
            Json::obj()
                .set("bytes", ingest.bytes)
                .set("items", ingest.items)
                .set("lazy_scan_ms", ingest.lazy_ms)
                .set("tree_parse_ms", ingest.tree_ms)
                .set("lazy_bytes_per_s", ingest.lazy_bps)
                .set("tree_bytes_per_s", ingest.tree_bps)
                .set("speedup", ingest.speedup)
                .set("ids_identical", true),
        )
        .set(
            "hpc_multipilot_check",
            Json::obj()
                .set("tasks", POINT_TASKS)
                .set("pilots", 4u64)
                .set("seed", SEEDS[0])
                .set("completion_set_identical", true),
        )
        .set(
            "hpc_fault_check",
            Json::obj()
                .set("tasks", POINT_TASKS)
                .set("pilots", 4u64)
                .set("killed_pilot", 2u64)
                .set("kill_after_agent_ready_s", 5.0)
                .set("seed", SEEDS[0])
                .set("tasks_requeued", fault.requeued)
                .set("retry_waves", fault.retry_waves)
                .set("retry_bulk_bytes", fault.retry_bulk_bytes)
                .set("abandoned", fault.abandoned)
                .set("completion_set_identical", true),
        )
        .set(
            "failover_check",
            Json::obj()
                .set("tasks", POINT_TASKS)
                .set("down_provider", "chi")
                .set("seed", SEEDS[0])
                .set("failover_legs", failover.failover_legs)
                .set("tasks_failed_over", failover.failed_over)
                .set("failover_bulk_bytes", failover.failover_bulk_bytes)
                .set("submit_retries", failover.submit_retries)
                .set("backoff_ms", failover.backoff_ms)
                .set("abandoned", failover.abandoned)
                .set("completion_set_identical", true),
        )
        .set(
            "sched_microbench",
            Json::obj()
                .set("pods", MICRO_PODS)
                .set("nodes", MICRO_NODES as u64)
                .set("vcpus_per_node", MICRO_VCPUS as u64)
                .set("seed", MICRO_SEED)
                .set("linear", micro_json(&linear))
                .set("indexed", micro_json(&indexed))
                .set("speedup", speedup)
                .set("records_identical", records_identical)
                .set("queue_heap", micro_json(&q_heap))
                .set("queue_calendar", micro_json(&q_cal))
                .set("queue_speedup", queue_speedup)
                .set("queue_records_identical", queue_records_identical),
        );
    let path = "BENCH_quick.json";
    std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_quick.json");
    println!("\n(wrote {path})");
}
