//! `hydra_lint` — CLI front-end for the determinism-invariant analyzer.
//!
//! Scans `src/**/*.rs` under the crate root, ratchets the per-rule
//! per-file violation counts against `ci/lint_baseline.json`, writes a
//! `hydra-lint-report/v1` JSON, and exits non-zero on any regression.
//! See [`hydra::lint`] for the rule set and the pragma syntax.
//!
//! Exit codes: 0 = clean (ratchet satisfied), 1 = lint regressions,
//! 2 = usage or I/O error.

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use hydra::lint;

const HELP: &str = "\
hydra_lint — determinism-invariant static analyzer for the hydra crate

USAGE:
  hydra_lint [OPTIONS]

OPTIONS:
  --root <dir>       crate root to scan [default: this crate's manifest dir]
  --baseline <file>  ratchet baseline [default: <root>/ci/lint_baseline.json]
  --json <file>      JSON report path [default: <root>/LINT_report.json]
  --refresh          rewrite the baseline from the current tree (ratchet down)
  --help             show this message

RULES:
  wallclock   Instant::now / SystemTime in library code
  hash-order  HashMap/HashSet iteration in sim/, broker/, workflow/, facts/
  prng-salt   unsalted Prng::new outside util/prng.rs; duplicate stream salts
  unwrap      .unwrap() / .expect( / panic! in non-test library code
  float-eq    ==/!= against an f64 literal (compare .to_bits() instead)

Suppress a finding with a scoped pragma in a plain // comment, with a
mandatory reason, covering its own line or (when standalone) the next:
  // hydra-lint: allow(<rule>[, <rule>]) — <reason>
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("hydra-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

fn take_value(
    argv: &[String],
    i: &mut usize,
    key: &str,
    inline: Option<&str>,
) -> Result<String, String> {
    if let Some(v) = inline {
        return Ok(v.to_string());
    }
    *i += 1;
    argv.get(*i).cloned().ok_or_else(|| format!("{key} needs a value (see --help)"))
}

fn run(argv: &[String]) -> Result<ExitCode, String> {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut baseline_opt: Option<PathBuf> = None;
    let mut report_opt: Option<PathBuf> = None;
    let mut refresh = false;

    let mut i = 0;
    while i < argv.len() {
        let arg = argv[i].as_str();
        let (key, inline) = match arg.split_once('=') {
            Some((k, v)) => (k, Some(v)),
            None => (arg, None),
        };
        match key {
            "--help" | "-h" => {
                print!("{HELP}");
                return Ok(ExitCode::SUCCESS);
            }
            "--refresh" => refresh = true,
            "--root" => root = PathBuf::from(take_value(argv, &mut i, key, inline)?),
            "--baseline" => {
                baseline_opt = Some(PathBuf::from(take_value(argv, &mut i, key, inline)?));
            }
            "--json" => {
                report_opt = Some(PathBuf::from(take_value(argv, &mut i, key, inline)?));
            }
            other => return Err(format!("unknown argument '{other}' (see --help)")),
        }
        i += 1;
    }

    let baseline_path = baseline_opt.unwrap_or_else(|| root.join("ci/lint_baseline.json"));
    let report_path = report_opt.unwrap_or_else(|| root.join("LINT_report.json"));

    let tree = lint::scan_tree(&root)?;
    let cur = lint::counts_of(&tree.violations);
    let totals: Vec<String> = cur
        .iter()
        .map(|(rule, files)| format!("{rule}={}", files.values().sum::<usize>()))
        .collect();

    if refresh {
        let mut text = lint::baseline_json(&cur).to_string_pretty();
        text.push('\n');
        fs::write(&baseline_path, text)
            .map_err(|e| format!("write {}: {e}", baseline_path.display()))?;
        println!(
            "hydra-lint: baseline refreshed at {} ({} files, {})",
            baseline_path.display(),
            tree.files_scanned,
            totals.join(" ")
        );
        return Ok(ExitCode::SUCCESS);
    }

    let text = fs::read_to_string(&baseline_path).map_err(|e| {
        format!("read {}: {e} (run with --refresh to create it)", baseline_path.display())
    })?;
    let base = lint::parse_baseline(&text)?;
    let outcome = lint::gate(&cur, &base);

    let mut report = lint::report_json(&tree, &cur, &outcome).to_string_pretty();
    report.push('\n');
    fs::write(&report_path, report)
        .map_err(|e| format!("write {}: {e}", report_path.display()))?;

    println!("hydra-lint: scanned {} files; {}", tree.files_scanned, totals.join(" "));
    for note in &outcome.tighten {
        println!("hydra-lint: note: {note}");
    }

    if outcome.passed() {
        println!("hydra-lint: clean — ratchet satisfied (report: {})", report_path.display());
        Ok(ExitCode::SUCCESS)
    } else {
        eprintln!(
            "hydra-lint: FAIL — {} (rule, file) pair(s) above ci/lint_baseline.json:",
            outcome.regressions.len()
        );
        for r in &outcome.regressions {
            eprintln!("  {r}");
        }
        eprintln!("offending sites (every current site of a regressed pair):");
        for v in lint::regressed_sites(&tree, &cur, &base) {
            eprintln!("  {}:{}: [{}] {}", v.file, v.line, v.rule, v.message);
        }
        eprintln!(
            "hydra-lint: fix the new violation, suppress it with a scoped pragma and a \
             reason, or refresh the baseline for deliberate debt"
        );
        Ok(ExitCode::from(1))
    }
}
