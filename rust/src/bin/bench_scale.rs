//! bench_scale — the 100K/1M-task scale tier (ISSUE 8 tentpole).
//!
//! The quick trajectory (`bench_quick`, run by `smoke.sh` on every PR)
//! stops at 4K tasks and 16K pods; the paper's core claim is scale, so
//! this harness pushes the simulator two orders of magnitude further:
//! 100K- and 1M-pod workloads on a 4096-node × 16-vCPU cluster, measured
//! once per event-queue backend (`EventQueueKind::Heap`, the reference,
//! vs `EventQueueKind::Calendar`, the O(1)-amortized default — see
//! `sim::event`). At this event count the queue is the expected hotspot,
//! which is exactly what the tier exists to expose and guard.
//!
//! Deliberately **excluded from `smoke.sh`** so tier-1 stays fast: run it
//! explicitly (`cargo run --release --bin bench_scale`), or let the
//! nightly/workflow_dispatch `bench-scale` CI job run the 100K point.
//! Writes machine-readable `BENCH_scale.json` (schema
//! `hydra-bench-scale/v1`); `ci/bench_gate.sh` understands the schema
//! when handed the file explicitly.
//!
//! Harness-level asserts (the tier gates itself even without a committed
//! baseline):
//! * every point completes all its pods under both backends;
//! * the two backends produce byte-identical `TaskRecord`s;
//! * on the 1M point the calendar queue's events/s must be ≥ the heap's
//!   reported in the same file (the tentpole's reason to exist).

use hydra::sim::event::EventQueueKind;
use hydra::sim::kubernetes::{
    ClusterSpec, ContainerSpec, KubernetesSim, PodSpec, SchedulerKind, TaskRecord,
};
use hydra::sim::provider::{PlatformProfile, ProviderId};
use hydra::util::json::{push_u64, Json};
use hydra::util::json_scan::JsonScanner;
use hydra::util::Stopwatch;

const SCALE_NODES: u32 = 4096;
const SCALE_VCPUS: u32 = 16;
const SCALE_SEED: u64 = 7;

struct ScalePoint {
    name: &'static str,
    pods: usize,
    /// Wall-clock repeats per backend (best-of): noise protection for
    /// the calendar-vs-heap assert.
    best_of: usize,
}

const POINTS: [ScalePoint; 2] = [
    ScalePoint { name: "scale_sched_100k", pods: 100_000, best_of: 3 },
    ScalePoint { name: "scale_sched_1m", pods: 1_000_000, best_of: 2 },
];

const USAGE: &str = "usage: bench_scale [--only 100k|1m]

Scale tier (ISSUE 8): 100K- and 1M-pod scheduling points on a 4096-node
cluster, event-queue heap (reference) vs calendar (default), asserting
byte-identical TaskRecords and, at 1M, calendar events/s >= heap.
Writes BENCH_scale.json (schema hydra-bench-scale/v1). Excluded from
smoke.sh; CI runs the 100K point nightly / on workflow_dispatch.

  --only 100k   run only the 100K-pod point
  --only 1m     run only the 1M-pod point";

struct ScaleRun {
    wall_s: f64,
    events: u64,
    events_per_s: f64,
    makespan_s: f64,
}

fn scale_pods(n: usize) -> Vec<PodSpec> {
    (0..n as u64)
        .map(|i| PodSpec { id: i, containers: vec![ContainerSpec::noop(i + 1)] })
        .collect()
}

/// One measured run: `pods` single-container noop pods through the
/// indexed scheduler on the chosen queue backend. Returns the timing and
/// the full record vector for the cross-backend identity check.
fn run_once(pods: usize, queue: EventQueueKind) -> (ScaleRun, Vec<TaskRecord>) {
    let profile = PlatformProfile::of(ProviderId::Jetstream2);
    let cluster = ClusterSpec::uniform(SCALE_NODES, SCALE_VCPUS);
    let mut sim = KubernetesSim::new(profile, cluster, SCALE_SEED)
        .with_scheduler(SchedulerKind::Indexed)
        .with_event_queue(queue);
    sim.submit(scale_pods(pods), 0.0);
    let sw = Stopwatch::start();
    let report = sim.run();
    let wall_s = sw.elapsed_secs();
    assert_eq!(report.pods_completed, pods, "{queue:?}: pods lost at {pods}");
    let events_per_s = if wall_s > 0.0 {
        report.events_processed as f64 / wall_s
    } else {
        f64::INFINITY
    };
    (
        ScaleRun {
            wall_s,
            events: report.events_processed,
            events_per_s,
            makespan_s: report.makespan_s,
        },
        report.tasks,
    )
}

/// Best-of-`n` wall time (fixed seed: the simulated schedule is
/// identical across repeats, only the wall clock varies).
fn run_best(pods: usize, queue: EventQueueKind, best_of: usize) -> (ScaleRun, Vec<TaskRecord>) {
    let (mut best, mut records) = run_once(pods, queue);
    for _ in 1..best_of {
        let (run, recs) = run_once(pods, queue);
        if run.wall_s < best.wall_s {
            best = run;
            records = recs;
        }
    }
    (best, records)
}

/// Frame the point's pods as one bulk `[manifest,...]` payload — the
/// same envelope shape the CaaS transport ships — so the ingest row
/// measures provider-response scanning at scale.
fn framed_payload(pods: &[PodSpec]) -> String {
    let mut out = String::with_capacity(2 + pods.len() * 72);
    out.push('[');
    for (k, p) in pods.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(r#"{"kind":"Pod","metadata":{"labels":{"hydra/pod-id":"#);
        push_u64(&mut out, p.id);
        out.push_str(r#"}},"containers":"#);
        push_u64(&mut out, p.containers.len() as u64);
        out.push('}');
    }
    out.push(']');
    out
}

/// ISSUE 10 ingest row: lazy-scan the point's framed payload **without
/// materializing a document tree** — count the items and spot-check the
/// first/last `hydra/pod-id`, exactly what the managers do per ack. At
/// 1M pods a tree parse would allocate millions of nodes; the scanner
/// allocates nothing. Returns `(bytes, scan_ms, bytes_per_s)`.
fn run_ingest(pods: &[PodSpec], best_of: usize) -> (usize, f64, f64) {
    const ID_PATH: [&str; 3] = ["metadata", "labels", "hydra/pod-id"];
    let bulk = framed_payload(pods);
    let b = bulk.as_bytes();
    let mut best = f64::INFINITY;
    for _ in 0..best_of {
        let sw = Stopwatch::start();
        let mut n = 0usize;
        let mut first = None;
        let mut last = None;
        for span in JsonScanner::new(b).items() {
            // hydra-lint: allow(unwrap) — bench aborts on a malformed payload
            let (s, e) = span.expect("framed payload must scan");
            if n == 0 {
                first = JsonScanner::new(&b[s..e]).path_u64(&ID_PATH);
            }
            last = Some((s, e));
            n += 1;
        }
        best = best.min(sw.elapsed_secs());
        assert_eq!(n, pods.len(), "ingest scan lost framed items");
        assert_eq!(first, pods.first().map(|p| p.id), "first pod id not found by lazy scan");
        let last_id =
            last.and_then(|(s, e)| JsonScanner::new(&b[s..e]).path_u64(&ID_PATH));
        assert_eq!(last_id, pods.last().map(|p| p.id), "last pod id not found by lazy scan");
    }
    let bps = b.len() as f64 / best.max(1e-12);
    (b.len(), best * 1e3, bps)
}

fn run_json(r: &ScaleRun) -> Json {
    Json::obj()
        .set("wall_s", r.wall_s)
        .set("events", r.events)
        .set("events_per_s", r.events_per_s)
        .set("makespan_s", r.makespan_s)
}

fn die(msg: &str) -> ! {
    eprintln!("bench_scale: {msg}\n\n{USAGE}");
    std::process::exit(2);
}

fn main() {
    let mut only: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--help" | "-h" => {
                println!("{USAGE}");
                return;
            }
            "--only" => match args.next().as_deref() {
                Some(v @ ("100k" | "1m")) => only = Some(v.to_string()),
                _ => die("--only takes 100k or 1m"),
            },
            other => die(&format!("unknown argument {other:?}")),
        }
    }

    let selected: Vec<&ScalePoint> = POINTS
        .iter()
        .filter(|p| match only.as_deref() {
            None => true,
            Some("100k") => p.pods == 100_000,
            Some("1m") => p.pods == 1_000_000,
            Some(_) => false,
        })
        .collect();

    println!(
        "bench_scale: {} pods/point on {SCALE_NODES} nodes x {SCALE_VCPUS} vCPUs \
         (seed {SCALE_SEED})",
        selected.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
    );
    println!(
        "{:<18} {:>10} {:>10} {:>12} {:>14} {:>9}",
        "POINT", "QUEUE", "WALL (s)", "EVENTS", "EVENTS/s", "SPEEDUP"
    );

    let mut point_docs = Vec::new();
    for p in &selected {
        let (heap, heap_records) = run_best(p.pods, EventQueueKind::Heap, p.best_of);
        let (cal, cal_records) = run_best(p.pods, EventQueueKind::Calendar, p.best_of);
        let records_identical = heap_records == cal_records;
        assert!(
            records_identical,
            "{}: calendar queue diverged from the heap reference",
            p.name
        );
        let speedup = cal.events_per_s / heap.events_per_s.max(1e-12);
        println!(
            "{:<18} {:>10} {:>10.3} {:>12} {:>14.0} {:>9}",
            p.name, "heap", heap.wall_s, heap.events, heap.events_per_s, ""
        );
        println!(
            "{:<18} {:>10} {:>10.3} {:>12} {:>14.0} {:>8.2}x",
            p.name, "calendar", cal.wall_s, cal.events, cal.events_per_s, speedup
        );
        if p.pods >= 1_000_000 {
            // The tentpole's acceptance: at 1M tasks the calendar queue
            // must not be slower than the heap it replaces.
            assert!(
                cal.events_per_s >= heap.events_per_s,
                "{}: calendar {:.0} ev/s < heap {:.0} ev/s — the O(1) queue regressed",
                p.name,
                cal.events_per_s,
                heap.events_per_s
            );
        }
        // ISSUE 10: scan the point's framed bulk payload in-harness —
        // item count + first/last id spot-check, no tree materialized.
        let (ingest_bytes, scan_ms, bps) = run_ingest(&scale_pods(p.pods), p.best_of);
        println!(
            "{:<18} {:>10} {} B scanned in {:.1} ms ({:.1} MB/s, no tree)",
            p.name,
            "ingest",
            ingest_bytes,
            scan_ms,
            bps / 1e6
        );
        point_docs.push(
            Json::obj()
                .set("name", p.name)
                .set("pods", p.pods)
                .set("tasks", p.pods)
                .set("best_of", p.best_of)
                .set("heap", run_json(&heap))
                .set("calendar", run_json(&cal))
                .set("speedup", speedup)
                .set("records_identical", records_identical)
                .set(
                    "ingest",
                    Json::obj()
                        .set("bytes", ingest_bytes)
                        .set("scan_ms", scan_ms)
                        .set("bytes_per_s", bps),
                ),
        );
    }

    let doc = Json::obj()
        .set("schema", "hydra-bench-scale/v1")
        .set("nodes", SCALE_NODES as u64)
        .set("vcpus_per_node", SCALE_VCPUS as u64)
        .set("seed", SCALE_SEED)
        .set("points", Json::Arr(point_docs));
    let path = "BENCH_scale.json";
    std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_scale.json");
    println!("\n(wrote {path})");
}
