//! PJRT runtime: load and execute the AOT-compiled FACTS artifacts.
//!
//! The compile path (`python/compile/aot.py`) lowers each FACTS step to
//! HLO **text** under `artifacts/` plus a `manifest.json` describing
//! input/output shapes. This module is the only place that touches XLA:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. Python never runs on this path.
//!
//! Interchange is HLO text because jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 (behind the published
//! `xla` 0.1.6 crate) rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §3).

use crate::util::json::{self};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A tensor crossing the runtime boundary: f32 data + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length must match shape"
        );
        Tensor { data, shape }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Shape signature of one artifact from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub quantiles: Vec<f64>,
    pub artifacts: Vec<ArtifactSpec>,
}

#[derive(Debug)]
pub enum RuntimeError {
    Io(String),
    Manifest(String),
    UnknownArtifact(String),
    ShapeMismatch { artifact: String, detail: String },
    Xla(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Io(m) => write!(f, "io: {m}"),
            RuntimeError::Manifest(m) => write!(f, "manifest: {m}"),
            RuntimeError::UnknownArtifact(n) => write!(f, "unknown artifact '{n}'"),
            RuntimeError::ShapeMismatch { artifact, detail } => {
                write!(f, "shape mismatch for '{artifact}': {detail}")
            }
            RuntimeError::Xla(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, RuntimeError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| RuntimeError::Io(format!("{}: {e}", dir.display())))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, RuntimeError> {
        let doc = json::parse(text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let quantiles = doc
            .get("quantiles")
            .and_then(|q| q.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| RuntimeError::Manifest("missing 'artifacts' array".into()))?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| RuntimeError::Manifest("artifact missing 'name'".into()))?
                .to_string();
            let file = a
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| RuntimeError::Manifest(format!("{name}: missing 'file'")))?
                .to_string();
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>, RuntimeError> {
                a.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| RuntimeError::Manifest(format!("{name}: missing '{key}'")))?
                    .iter()
                    .map(|io| {
                        io.get("shape")
                            .and_then(|s| s.as_arr())
                            .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                            .ok_or_else(|| RuntimeError::Manifest(format!("{name}: bad shape")))
                    })
                    .collect()
            };
            let input_shapes = shapes("inputs")?;
            let output_shapes = shapes("outputs")?;
            artifacts.push(ArtifactSpec { name, file, input_shapes, output_shapes });
        }
        Ok(Manifest { quantiles, artifacts })
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The runtime: one PJRT CPU client, lazily-compiled executables keyed by
/// artifact name. `Mutex`-guarded so service-manager threads can share it.
pub struct PjRtRuntime {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: Manifest,
    loaded: Mutex<HashMap<String, Loaded>>,
    exec_count: std::sync::atomic::AtomicU64,
}

impl PjRtRuntime {
    /// Open the artifacts directory (expects `manifest.json`).
    pub fn load(dir: impl Into<PathBuf>) -> Result<PjRtRuntime, RuntimeError> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| RuntimeError::Xla(e.to_string()))?;
        Ok(PjRtRuntime {
            dir,
            client,
            manifest,
            loaded: Mutex::new(HashMap::new()),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn executions(&self) -> u64 {
        self.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Number of artifacts compiled so far (observability for the
    /// compile-once cache).
    pub fn compiled_count(&self) -> usize {
        self.loaded.lock().unwrap().len()
    }

    fn ensure_compiled(&self, name: &str) -> Result<(), RuntimeError> {
        let mut g = self.loaded.lock().unwrap();
        if g.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .spec(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError::Io("non-utf8 path".into()))?,
        )
        .map_err(|e| RuntimeError::Xla(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError::Xla(format!("compile {name}: {e}")))?;
        g.insert(name.to_string(), Loaded { exe, spec });
        Ok(())
    }

    /// Execute one artifact with shape checking; returns the output
    /// tensors in manifest order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        self.ensure_compiled(name)?;
        let g = self.loaded.lock().unwrap();
        let loaded = g.get(name).unwrap();
        let spec = &loaded.spec;
        if inputs.len() != spec.input_shapes.len() {
            return Err(RuntimeError::ShapeMismatch {
                artifact: name.to_string(),
                detail: format!(
                    "expected {} inputs, got {}",
                    spec.input_shapes.len(),
                    inputs.len()
                ),
            });
        }
        for (i, (t, want)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            if &t.shape != want {
                return Err(RuntimeError::ShapeMismatch {
                    artifact: name.to_string(),
                    detail: format!("input {i}: expected {:?}, got {:?}", want, t.shape),
                });
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let l = xla::Literal::vec1(&t.data);
                let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
                l.reshape(&dims).map_err(|e| RuntimeError::Xla(e.to_string()))
            })
            .collect::<Result<_, _>>()?;

        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| RuntimeError::Xla(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::Xla(e.to_string()))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| RuntimeError::Xla(format!("untuple {name}: {e}")))?;
        if parts.len() != spec.output_shapes.len() {
            return Err(RuntimeError::ShapeMismatch {
                artifact: name.to_string(),
                detail: format!(
                    "expected {} outputs, got {}",
                    spec.output_shapes.len(),
                    parts.len()
                ),
            });
        }
        self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        parts
            .into_iter()
            .zip(&spec.output_shapes)
            .map(|(lit, shape)| {
                let data = lit.to_vec::<f32>().map_err(|e| RuntimeError::Xla(e.to_string()))?;
                if data.len() != shape.iter().product::<usize>() {
                    return Err(RuntimeError::ShapeMismatch {
                        artifact: name.to_string(),
                        detail: format!("output length {} vs shape {:?}", data.len(), shape),
                    });
                }
                Ok(Tensor { data, shape: shape.clone() })
            })
            .collect()
    }
}

/// Default artifacts directory: `$HYDRA_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("HYDRA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
 "format": "hlo-text-v1",
 "quantiles": [0.05, 0.5, 0.95],
 "artifacts": [
  {"name": "fit_k2_small", "file": "fit_k2_small.hlo.txt",
   "inputs": [{"name": "in0", "shape": [4, 32, 2], "dtype": "f32"},
              {"name": "in1", "shape": [4, 32], "dtype": "f32"}],
   "outputs": [{"name": "theta", "shape": [4, 2], "dtype": "f32"},
               {"name": "sigma2", "shape": [4], "dtype": "f32"},
               {"name": "A", "shape": [4, 2, 2], "dtype": "f32"}]}
 ]
}"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.quantiles, vec![0.05, 0.5, 0.95]);
        let s = m.spec("fit_k2_small").unwrap();
        assert_eq!(s.input_shapes, vec![vec![4, 32, 2], vec![4, 32]]);
        assert_eq!(s.output_shapes.len(), 3);
        assert!(m.spec("nope").is_none());
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"file": "x"}]}"#).is_err());
    }

    #[test]
    fn tensor_invariants() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(Tensor::scalar(5.0).shape, Vec::<usize>::new());
        assert_eq!(Tensor::zeros(&[3, 2]).data.len(), 6);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![1.0], vec![2, 2]);
    }

    // Execution against real artifacts is covered by
    // rust/tests/integration_runtime.rs (requires `make artifacts`).
}
