//! Metrics: the paper's four experimental quantities plus tracing.
//!
//! * **OVH** — time Hydra spends preparing a workload and initiating its
//!   execution: *real wall-clock time of broker work* (partitioning,
//!   manifest building/serialization, bulk submission prep).
//! * **TH** — Hydra's throughput: tasks *processed by the broker* per
//!   second (`tasks / OVH`), explicitly not platform execution rate.
//! * **TPT** — platform task processing time: virtual makespan of
//!   executing the workload on the (simulated) platform, including
//!   environment setup and teardown.
//! * **TTX** — total execution time of the submitted workload on the
//!   platform (used for the heterogeneous and FACTS experiments).
//!
//! The OVH/TPT split is the paper's own separation of broker-side and
//! platform-side costs; DESIGN.md §1 explains why OVH stays real while
//! TPT/TTX are simulated.

use crate::api::task::{TaskId, TaskState};
use crate::sim::provider::ProviderId;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Broker-side overhead breakdown for one workload run (seconds, wall).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Overhead {
    /// Partitioning tasks into pods / bulk task descriptions.
    pub partition_s: f64,
    /// Building + serializing manifests (disk or memory).
    pub serialize_s: f64,
    /// Assembling and issuing the bulk submission.
    pub submit_s: f64,
}

impl Overhead {
    pub fn total_s(&self) -> f64 {
        self.partition_s + self.serialize_s + self.submit_s
    }

    /// Fold another overhead window into this one, phase by phase (the
    /// workflow engine accumulates per-wave manager overheads this way).
    pub fn accumulate(&mut self, other: &Overhead) {
        self.partition_s += other.partition_s;
        self.serialize_s += other.serialize_s;
        self.submit_s += other.submit_s;
    }
}

/// The paper's metric set for one (provider, workload) run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub provider: ProviderId,
    pub tasks: usize,
    pub pods: usize,
    pub ovh: Overhead,
    /// Virtual platform makespan (TPT for noop workloads, TTX otherwise).
    pub tpt_s: f64,
    pub ttx_s: f64,
}

impl RunMetrics {
    /// TH: broker throughput in tasks/second.
    pub fn throughput_tps(&self) -> f64 {
        let ovh = self.ovh.total_s();
        if ovh <= 0.0 {
            f64::INFINITY
        } else {
            self.tasks as f64 / ovh
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("provider", self.provider.short_name())
            .set("tasks", self.tasks)
            .set("pods", self.pods)
            .set("ovh_s", self.ovh.total_s())
            .set("ovh_partition_s", self.ovh.partition_s)
            .set("ovh_serialize_s", self.ovh.serialize_s)
            .set("ovh_submit_s", self.ovh.submit_s)
            .set("th_tps", self.throughput_tps())
            .set("tpt_s", self.tpt_s)
            .set("ttx_s", self.ttx_s)
    }
}

/// Aggregate of concurrent per-provider runs (Experiments 2–4): OVH is the
/// max over concurrent brokers (they run in parallel), tasks sum, and the
/// aggregate TH is total tasks over the aggregate OVH window.
pub fn aggregate(runs: &[RunMetrics]) -> Option<AggregateMetrics> {
    if runs.is_empty() {
        return None;
    }
    let tasks: usize = runs.iter().map(|r| r.tasks).sum();
    let pods: usize = runs.iter().map(|r| r.pods).sum();
    let ovh_max = runs.iter().map(|r| r.ovh.total_s()).fold(0.0, f64::max);
    let tpt_max = runs.iter().map(|r| r.tpt_s).fold(0.0, f64::max);
    let ttx_max = runs.iter().map(|r| r.ttx_s).fold(0.0, f64::max);
    Some(AggregateMetrics {
        tasks,
        pods,
        ovh_s: ovh_max,
        th_tps: if ovh_max > 0.0 { tasks as f64 / ovh_max } else { f64::INFINITY },
        tpt_s: tpt_max,
        ttx_s: ttx_max,
    })
}

#[derive(Debug, Clone)]
pub struct AggregateMetrics {
    pub tasks: usize,
    pub pods: usize,
    pub ovh_s: f64,
    pub th_tps: f64,
    pub tpt_s: f64,
    pub ttx_s: f64,
}

/// Assemble the reporting document for one brokered run: per-provider
/// metrics, the aggregate, and optionally the full task trace — the
/// "monitoring and reporting" surface of the resource-brokering
/// requirements the paper cites (§3, Venkateswaran & Sarkar).
pub fn run_report(
    runs: &[RunMetrics],
    agg: &AggregateMetrics,
    trace: Option<Json>,
) -> Json {
    let mut doc = Json::obj()
        .set(
            "per_provider",
            Json::Arr(runs.iter().map(|r| r.to_json()).collect()),
        )
        .set(
            "aggregate",
            Json::obj()
                .set("tasks", agg.tasks)
                .set("pods", agg.pods)
                .set("ovh_s", agg.ovh_s)
                .set("th_tps", agg.th_tps)
                .set("tpt_s", agg.tpt_s)
                .set("ttx_s", agg.ttx_s),
        );
    if let Some(t) = trace {
        doc = doc.set("trace", t);
    }
    doc
}

/// One tracing event: a task state transition with a wall-clock timestamp
/// (micros since trace start) and optionally a virtual timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub task: TaskId,
    pub state: TaskState,
    pub wall_us: u64,
    pub virtual_s: Option<f64>,
}

/// Append-only trace log, mirroring the paper's "monitoring and tracing
/// capabilities ... designed from the ground up for performance".
#[derive(Debug, Default)]
pub struct TraceLog {
    start: Option<std::time::Instant>,
    events: Vec<TraceEvent>,
}

impl TraceLog {
    pub fn new() -> TraceLog {
        // hydra-lint: allow(wallclock) — trace epochs are wall-relative by design (OVH)
        TraceLog { start: Some(std::time::Instant::now()), events: Vec::new() }
    }

    pub fn record(&mut self, task: TaskId, state: TaskState) {
        self.record_virtual(task, state, None);
    }

    pub fn record_virtual(&mut self, task: TaskId, state: TaskState, virtual_s: Option<f64>) {
        let wall_us = self
            .start
            .map(|s| s.elapsed().as_micros() as u64)
            .unwrap_or(0);
        self.events.push(TraceEvent { task, state, wall_us, virtual_s });
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events for one task, in order.
    pub fn for_task(&self, task: TaskId) -> Vec<&TraceEvent> {
        self.events.iter().filter(|e| e.task == task).collect()
    }

    /// Export as a JSON array (one object per event).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    let mut o = Json::obj()
                        .set("task", e.task.0)
                        .set("state", e.state.as_str())
                        .set("wall_us", e.wall_us);
                    if let Some(v) = e.virtual_s {
                        o = o.set("virtual_s", v);
                    }
                    o
                })
                .collect(),
        )
    }
}

/// Multi-trial series for one experiment point (mean ± std across seeds).
#[derive(Debug, Clone)]
pub struct TrialSeries {
    pub label: String,
    pub ovh: Vec<f64>,
    pub th: Vec<f64>,
    pub tpt: Vec<f64>,
    pub ttx: Vec<f64>,
}

impl TrialSeries {
    pub fn new(label: impl Into<String>) -> TrialSeries {
        TrialSeries { label: label.into(), ovh: vec![], th: vec![], tpt: vec![], ttx: vec![] }
    }

    pub fn push_run(&mut self, m: &RunMetrics) {
        self.ovh.push(m.ovh.total_s());
        self.th.push(m.throughput_tps());
        self.tpt.push(m.tpt_s);
        self.ttx.push(m.ttx_s);
    }

    pub fn push_aggregate(&mut self, m: &AggregateMetrics) {
        self.ovh.push(m.ovh_s);
        self.th.push(m.th_tps);
        self.tpt.push(m.tpt_s);
        self.ttx.push(m.ttx_s);
    }

    pub fn ovh_summary(&self) -> Summary {
        Summary::of(&self.ovh)
    }

    pub fn th_summary(&self) -> Summary {
        Summary::of(&self.th)
    }

    pub fn tpt_summary(&self) -> Summary {
        Summary::of(&self.tpt)
    }

    pub fn ttx_summary(&self) -> Summary {
        Summary::of(&self.ttx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(provider: ProviderId, tasks: usize, ovh: f64, tpt: f64) -> RunMetrics {
        RunMetrics {
            provider,
            tasks,
            pods: tasks,
            ovh: Overhead { partition_s: ovh / 2.0, serialize_s: ovh / 2.0, submit_s: 0.0 },
            tpt_s: tpt,
            ttx_s: tpt,
        }
    }

    #[test]
    fn throughput_is_tasks_over_ovh() {
        let m = run(ProviderId::Aws, 1000, 2.0, 50.0);
        assert!((m.throughput_tps() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_sums_tasks_and_takes_max_windows() {
        // Exp 2: four concurrent providers, each processing 4000 tasks with
        // ~same OVH => aggregate TH ~ 4x the per-provider TH.
        let runs: Vec<RunMetrics> = ProviderId::CLOUDS
            .iter()
            .map(|&p| run(p, 4000, 2.0, 100.0))
            .collect();
        let agg = aggregate(&runs).unwrap();
        assert_eq!(agg.tasks, 16_000);
        assert!((agg.ovh_s - 2.0).abs() < 1e-9);
        let per = runs[0].throughput_tps();
        assert!((agg.th_tps / per - 4.0).abs() < 1e-9);
        assert!(aggregate(&[]).is_none());
    }

    #[test]
    fn trace_log_orders_and_filters() {
        let mut log = TraceLog::new();
        log.record(TaskId(1), TaskState::New);
        log.record(TaskId(2), TaskState::New);
        log.record_virtual(TaskId(1), TaskState::Done, Some(12.5));
        assert_eq!(log.len(), 3);
        let t1 = log.for_task(TaskId(1));
        assert_eq!(t1.len(), 2);
        assert!(t1[1].wall_us >= t1[0].wall_us);
        assert_eq!(t1[1].virtual_s, Some(12.5));
    }

    #[test]
    fn trace_json_exports_all_events() {
        let mut log = TraceLog::new();
        log.record(TaskId(7), TaskState::Running);
        let j = log.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("task").unwrap().as_u64(), Some(7));
        assert_eq!(arr[0].get("state").unwrap().as_str(), Some("RUNNING"));
    }

    #[test]
    fn trial_series_summaries() {
        let mut s = TrialSeries::new("4K/4");
        for i in 0..5 {
            s.push_run(&run(ProviderId::Azure, 4000, 1.0 + i as f64 * 0.1, 30.0));
        }
        assert_eq!(s.ovh_summary().n, 5);
        assert!(s.th_summary().mean > 0.0);
        assert!((s.tpt_summary().mean - 30.0).abs() < 1e-9);
    }

    #[test]
    fn run_report_combines_everything() {
        let runs = vec![run(ProviderId::Aws, 10, 1.0, 5.0), run(ProviderId::Azure, 10, 1.0, 6.0)];
        let agg = aggregate(&runs).unwrap();
        let mut log = TraceLog::new();
        log.record(TaskId(0), TaskState::New);
        let doc = run_report(&runs, &agg, Some(log.to_json()));
        assert_eq!(doc.at(&["per_provider"]).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(doc.at(&["aggregate", "tasks"]).unwrap().as_usize(), Some(20));
        assert_eq!(doc.at(&["trace"]).unwrap().as_arr().unwrap().len(), 1);
        // round-trips through the parser
        let text = doc.to_string_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
        // without trace, key absent
        let doc2 = run_report(&runs, &agg, None);
        assert!(doc2.get("trace").is_none());
    }

    #[test]
    fn run_metrics_json_shape() {
        let j = run(ProviderId::Jetstream2, 10, 1.0, 5.0).to_json();
        for key in ["provider", "tasks", "pods", "ovh_s", "th_tps", "tpt_s", "ttx_s"] {
            assert!(j.get(key).is_some(), "{key}");
        }
    }
}
