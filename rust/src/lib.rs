//! # Hydra — cloud/HPC brokering for heterogeneous workloads at scale
//!
//! A from-scratch reproduction of *"Hydra: Brokering Cloud and HPC
//! Resources to Support the Execution of Heterogeneous Workloads at
//! Scale"* (Alsaadi, Turilli, Jha, 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the broker: provider/service proxies, the
//!   open `ServiceManager` trait with CaaS/HPC/FaaS managers behind a
//!   single factory dispatch, MCPP/SCPP workload partitioning, bulk
//!   submission, monitoring/tracing, plus every platform substrate
//!   (Kubernetes sim, batch-queue/pilot sim, FaaS sim, Argo-like workflow
//!   engine) and a PJRT runtime that executes the FACTS science compute.
//! * **Layer 2 (python/compile/model.py)** — the FACTS sea-level steps as
//!   JAX functions, AOT-lowered to `artifacts/*.hlo.txt` at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   fit/projection hot spots, lowered into the same HLO.
//!
//! Python never runs on the request path: after `make artifacts`, the Rust
//! binary is self-contained. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod api;
pub mod broker;
pub mod facts;
pub mod lint;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workflow;
