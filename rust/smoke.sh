#!/usr/bin/env bash
# Tier-1 verify + perf smoke in one command (ISSUE 1 CI/tooling satellite):
#
#   ./smoke.sh
#
# Builds release, runs the test suite, then runs the bench_quick harness,
# which emits machine-readable BENCH_quick.json (the ROADMAP perf
# trajectory record) into this directory.
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--help" || "${1:-}" == "-h" ]]; then
  cat <<'USAGE'
usage: ./smoke.sh

Runs the tier-1 verify plus the perf smoke, in order:
  1. cargo build --release
  2. cargo test -q                           (includes the equivalence
     suites: sched_equivalence, pilot_equivalence, queue_equivalence —
     the calendar-vs-heap event-queue lock from ISSUE 8 — and
     json_equivalence, the ISSUE 10 tree-parser-vs-lazy-scanner lock)
  3. cargo run --release --bin hydra_lint    (ISSUE 9 determinism lint:
     wallclock / hash-order / prng-salt / unwrap / float-eq, gated
     against the ratcheted ci/lint_baseline.json; writes the untracked
     LINT_report.json, schema hydra-lint-report/v1. Suppress a site
     with '// hydra-lint: allow(<rule>) — <reason>'; after paying down
     baseline debt, re-ratchet with
     'cargo run --release --bin hydra_lint -- --refresh')
  4. cargo run --release --bin bench_quick   (writes BENCH_quick.json,
     schema hydra-bench-quick/v1 — the ROADMAP perf-trajectory record;
     includes the heap-vs-calendar queue rows on the 16K-pod point and
     the ISSUE 10 ingest microbench: lazy zero-alloc scan vs tree parse
     over the 4K-task framed payload, lazy >= tree bytes/s asserted)

Deliberately NOT run here: the bench_scale tier (100K/1M-pod points,
schema hydra-bench-scale/v1) — it takes minutes, so tier-1 stays fast.
Run it explicitly with 'cargo run --release --bin bench_scale', or let
the nightly/workflow_dispatch bench-scale CI job run the 100K point.

CI runs this same script: the smoke-bench job in
.github/workflows/ci.yml invokes ./smoke.sh, diffs the fresh
BENCH_quick.json against the committed BENCH_baseline.json via
./ci/bench_gate.sh (non-blocking for now), and uploads BENCH_quick.json
as a build artifact. Promote a measured run to the committed baseline
with: ./ci/bench_gate.sh --refresh
USAGE
  exit 0
fi

cargo build --release
cargo test -q
cargo run --release --bin hydra_lint
cargo run --release --bin bench_quick

echo
echo "smoke: OK (tier-1 green, lint gate clean, BENCH_quick.json written)"
