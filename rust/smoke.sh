#!/usr/bin/env bash
# Tier-1 verify + perf smoke in one command (ISSUE 1 CI/tooling satellite):
#
#   ./smoke.sh
#
# Builds release, runs the test suite, then runs the bench_quick harness,
# which emits machine-readable BENCH_quick.json (the ROADMAP perf
# trajectory record) into this directory.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo run --release --bin bench_quick

echo
echo "smoke: OK (tier-1 green, BENCH_quick.json written)"
