#!/usr/bin/env bash
# bench_gate.sh — perf-regression gate over the BENCH_quick trajectory
# (ISSUE 3 satellite; wired into .github/workflows/ci.yml as a
# non-blocking step until two PRs of trajectory data exist).
#
#   ./ci/bench_gate.sh [fresh.json] [baseline.json]   # compare (default:
#                                                     # BENCH_quick.json vs
#                                                     # BENCH_baseline.json)
#   ./ci/bench_gate.sh --refresh                      # promote the fresh
#                                                     # run to baseline
#
# Exit 1 when any row shared by both files regresses by more than
# BENCH_GATE_TOLERANCE (default 0.25 = 25%):
#   * events/s rows (sched microbench) must not drop;
#   * OVH and serialize_ms rows (broker points) must not rise.
# Rows present in only one of baseline/fresh (e.g. a bench point added by
# the current PR, like exp_faas_4k, exp_hpc_multipilot_4k, or this PR's
# exp_failover_4k) WARN but never fail the gate — the schema is expected
# to grow a row per PR, and adding a point must not trip the diff. Only
# shared-row regressions fail. A freshly added row therefore stays
# WARN-only until a measured run is promoted to the committed baseline
# with `./ci/bench_gate.sh --refresh`; from then on it gates like any
# other row (exp_failover_4k included, once a baseline carrying it
# lands).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--refresh" ]]; then
  cp BENCH_quick.json BENCH_baseline.json
  echo "bench_gate: baseline refreshed from BENCH_quick.json"
  exit 0
fi

fresh="${1:-BENCH_quick.json}"
base="${2:-BENCH_baseline.json}"
tol="${BENCH_GATE_TOLERANCE:-0.25}"

if [[ ! -f "$fresh" ]]; then
  echo "bench_gate: no fresh bench at $fresh (run ./smoke.sh first)" >&2
  exit 1
fi
if [[ ! -f "$base" ]]; then
  echo "bench_gate: no baseline at $base — skipping gate"
  exit 0
fi

python3 - "$fresh" "$base" "$tol" <<'PY'
import json
import sys

fresh_path, base_path, tol = sys.argv[1], sys.argv[2], float(sys.argv[3])
fresh = json.load(open(fresh_path))
base = json.load(open(base_path))

# A bad schema in the *fresh* file is a failure — otherwise a PR that
# breaks bench_quick's output silently disables the gate. Only a
# baseline-side mismatch (e.g. an old baseline after a schema bump) is
# a clean skip.
fresh_schema = fresh.get("schema")
if fresh_schema != "hydra-bench-quick/v1":
    print(f"bench_gate: {fresh_path}: unexpected schema {fresh_schema!r}; "
          "bench output is broken — failing the gate")
    sys.exit(1)
base_schema = base.get("schema")
if base_schema != "hydra-bench-quick/v1":
    print(f"bench_gate: {base_path}: baseline schema {base_schema!r} predates "
          "the current format; skipping gate (refresh the baseline)")
    sys.exit(0)


def rows(doc):
    """Flatten a bench document into {row_name: (value, higher_is_better)}."""
    out = {}
    for p in doc.get("points", []):
        name = p.get("name", "?")
        if isinstance(p.get("ovh_ms_mean"), (int, float)):
            out[f"{name}.ovh_ms"] = (p["ovh_ms_mean"], False)
        if isinstance(p.get("serialize_ms_mean"), (int, float)):
            out[f"{name}.serialize_ms"] = (p["serialize_ms_mean"], False)
    micro = doc.get("serialize_microbench") or {}
    if isinstance(micro.get("serialize_ms_parallel"), (int, float)):
        out["serialize_micro.parallel_ms"] = (micro["serialize_ms_parallel"], False)
    sched = doc.get("sched_microbench") or {}
    for kind in ("linear", "indexed"):
        eps = (sched.get(kind) or {}).get("events_per_s")
        if isinstance(eps, (int, float)):
            out[f"sched.{kind}.events_per_s"] = (eps, True)
    return out


fresh_rows, base_rows = rows(fresh), rows(base)
if not base_rows:
    print(f"bench_gate: {base_path} has no comparable rows (placeholder baseline); "
          "gate passes vacuously — refresh it from a measured run with "
          "'./ci/bench_gate.sh --refresh'")
    sys.exit(0)

failures = []
warnings = 0
for key in sorted(base_rows):
    old, higher_is_better = base_rows[key]
    if key not in fresh_rows:
        # Warn, never fail: a renamed/retired point must not block the PR
        # that retires it (the shared rows still gate regressions).
        print(f"bench_gate: WARN {key}: present in baseline only (row dropped?)")
        warnings += 1
        continue
    new = fresh_rows[key][0]
    if old <= 0:
        print(f"bench_gate: {key}: non-positive baseline {old}; skipped")
        continue
    change = (new - old) / old
    regressed = (change < -tol) if higher_is_better else (change > tol)
    status = "REGRESSED" if regressed else "ok"
    print(f"bench_gate: {key}: {old:.4g} -> {new:.4g} ({change:+.1%}) [{status}]")
    if regressed:
        failures.append(key)
for key in sorted(set(fresh_rows) - set(base_rows)):
    # Warn, never fail: new bench points (e.g. exp_faas_4k) enter the
    # baseline on the next --refresh.
    print(f"bench_gate: WARN {key}: new row (no baseline yet)")
    warnings += 1

if failures:
    print(f"bench_gate: FAIL — {len(failures)} row(s) regressed beyond "
          f"{tol:.0%}: {', '.join(failures)}")
    sys.exit(1)
suffix = f" ({warnings} unshared-row warning(s))" if warnings else ""
print(f"bench_gate: OK — no shared row regressed beyond {tol:.0%}{suffix}")
PY
