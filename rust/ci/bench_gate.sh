#!/usr/bin/env bash
# bench_gate.sh — perf-regression gate over the bench trajectory
# (ISSUE 3 satellite, extended by ISSUE 8 to the scale tier; wired into
# .github/workflows/ci.yml).
#
#   ./ci/bench_gate.sh [fresh.json] [baseline.json]
#       Compare fresh against baseline. Defaults: BENCH_quick.json vs
#       BENCH_baseline.json. The *fresh* file's schema selects the row
#       flattener — both the quick tier (hydra-bench-quick/v1) and the
#       scale tier (hydra-bench-scale/v1, from bench_scale) are
#       understood, so the nightly bench-scale CI job can gate with:
#         ./ci/bench_gate.sh BENCH_scale.json BENCH_scale_baseline.json
#   ./ci/bench_gate.sh --refresh [fresh.json] [baseline.json]
#       Promote the fresh run to the baseline (same defaults), e.g.:
#         ./ci/bench_gate.sh --refresh                  # quick tier
#         ./ci/bench_gate.sh --refresh BENCH_scale.json BENCH_scale_baseline.json
#
# Exit 1 when any row shared by both files regresses by more than
# BENCH_GATE_TOLERANCE (default 0.25 = 25%):
#   * events/s rows (sched microbench incl. queue_heap/queue_calendar,
#     and the scale points' heap/calendar) must not drop;
#   * OVH and serialize_ms rows (broker points) must not rise.
# Rows present in only one of baseline/fresh (e.g. a bench point added by
# the current PR) WARN but never fail the gate — the schema is expected
# to grow a row per PR, and adding a point must not trip the diff. Only
# shared-row regressions fail. A freshly added row therefore stays
# WARN-only until a measured run is promoted to the committed baseline
# with --refresh; from then on it gates like any other row. The ISSUE 10
# ingest_microbench rows (lazy/tree bytes-per-s in the quick tier, the
# per-point ingest bytes-per-s in the scale tier) follow exactly that
# policy: WARN-only until a measured baseline is promoted.
#
# Schema policy: a bad/unknown schema in the *fresh* file fails the gate
# (broken bench output must not silently disable gating); a baseline
# whose schema doesn't match the fresh file's (e.g. an old baseline after
# a schema bump, or no scale baseline committed yet) is a clean skip.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--refresh" ]]; then
  src="${2:-BENCH_quick.json}"
  dst="${3:-BENCH_baseline.json}"
  cp "$src" "$dst"
  echo "bench_gate: baseline $dst refreshed from $src"
  exit 0
fi

fresh="${1:-BENCH_quick.json}"
base="${2:-BENCH_baseline.json}"
tol="${BENCH_GATE_TOLERANCE:-0.25}"

if [[ ! -f "$fresh" ]]; then
  echo "bench_gate: no fresh bench at $fresh (run ./smoke.sh or bench_scale first)" >&2
  exit 1
fi
if [[ ! -f "$base" ]]; then
  echo "bench_gate: no baseline at $base — skipping gate"
  exit 0
fi

python3 - "$fresh" "$base" "$tol" <<'PY'
import json
import sys

fresh_path, base_path, tol = sys.argv[1], sys.argv[2], float(sys.argv[3])
fresh = json.load(open(fresh_path))
base = json.load(open(base_path))


def quick_rows(doc):
    """Flatten a quick-tier document into {row_name: (value, higher_is_better)}."""
    out = {}
    for p in doc.get("points", []):
        name = p.get("name", "?")
        if isinstance(p.get("ovh_ms_mean"), (int, float)):
            out[f"{name}.ovh_ms"] = (p["ovh_ms_mean"], False)
        if isinstance(p.get("serialize_ms_mean"), (int, float)):
            out[f"{name}.serialize_ms"] = (p["serialize_ms_mean"], False)
    micro = doc.get("serialize_microbench") or {}
    if isinstance(micro.get("serialize_ms_parallel"), (int, float)):
        out["serialize_micro.parallel_ms"] = (micro["serialize_ms_parallel"], False)
    ingest = doc.get("ingest_microbench") or {}
    for side in ("lazy", "tree"):
        bps = ingest.get(f"{side}_bytes_per_s")
        if isinstance(bps, (int, float)):
            out[f"ingest.{side}.bytes_per_s"] = (bps, True)
    sched = doc.get("sched_microbench") or {}
    for kind in ("linear", "indexed", "queue_heap", "queue_calendar"):
        eps = (sched.get(kind) or {}).get("events_per_s")
        if isinstance(eps, (int, float)):
            out[f"sched.{kind}.events_per_s"] = (eps, True)
    return out


def scale_rows(doc):
    """Flatten a scale-tier document (bench_scale's BENCH_scale.json)."""
    out = {}
    for p in doc.get("points", []):
        name = p.get("name", "?")
        for kind in ("heap", "calendar"):
            eps = (p.get(kind) or {}).get("events_per_s")
            if isinstance(eps, (int, float)):
                out[f"{name}.{kind}.events_per_s"] = (eps, True)
        bps = (p.get("ingest") or {}).get("bytes_per_s")
        if isinstance(bps, (int, float)):
            out[f"{name}.ingest.bytes_per_s"] = (bps, True)
    return out


FLATTENERS = {
    "hydra-bench-quick/v1": quick_rows,
    "hydra-bench-scale/v1": scale_rows,
}

# A bad schema in the *fresh* file is a failure — otherwise a PR that
# breaks the bench output silently disables the gate. Only a
# baseline-side mismatch (e.g. an old baseline after a schema bump, or
# no scale baseline committed yet) is a clean skip.
fresh_schema = fresh.get("schema")
if fresh_schema not in FLATTENERS:
    print(f"bench_gate: {fresh_path}: unexpected schema {fresh_schema!r}; "
          "bench output is broken — failing the gate")
    sys.exit(1)
base_schema = base.get("schema")
if base_schema != fresh_schema:
    print(f"bench_gate: {base_path}: baseline schema {base_schema!r} does not "
          f"match fresh {fresh_schema!r}; skipping gate (refresh the baseline)")
    sys.exit(0)

rows = FLATTENERS[fresh_schema]
fresh_rows, base_rows = rows(fresh), rows(base)
if not base_rows:
    print(f"bench_gate: {base_path} has no comparable rows (placeholder baseline); "
          "gate passes vacuously — refresh it from a measured run with "
          "'./ci/bench_gate.sh --refresh'")
    sys.exit(0)

failures = []
warnings = 0
for key in sorted(base_rows):
    old, higher_is_better = base_rows[key]
    if key not in fresh_rows:
        # Warn, never fail: a renamed/retired point must not block the PR
        # that retires it (the shared rows still gate regressions).
        print(f"bench_gate: WARN {key}: present in baseline only (row dropped?)")
        warnings += 1
        continue
    new = fresh_rows[key][0]
    if old <= 0:
        print(f"bench_gate: {key}: non-positive baseline {old}; skipped")
        continue
    change = (new - old) / old
    regressed = (change < -tol) if higher_is_better else (change > tol)
    status = "REGRESSED" if regressed else "ok"
    print(f"bench_gate: {key}: {old:.4g} -> {new:.4g} ({change:+.1%}) [{status}]")
    if regressed:
        failures.append(key)
for key in sorted(set(fresh_rows) - set(base_rows)):
    # Warn, never fail: new bench points (e.g. this PR's queue rows)
    # enter the baseline on the next --refresh.
    print(f"bench_gate: WARN {key}: new row (no baseline yet)")
    warnings += 1

if failures:
    print(f"bench_gate: FAIL — {len(failures)} row(s) regressed beyond "
          f"{tol:.0%}: {', '.join(failures)}")
    sys.exit(1)
suffix = f" ({warnings} unshared-row warning(s))" if warnings else ""
print(f"bench_gate: OK — no shared row regressed beyond {tol:.0%}{suffix}")
PY
