//! ISSUE 5 acceptance: multi-pilot HPC scheduling is locked to the serial
//! pilot-lifecycle reference.
//!
//! * `MultiPilotSim` with `pilots = 1` must produce **byte-identical**
//!   `HpcTaskRecord`s to `HpcSim` (the serial reference kept the way
//!   `SchedulerKind::LinearScan` anchors the Kubernetes scheduler) —
//!   checked across 3 fixed seeds × task counts {0, 1, 4096}, down to
//!   the f64 bit patterns.
//! * For `pilots ∈ {2, 8}` the fleet must complete exactly the submitted
//!   task set (same records per seed in any order — the runs themselves
//!   are deterministic), with every task on exactly one pilot.
//! * The production path (`HpcManager`, which always runs the multi-pilot
//!   scheduler) must reproduce the reference records end to end when
//!   `pilots = 1`.
//! * `FaultSpec::none()` is a true no-op (ISSUE 6): the fault machinery
//!   draws nothing and schedules nothing, so the P ∈ {1, 4} schedules
//!   stay byte-identical to the fault-free runs.
//! * `ProviderFaultSpec::none()` + the default `RetryPolicy` are a true
//!   no-op too (ISSUE 7): the fallible provider endpoint constructs no
//!   PRNG, backs off zero seconds, and leaves the circuit breaker
//!   untouched, so the manager path stays byte-identical to the
//!   pre-fault broker.

use hydra::api::task::{Payload, TaskDescription, TaskId};
use hydra::api::{ProviderConfig, ResourceRequest};
use hydra::broker::hpc::{pilot_specs, HpcManager};
use hydra::broker::state::TaskRegistry;
use hydra::broker::{ProviderFaultSpec, RetryPolicy};
use hydra::sim::hpc::{FaultSpec, HpcSim, HpcTaskSpec, MultiPilotSim, PilotSpec};
use hydra::sim::provider::{PlatformProfile, ProviderId};

const SEEDS: [u64; 3] = [11, 0xBEEF, 0x5EED5];
const COUNTS: [usize; 3] = [0, 1, 4096];

fn b2() -> PlatformProfile {
    PlatformProfile::of(ProviderId::Bridges2)
}

/// Heterogeneous pilot workload: mixed widths (including oversized tasks
/// that exercise the clamp), payload kinds, and durations.
fn workload(n: usize) -> Vec<HpcTaskSpec> {
    (0..n)
        .map(|i| {
            let cores = match i % 7 {
                0 => 1,
                1 => 4,
                2 => 16,
                3 => 32,
                4 => 128,
                5 => 300, // wider than any pilot in these tests: clamps
                _ => 2,
            };
            HpcTaskSpec {
                task_id: i as u64,
                cores,
                work_s: (i % 5) as f64 * 7.5,
                sleep_s: if i % 3 == 0 { 0.25 } else { 0.0 },
            }
        })
        .collect()
}

fn run_serial(tasks: Vec<HpcTaskSpec>, nodes: u32, seed: u64) -> hydra::sim::hpc::HpcReport {
    let mut sim = HpcSim::new(b2(), PilotSpec { nodes }, seed);
    sim.submit(tasks);
    sim.run()
}

fn run_multi(
    tasks: Vec<HpcTaskSpec>,
    nodes: u32,
    pilots: u32,
    seed: u64,
) -> hydra::sim::hpc::MultiPilotReport {
    let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes }, pilots, seed);
    sim.submit(tasks);
    sim.run()
}

#[test]
fn pilots_1_matches_serial_reference_byte_for_byte() {
    for &seed in &SEEDS {
        for &n in &COUNTS {
            let tasks = workload(n);
            let serial = run_serial(tasks.clone(), 2, seed);
            let multi = run_multi(tasks, 2, 1, seed);
            assert_eq!(serial.tasks.len(), n, "seed={seed} n={n}");
            assert_eq!(serial.tasks, multi.tasks, "seed={seed} n={n}");
            // Exact equality above already forbids -0.0/0.0 and NaN
            // mismatches for these records; the bit-pattern check makes
            // "byte-identical" literal.
            for (a, b) in serial.tasks.iter().zip(&multi.tasks) {
                assert_eq!(a.task_id, b.task_id);
                assert_eq!(a.launched_s.to_bits(), b.launched_s.to_bits());
                assert_eq!(a.finished_s.to_bits(), b.finished_s.to_bits());
                assert_eq!(a.failed, b.failed);
            }
            assert_eq!(serial.makespan_s.to_bits(), multi.makespan_s.to_bits());
            assert_eq!(serial.events_processed, multi.events_processed);
            assert_eq!(multi.pilots.len(), 1);
            assert_eq!(
                serial.queue_wait_s.to_bits(),
                multi.pilots[0].queue_wait_s.to_bits()
            );
            assert_eq!(
                serial.agent_ready_s.to_bits(),
                multi.pilots[0].agent_ready_s.to_bits()
            );
            assert_eq!(serial.peak_cores_busy, multi.pilots[0].peak_cores_busy);
        }
    }
}

#[test]
fn pilots_1_matches_serial_reference_under_failure_injection() {
    // The failure-flag PRNG draws must line up too.
    for &seed in &SEEDS {
        let tasks = workload(500);
        let mut a = HpcSim::new(b2(), PilotSpec { nodes: 1 }, seed).with_failure_rate(0.07);
        a.submit(tasks.clone());
        let serial = a.run();
        let mut b = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, 1, seed)
            .with_failure_rate(0.07);
        b.submit(tasks);
        let multi = b.run();
        assert_eq!(serial.tasks, multi.tasks, "seed={seed}");
        assert!(serial.tasks.iter().any(|t| t.failed), "injection must fire");
    }
}

#[test]
fn multi_pilot_completes_the_same_records_any_order() {
    for pilots in [2u32, 8] {
        for &seed in &SEEDS {
            let n = 4096;
            let tasks = workload(n);
            let multi = run_multi(tasks.clone(), 1, pilots, seed);

            // Completion-set equality against the submitted set: every
            // task appears exactly once, none invented.
            let mut ids: Vec<u64> = multi.tasks.iter().map(|t| t.task_id).collect();
            ids.sort_unstable();
            let want: Vec<u64> = (0..n as u64).collect();
            assert_eq!(ids, want, "pilots={pilots} seed={seed}");

            // ...and against the serial reference's completion set.
            let serial = run_serial(tasks.clone(), 1, seed);
            let mut serial_ids: Vec<u64> = serial.tasks.iter().map(|t| t.task_id).collect();
            serial_ids.sort_unstable();
            assert_eq!(ids, serial_ids, "pilots={pilots} seed={seed}");

            // Records are internally consistent and deterministic.
            for t in &multi.tasks {
                assert!(t.finished_s >= t.launched_s);
                assert!(t.launched_s >= multi.first_agent_ready_s());
            }
            assert_eq!(multi.pilot_of.len(), n);
            assert!(multi.pilot_of.iter().all(|&p| (p as usize) < pilots as usize));
            let again = run_multi(tasks, 1, pilots, seed);
            assert_eq!(multi.tasks, again.tasks, "pilots={pilots} seed={seed}");
            assert_eq!(multi.pilot_of, again.pilot_of, "pilots={pilots} seed={seed}");
        }
    }
}

#[test]
fn fault_spec_none_is_a_true_noop() {
    // ISSUE 6 acceptance: with `FaultSpec::none()` the fault machinery
    // must consume nothing — no PRNG draws, no extra events — so the
    // schedule stays byte-identical to the fault-free run for P ∈ {1, 4}
    // across 3 seeds, down to the f64 bit patterns.
    for &seed in &SEEDS {
        for pilots in [1u32, 4] {
            let n = 2048;
            let plain = run_multi(workload(n), 1, pilots, seed);
            let mut sim = MultiPilotSim::uniform(b2(), PilotSpec { nodes: 1 }, pilots, seed)
                .with_faults(FaultSpec::none());
            sim.submit(workload(n));
            let faultless = sim.run();

            assert_eq!(plain.tasks.len(), faultless.tasks.len(), "seed={seed} P={pilots}");
            for (a, b) in plain.tasks.iter().zip(&faultless.tasks) {
                assert_eq!(a.task_id, b.task_id, "seed={seed} P={pilots}");
                assert_eq!(a.launched_s.to_bits(), b.launched_s.to_bits());
                assert_eq!(a.finished_s.to_bits(), b.finished_s.to_bits());
                assert_eq!(a.failed, b.failed);
            }
            assert_eq!(plain.pilot_of, faultless.pilot_of, "seed={seed} P={pilots}");
            assert_eq!(
                plain.makespan_s.to_bits(),
                faultless.makespan_s.to_bits(),
                "seed={seed} P={pilots}"
            );
            assert_eq!(plain.events_processed, faultless.events_processed);
            assert!(faultless.abandoned.is_empty(), "seed={seed} P={pilots}");
            assert!(faultless.retry_waves.is_empty(), "seed={seed} P={pilots}");
            assert!(faultless.pilots.iter().all(|p| p.died_at.is_none() && p.materialized));
        }
    }
}

/// The mixed-payload executable workload the manager-path tests share,
/// registered into `reg`.
fn step_tasks(reg: &TaskRegistry) -> Vec<(TaskId, TaskDescription)> {
    (0..600)
        .map(|i| {
            let d = TaskDescription::executable(format!("e{i}"), "/bin/step")
                .with_cpus(1 + (i as u32 % 8))
                .with_payload(match i % 3 {
                    0 => Payload::Noop,
                    1 => Payload::Sleep(1.5),
                    _ => Payload::Work(40.0),
                });
            (reg.register(d.clone()), d)
        })
        .collect()
}

#[test]
fn manager_pilots_1_reproduces_the_reference_end_to_end() {
    // The production path: HpcManager always drives the multi-pilot
    // scheduler; with pilots = 1 its records must be the serial
    // reference's, byte for byte, through validation, sharded
    // serialization, and submission.
    let seed = 11u64;
    let reg = TaskRegistry::new();
    let tasks = step_tasks(&reg);
    let manager = HpcManager::new(
        ProviderConfig::simulated(ProviderId::Bridges2),
        ResourceRequest::pilot(ProviderId::Bridges2, 2),
        seed,
    )
    .unwrap();
    let run = manager.execute(&tasks, &reg).unwrap();
    let got = &run.detail.hpc_sim().unwrap().tasks;

    let mut reference = HpcSim::new(b2(), PilotSpec { nodes: 2 }, seed);
    reference.submit(pilot_specs(&tasks));
    let want = reference.run().tasks;
    assert_eq!(got, &want, "manager path diverged from the serial reference");
    assert!(reg.all_final());
}

#[test]
fn provider_fault_spec_none_is_a_true_noop() {
    // ISSUE 7 acceptance: an explicit `ProviderFaultSpec::none()` +
    // default `RetryPolicy` must be indistinguishable from the manager
    // with untouched defaults (the PR 6 broker) — no fault PRNG, zero
    // backoff, no breaker activity — and both must still reproduce the
    // raw serial reference, down to the f64 bit patterns.
    let seed = 11u64;
    let run_manager = |explicit: bool| {
        let reg = TaskRegistry::new();
        let tasks = step_tasks(&reg);
        let mut req = ResourceRequest::pilot(ProviderId::Bridges2, 2);
        if explicit {
            req = req
                .with_provider_faults(ProviderFaultSpec::none())
                .with_retry_policy(RetryPolicy::default());
        }
        let manager =
            HpcManager::new(ProviderConfig::simulated(ProviderId::Bridges2), req, seed).unwrap();
        let run = manager.execute(&tasks, &reg).unwrap();
        assert!(reg.all_final());
        assert!(manager.breaker.allow(), "healthy path must leave the breaker closed");
        assert_eq!(manager.breaker.opens(), 0);
        run
    };
    let defaulted = run_manager(false);
    let explicit = run_manager(true);

    // The ISSUE 7 resilience counters are structurally zero when healthy.
    for run in [&defaulted, &explicit] {
        assert_eq!(run.faults.submit_retries, 0);
        assert_eq!(run.faults.backoff_ms, 0);
        assert_eq!(run.faults.circuit_opens, 0);
        assert_eq!(run.faults.failed_over, 0);
    }
    assert_eq!(defaulted.bytes_serialized, explicit.bytes_serialized);
    assert_eq!(defaulted.bulk_bytes, explicit.bulk_bytes);

    let a = &defaulted.detail.hpc_sim().unwrap().tasks;
    let b = &explicit.detail.hpc_sim().unwrap().tasks;
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.task_id, y.task_id);
        assert_eq!(x.launched_s.to_bits(), y.launched_s.to_bits());
        assert_eq!(x.finished_s.to_bits(), y.finished_s.to_bits());
        assert_eq!(x.failed, y.failed);
    }

    // Anchor both against the raw serial reference (pilots = 1 shape):
    // the fallible endpoint did not perturb the schedule at all.
    let reg = TaskRegistry::new();
    let tasks = step_tasks(&reg);
    let mut reference = HpcSim::new(b2(), PilotSpec { nodes: 2 }, seed);
    reference.submit(pilot_specs(&tasks));
    let want = reference.run().tasks;
    assert_eq!(a.len(), want.len());
    for (x, y) in a.iter().zip(want.iter()) {
        assert_eq!(x.task_id, y.task_id);
        assert_eq!(x.launched_s.to_bits(), y.launched_s.to_bits());
        assert_eq!(x.finished_s.to_bits(), y.finished_s.to_bits());
        assert_eq!(x.failed, y.failed);
    }
}
