//! Integration: the indexed scheduler is observably identical to the
//! seed's linear scan, end to end through the public API.
//!
//! The unit tests in `sim::kubernetes` prove record-level equivalence at
//! the simulator layer; here we drive the same guarantee from outside the
//! crate — the surface `bench_quick` and downstream users rely on — and
//! check the Arc-shared broker data path produces identical platform
//! outcomes to the owned-description path.

use hydra::api::task::{TaskDescription, TaskId};
use hydra::broker::partitioner::{PartitionModel, Partitioner, PodBuildMode};
use hydra::broker::state::TaskRegistry;
use hydra::sim::kubernetes::{ClusterSpec, KubernetesSim, PodSpec, SchedulerKind};
use hydra::sim::provider::{PlatformProfile, ProviderId};
use std::sync::Arc;

fn workload(n: usize) -> Vec<(TaskId, TaskDescription)> {
    (0..n)
        .map(|i| {
            let t = TaskDescription::container(format!("t{i}"), "noop:latest")
                .with_cpus(1 + (i as u32 % 3))
                .with_mem_mb(128 + (i as u64 % 5) * 512);
            (TaskId(i as u64), t)
        })
        .collect()
}

fn partitioned_pods(tasks: &[(TaskId, TaskDescription)], cluster: &ClusterSpec) -> Vec<PodSpec> {
    Partitioner::new(PartitionModel::Mcpp { max_cpp: 8 }, PodBuildMode::Memory)
        .partition(tasks, cluster, 0)
        .unwrap()
}

fn run(kind: SchedulerKind, cluster: ClusterSpec, pods: Vec<PodSpec>, seed: u64)
    -> hydra::sim::kubernetes::SimReport
{
    let profile = PlatformProfile::of(ProviderId::Aws);
    let mut sim = KubernetesSim::new(profile, cluster, seed).with_scheduler(kind);
    sim.submit(pods, 0.0);
    sim.run()
}

#[test]
fn indexed_equals_linear_on_partitioned_1k_workload() {
    // 1K tasks through the real partitioner, then both schedulers: the
    // acceptance equivalence at integration scale.
    let cluster = ClusterSpec::uniform(16, 16);
    let tasks = workload(1000);
    let a = run(SchedulerKind::Indexed, cluster, partitioned_pods(&tasks, &cluster), 2024);
    let b = run(SchedulerKind::LinearScan, cluster, partitioned_pods(&tasks, &cluster), 2024);
    assert_eq!(a.tasks.len(), 1000);
    assert_eq!(a.tasks, b.tasks, "TaskRecord streams diverged");
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.makespan_s, b.makespan_s);
}

#[test]
fn free_capacity_restored_after_multi_batch_run() {
    let cluster = ClusterSpec::uniform(4, 8);
    let tasks = workload(200);
    let profile = PlatformProfile::of(ProviderId::Azure);
    let mut sim = KubernetesSim::new(profile, cluster, 5);
    let pods = partitioned_pods(&tasks, &cluster);
    let half = pods.len() / 2;
    let mut first = pods;
    let second = first.split_off(half);
    sim.submit(first, 0.0);
    sim.submit(second, 3.0);
    let r = sim.run();
    assert_eq!(r.tasks.len(), 200);
    assert_eq!(
        sim.free_capacity(),
        (
            cluster.nodes * cluster.vcpus_per_node,
            cluster.nodes * cluster.gpus_per_node,
            cluster.nodes as u64 * cluster.mem_mb_per_node,
        ),
        "teardown must return every reservation to the index"
    );
}

#[test]
fn arc_shared_descriptions_match_owned_through_caas() {
    // The Arc data path (registry-shared handles) must be observationally
    // identical to owned descriptions: same pods, same manifests bytes,
    // same virtual timings.
    use hydra::api::ProviderConfig;
    use hydra::api::ResourceRequest;
    use hydra::broker::caas::CaasManager;

    let mk_manager = || {
        CaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, 1, 16),
            Partitioner::new(PartitionModel::Mcpp { max_cpp: 16 }, PodBuildMode::Memory),
            31,
        )
        .unwrap()
    };

    // Owned path.
    let reg_a = TaskRegistry::new();
    let owned: Vec<(TaskId, TaskDescription)> = (0..120)
        .map(|i| {
            let d = TaskDescription::container(format!("t{i}"), "noop:latest");
            (reg_a.register(d.clone()), d)
        })
        .collect();
    let ra = mk_manager().execute(&owned, &reg_a).unwrap();

    // Shared path: register, then resolve Arc handles in bulk.
    let reg_b = TaskRegistry::new();
    let ids = reg_b.register_all(
        (0..120)
            .map(|i| TaskDescription::container(format!("t{i}"), "noop:latest"))
            .collect(),
    );
    let shared: Vec<(TaskId, Arc<TaskDescription>)> = ids
        .iter()
        .copied()
        .zip(reg_b.descriptions_of(&ids).unwrap())
        .collect();
    let rb = mk_manager().execute(&shared, &reg_b).unwrap();

    assert_eq!(ra.metrics.pods, rb.metrics.pods);
    assert_eq!(ra.bytes_serialized, rb.bytes_serialized);
    let (sim_a, sim_b) = (ra.detail.caas_sim().unwrap(), rb.detail.caas_sim().unwrap());
    assert_eq!(sim_a.tasks.len(), sim_b.tasks.len());
    // Same seed + same pods => identical virtual timelines.
    assert_eq!(sim_a.makespan_s, sim_b.makespan_s);
    assert_eq!(sim_a.events_processed, sim_b.events_processed);
    assert!(reg_a.all_final() && reg_b.all_final());
}
