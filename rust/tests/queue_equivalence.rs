//! Calendar vs heap event-queue equivalence (ISSUE 8 tentpole lock).
//!
//! `sim::event::EventQueue` can run on two backing stores
//! (`EventQueueKind::Calendar`, the O(1)-amortized default, and
//! `EventQueueKind::Heap`, the reference). Every simulator inherits the
//! queue through the shared API, so the *entire* platform layer is only
//! as deterministic as the queues are identical. This suite drives both
//! backends through the same operation scripts and asserts byte-identical
//! behavior at every step: pop order (`(time, seq)` — including
//! same-timestamp ties and past-clamping), the virtual clock, the
//! processed counter, and the queue length.
//!
//! Run by name as its own CI tier-1 step (like `pilot_equivalence`):
//! `cargo test -q --test queue_equivalence`.

use hydra::sim::event::{EventQueue, EventQueueKind, SimTime, SECONDS};
use hydra::util::prng::Prng;

/// One scripted queue operation. Times are absolute so that scripts can
/// deliberately schedule into the past (the wrapper clamps to `now` —
/// identically for both backends, which the trace compare proves).
#[derive(Debug, Clone, Copy)]
enum Op {
    Schedule { at: SimTime },
    ScheduleIn { delay: SimTime },
    Pop,
}

/// Drive both backends through `ops` in lockstep, asserting identical
/// observable state after every operation, then drain both to empty.
/// Returns how many events were popped (for sanity asserts by callers).
fn assert_equivalent(ops: &[Op]) -> usize {
    let mut heap = EventQueue::with_kind(EventQueueKind::Heap);
    let mut cal = EventQueue::with_kind(EventQueueKind::Calendar);
    let mut id = 0u64;
    let mut popped = 0usize;
    for (step, op) in ops.iter().enumerate() {
        match *op {
            Op::Schedule { at } => {
                heap.schedule_at(at, id);
                cal.schedule_at(at, id);
                id += 1;
            }
            Op::ScheduleIn { delay } => {
                heap.schedule_in(delay, id);
                cal.schedule_in(delay, id);
                id += 1;
            }
            Op::Pop => {
                let (h, c) = (heap.pop(), cal.pop());
                assert_eq!(h, c, "step {step}: pop diverged");
                if h.is_some() {
                    popped += 1;
                }
            }
        }
        assert_eq!(heap.now(), cal.now(), "step {step}: clock diverged");
        assert_eq!(heap.len(), cal.len(), "step {step}: length diverged");
        assert_eq!(heap.processed(), cal.processed(), "step {step}: processed diverged");
        // next_time is O(buckets) on the calendar side — sample it
        // rather than paying the scan on every step of the big scripts.
        if step % 997 == 0 || heap.len() < 4 {
            assert_eq!(heap.next_time(), cal.next_time(), "step {step}: peek diverged");
        }
    }
    loop {
        let (h, c) = (heap.pop(), cal.pop());
        assert_eq!(h, c, "drain: pop diverged");
        match h {
            Some(_) => popped += 1,
            None => break,
        }
    }
    assert!(heap.is_empty() && cal.is_empty());
    assert_eq!(heap.now(), cal.now());
    assert_eq!(heap.processed(), cal.processed());
    popped
}

/// Random interleaved schedule/pop script. `horizon` spreads the times;
/// `quantize` > 0 snaps times onto that grid to mass-produce ties.
fn random_ops(seed: u64, n_events: usize, horizon: u64, quantize: u64, pop_bias: f64) -> Vec<Op> {
    let mut rng = Prng::new(seed);
    let mut ops = Vec::with_capacity(n_events * 2);
    let mut scheduled = 0usize;
    while scheduled < n_events {
        if rng.uniform() < pop_bias {
            ops.push(Op::Pop);
        } else {
            let mut at = rng.range_u64(0, horizon.max(1));
            if quantize > 0 {
                at -= at % quantize;
            }
            // Absolute times drawn uniformly: once pops have advanced the
            // clock, low draws land in the past and exercise the clamp.
            ops.push(Op::Schedule { at });
            scheduled += 1;
        }
    }
    ops
}

#[test]
fn empty_queues_agree() {
    assert_eq!(assert_equivalent(&[Op::Pop, Op::Pop, Op::Pop]), 0);
}

#[test]
fn single_event() {
    let n = assert_equivalent(&[Op::Schedule { at: 42 }, Op::Pop, Op::Pop]);
    assert_eq!(n, 1);
}

#[test]
fn randomized_interleaved_schedules() {
    // Several seeds x shapes: dense micro-horizons (heavy ties +
    // clamping), second-scale horizons (the simulators' regime), and a
    // huge sparse horizon (forces the calendar's direct-search path).
    for seed in [1u64, 7, 0xBEEF, 0xD00D5EED] {
        for (horizon, quantize) in [
            (100, 0),
            (100, 16),
            (10 * SECONDS, 0),
            (3_600 * SECONDS, 1_000_000),
            (u64::MAX / 4, 0),
        ] {
            let ops = random_ops(seed, 10_000, horizon, quantize, 0.45);
            let n = assert_equivalent(&ops);
            assert_eq!(n, 10_000, "seed {seed} horizon {horizon}: events lost");
        }
    }
}

#[test]
fn hundred_k_events_schedule_then_drain() {
    // Bulk load 100K events (multiple calendar rebuilds), then drain —
    // plus a second pass fully interleaved.
    let mut rng = Prng::new(99);
    let mut ops: Vec<Op> = (0..100_000)
        .map(|_| Op::Schedule { at: rng.range_u64(0, 3_600 * SECONDS) })
        .collect();
    assert_eq!(assert_equivalent(&ops), 100_000);

    ops = random_ops(0xCA1E_17DA, 100_000, 3_600 * SECONDS, 0, 0.48);
    assert_eq!(assert_equivalent(&ops), 100_000);
}

#[test]
fn mass_ties_at_one_instant() {
    // 20K events at the same timestamp: pure seq-order FIFO, the
    // worst case for a bucketed store (everything lands in one day).
    let mut ops: Vec<Op> = (0..20_000).map(|_| Op::Schedule { at: 5 * SECONDS }).collect();
    ops.extend((0..20_000).map(|_| Op::Pop));
    assert_eq!(assert_equivalent(&ops), 20_000);
}

#[test]
fn past_clamping_preserves_insertion_order() {
    // Jump the clock forward, then schedule a burst of already-elapsed
    // times: all clamp to `now` and must pop in insertion order on both
    // backends (their relative order is the seq tie-break).
    let mut ops = vec![Op::Schedule { at: 1_000_000 }, Op::Pop];
    ops.extend((0..1_000u64).map(|i| Op::Schedule { at: i % 17 }));
    ops.extend((0..500).map(|_| Op::Pop));
    ops.extend((0..100u64).map(|i| Op::Schedule { at: i }));
    assert_eq!(assert_equivalent(&ops), 1_101);
}

#[test]
fn sparse_jumps_and_descending_inserts() {
    // Widely-spaced events inserted in descending time order: the
    // calendar cursor can never ride a dense day; every pop crosses a
    // huge gap (direct-search fallback) and inserts always land before
    // the cursor's bucket position.
    let mut ops: Vec<Op> = (0..512u64)
        .rev()
        .map(|i| Op::Schedule { at: i * 7_919 * SECONDS })
        .collect();
    ops.extend((0..512).map(|_| Op::Pop));
    assert_eq!(assert_equivalent(&ops), 512);
}

#[test]
fn pop_heavy_drain_phases_shrink_and_refill() {
    // Fill, drain almost dry (forcing calendar shrink rebuilds), refill,
    // repeat — the resize hysteresis must never change ordering.
    let mut rng = Prng::new(0x5ca1e);
    let mut ops = Vec::new();
    let mut events = 0usize;
    for phase in 0..6 {
        let fill = 4_000 + phase * 1_000;
        for _ in 0..fill {
            ops.push(Op::Schedule { at: rng.range_u64(0, 600 * SECONDS) });
            events += 1;
        }
        for _ in 0..(fill - 50) {
            ops.push(Op::Pop);
        }
    }
    assert_eq!(assert_equivalent(&ops), events);
}

#[test]
fn relative_scheduling_matches() {
    // schedule_in goes through the shared wrapper arithmetic; mix it
    // with absolute times and pops.
    let mut rng = Prng::new(0xde1a);
    let mut ops = Vec::new();
    for i in 0..5_000u64 {
        match i % 4 {
            0 => ops.push(Op::ScheduleIn { delay: rng.range_u64(0, 2 * SECONDS) }),
            1 => ops.push(Op::Schedule { at: rng.range_u64(0, 60 * SECONDS) }),
            2 => ops.push(Op::ScheduleIn { delay: 0 }),
            _ => ops.push(Op::Pop),
        }
    }
    assert_equivalent(&ops);
}
