//! Differential equivalence suite for the two JSON readers (ISSUE 10).
//!
//! The crate now has two independent implementations of RFC 8259:
//!
//! * `util::json` — the recursive-descent **tree parser** (allocates a
//!   `Json` document), paired with the writer;
//! * `util::json_scan` — the non-recursive, zero-alloc **lazy scanner**
//!   used on the provider-ingest hot path.
//!
//! Two readers that disagree are a liability: an ack the manager's
//! scanner accepts but the provider's tree parser would reject (or vice
//! versa) turns into a phantom `AckMismatch`. This suite pins the two
//! together with seeded differential property tests:
//!
//! * every document the writer emits re-parses to the identical tree;
//! * tree parser and scanner agree on accept/reject — for well-formed
//!   documents *and* for random byte-level mutations of them;
//! * values extracted lazily (`path_str`/`path_u64`/`path_f64`) match a
//!   full tree walk;
//! * the shared strict-number vectors agree in both directions;
//! * and a source-level check that the scanner's non-test code stays
//!   allocation-free by construction (no `String`/`Vec`/`format!`/...),
//!   in the spirit of `hydra-lint`.

use hydra::util::json::{parse, Json, MAX_DEPTH};
use hydra::util::json_scan::{JsonScanner, NUMBER_ACCEPT, NUMBER_REJECT};
use hydra::util::prop::{forall_seeded, Gen};

/// A random JSON document of bounded depth. Numbers are arbitrary finite
/// f64s: the writer prints the shortest representation that round-trips
/// exactly, so tree equality after re-parsing is exact, not approximate.
fn gen_doc(g: &mut Gen, depth: usize) -> Json {
    let scalar = depth == 0 || g.size < 5;
    match if scalar { g.usize(0, 3) } else { g.usize(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => {
            let mag = g.f64(-1e9, 1e9);
            // Mix integral and fractional values: the writer has two
            // formatting paths (push_i64 vs fmt) and both must re-parse.
            Json::Num(if g.bool() { mag.trunc() } else { mag })
        }
        3 => Json::Str(g.string(12)),
        4 => Json::Arr(g.vec(0, 4, |g| gen_doc(g, depth - 1))),
        _ => {
            let n = g.usize(0, 3);
            Json::Obj((0..n).map(|i| (format!("k{i}-{}", g.string(4)), gen_doc(g, depth - 1))).collect())
        }
    }
}

#[test]
fn writer_output_always_reparses_identically() {
    forall_seeded("write -> tree-parse is the identity", 0x10DE_CAFE, 300, |g| {
        let doc = gen_doc(g, 4);
        let text = doc.to_string_compact();
        let back = parse(&text).unwrap_or_else(|e| panic!("writer emitted unparseable {text:?}: {e:?}"));
        assert_eq!(doc, back, "round-trip changed the document: {text:?}");
    });
}

#[test]
fn tree_parser_and_scanner_agree_on_wellformed_docs() {
    forall_seeded("tree accept == scanner accept (well-formed)", 0x5CA_11ED, 300, |g| {
        let doc = gen_doc(g, 4);
        let text = doc.to_string_compact();
        assert!(parse(&text).is_ok(), "tree rejected writer output {text:?}");
        if let Err(e) = JsonScanner::new(text.as_bytes()).validate() {
            panic!("scanner rejected writer output {text:?}: {e}");
        }
    });
}

#[test]
fn tree_parser_and_scanner_agree_on_mutated_docs() {
    // Generated docs are pure ASCII (the prop-string alphabet), so
    // byte-level mutations with printable ASCII keep the input valid
    // UTF-8 and both readers see exactly the same document. The property
    // is *agreement*, not rejection: a mutation may well stay
    // well-formed.
    forall_seeded("tree accept == scanner accept (mutated)", 0xBAD_B17E, 400, |g| {
        let doc = gen_doc(g, 3);
        let mut bytes = doc.to_string_compact().into_bytes();
        match g.usize(0, 2) {
            0 => {
                // Truncate.
                let at = g.usize(0, bytes.len());
                bytes.truncate(at);
            }
            1 => {
                // Overwrite one byte with printable ASCII.
                if !bytes.is_empty() {
                    let at = g.usize(0, bytes.len() - 1);
                    bytes[at] = g.u64(0x20, 0x7E) as u8;
                }
            }
            _ => {
                // Insert one printable ASCII byte.
                let at = g.usize(0, bytes.len());
                bytes.insert(at, g.u64(0x20, 0x7E) as u8);
            }
        }
        let text = std::str::from_utf8(&bytes).unwrap_or_else(|_| unreachable!("ascii mutations"));
        let tree = parse(text).is_ok();
        let scan = JsonScanner::new(&bytes).validate().is_ok();
        assert_eq!(
            tree, scan,
            "readers disagree on {text:?}: tree={tree} scanner={scan}"
        );
    });
}

#[test]
fn lazy_extraction_matches_tree_walk() {
    forall_seeded("path_* == tree .at()", 0xEC_0DE5, 300, |g| {
        // Below the writer's integral fast path bound (9e15 < 2^53), so
        // the u64 survives the f64 tree representation exactly and both
        // readers recover the same digits.
        let n = g.u64(0, 8_999_999_999_999_999);
        let x = g.f64(-1e6, 1e6);
        let s = g.string(16);
        let inner = g.u64(0, 999_999);
        let doc = Json::obj()
            .set("n", n)
            .set("x", x)
            .set("s", s.clone())
            .set("nested", Json::obj().set("id", inner))
            .set("arr", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        let text = doc.to_string_compact();
        let scan = JsonScanner::new(text.as_bytes());

        assert_eq!(scan.path_u64(&["n"]), doc.at(&["n"]).and_then(Json::as_u64));
        assert_eq!(scan.path_f64(&["x"]), doc.at(&["x"]).and_then(Json::as_f64));
        // Prop strings contain no escape-worthy characters, so the
        // borrowed fast path must serve them.
        assert_eq!(scan.path_str(&["s"]), Some(s.as_str()));
        assert_eq!(
            scan.path_u64(&["nested", "id"]),
            doc.at(&["nested", "id"]).and_then(Json::as_u64)
        );
        // Misses stay misses on both sides.
        assert_eq!(scan.path_u64(&["absent"]), None);
        assert!(doc.at(&["absent"]).is_none());
        // A path into a non-object is a miss, not an error.
        assert_eq!(scan.path_u64(&["arr", "0"]), None);
    });
}

#[test]
fn strict_number_vectors_agree_between_readers() {
    for v in NUMBER_ACCEPT {
        let framed = format!("[{v}]");
        assert!(parse(&framed).is_ok(), "tree rejected valid number {v:?}");
        assert!(
            JsonScanner::new(framed.as_bytes()).validate().is_ok(),
            "scanner rejected valid number {v:?}"
        );
    }
    for v in NUMBER_REJECT {
        let framed = format!("[{v}]");
        assert!(parse(&framed).is_err(), "tree accepted invalid number {v:?}");
        assert!(
            JsonScanner::new(framed.as_bytes()).validate().is_err(),
            "scanner accepted invalid number {v:?}"
        );
    }
}

#[test]
fn depth_cap_agrees_between_readers() {
    let nest = |depth: usize| {
        let mut s = String::new();
        for _ in 0..depth {
            s.push('[');
        }
        s.push('0');
        for _ in 0..depth {
            s.push(']');
        }
        s
    };
    let at_cap = nest(MAX_DEPTH);
    assert!(parse(&at_cap).is_ok());
    assert!(JsonScanner::new(at_cap.as_bytes()).validate().is_ok());
    let over = nest(MAX_DEPTH + 1);
    assert!(parse(&over).is_err(), "tree must reject beyond MAX_DEPTH");
    assert!(
        JsonScanner::new(over.as_bytes()).validate().is_err(),
        "scanner must reject beyond MAX_DEPTH"
    );
}

#[test]
fn surrogate_and_escape_handling_agree() {
    // Escapes decode through the tree parser; the scanner only
    // validates. Accept/reject must still line up exactly.
    let cases: &[(&str, bool)] = &[
        (r#""😀""#, true),  // paired surrogate (U+1F600)
        (r#""\ud83d""#, true),        // lone high -> U+FFFD, accepted
        (r#""\ude00""#, true),        // lone low -> U+FFFD, accepted
        (r#""A\n\t""#, true),    // plain escapes
        (r#""\q""#, false),           // unknown escape
        (r#""\u12g4""#, false),       // bad hex digit
        (r#""\u123""#, false),        // short hex run
    ];
    for &(text, ok) in cases {
        assert_eq!(parse(text).is_ok(), ok, "tree on {text}");
        assert_eq!(
            JsonScanner::new(text.as_bytes()).validate().is_ok(),
            ok,
            "scanner on {text}"
        );
    }
}

/// hydra-lint-style source assertion: the scanner's non-test code must
/// stay allocation-free *by construction*. The runtime guarantees
/// (borrowed `&str` returns, fixed `[u8; MAX_DEPTH]` state stack) only
/// hold as long as nobody slips an allocating type into the hot loop, so
/// this test greps the module source the same way `hydra-lint` ratchets
/// its rules.
#[test]
fn scanner_source_has_no_allocations() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/src/util/json_scan.rs");
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    // Only non-test code is constrained; strip `//` comments (the file
    // has no string literal containing a slash-pair).
    let non_test = src.split("#[cfg(test)]").next().unwrap_or(&src);
    let mut code = String::new();
    for line in non_test.lines() {
        code.push_str(line.split("//").next().unwrap_or(line));
        code.push('\n');
    }
    for banned in [
        "String", "Vec<", "vec!", "format!", ".to_string", ".to_owned", "Box<", ".unwrap()",
        ".expect(", "panic!",
    ] {
        assert!(
            !code.contains(banned),
            "json_scan non-test code must stay allocation-free and panic-free: found {banned:?}"
        );
    }
}
