//! Integration: the FACTS workflow end to end — real PJRT compute feeding
//! the workflow engine across simulated cloud and HPC platforms
//! (Experiment 4 in miniature).

use hydra::api::{ProviderConfig, ResourceRequest};
use hydra::broker::state::TaskRegistry;
use hydra::facts::{self, data, pipeline::FactsPipeline, FactsSize};
use hydra::runtime::{default_artifacts_dir, PjRtRuntime};
use hydra::sim::provider::ProviderId;
use hydra::workflow::engine::WorkflowEngine;

fn runtime() -> PjRtRuntime {
    PjRtRuntime::load(default_artifacts_dir()).expect("run `make artifacts` first")
}

/// Measure real step timings once (the workflow engine reuses them as
/// simulated task durations, exactly like examples/facts_e2e.rs).
fn measured_timings(rt: &PjRtRuntime) -> facts::StepTimings {
    let pipe = FactsPipeline::new(rt, FactsSize::Small);
    let inputs = data::generate(1, FactsSize::Small);
    // Warm-up compiles, second run measures steady-state.
    pipe.run(&inputs).unwrap();
    pipe.run(&inputs).unwrap().timings
}

#[test]
fn facts_workflows_run_on_cloud_and_hpc() {
    let rt = runtime();
    let timings = measured_timings(&rt);
    assert!(timings.total_s() > 0.0);
    let spec = facts::workflow_spec(FactsSize::Small);

    // Cloud (Jetstream2).
    let reg = TaskRegistry::new();
    let jet2 = WorkflowEngine::new(
        ProviderConfig::simulated(ProviderId::Jetstream2),
        ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16),
    )
    .execute_many(&spec, 10, &reg, facts::measured_workflow(timings))
    .unwrap();
    assert_eq!(jet2.waves, 4);
    assert_eq!(jet2.tasks, 40);
    assert!(reg.all_final());

    // HPC (Bridges2).
    let reg2 = TaskRegistry::new();
    let b2 = WorkflowEngine::new(
        ProviderConfig::simulated(ProviderId::Bridges2),
        ResourceRequest::pilot(ProviderId::Bridges2, 1),
    )
    .execute_many(&spec, 10, &reg2, facts::measured_workflow(timings))
    .unwrap();
    assert_eq!(b2.waves, 4);
    assert!(reg2.all_final());

    // Fig 5 ordering (excluding the one-off HPC queue wait): Bridges2
    // executes the same workflows faster than the cloud.
    let b2_exec = b2.ttx_s - b2.wave_ttx_s[0].min(100.0);
    assert!(
        b2_exec < jet2.ttx_s,
        "bridges2 exec {} vs jet2 {}",
        b2_exec,
        jet2.ttx_s
    );
}

#[test]
fn facts_weak_scaling_is_near_flat_on_cloud() {
    // Fig 5 (left): weak scaling — instances grow with cores; TTX should
    // stay within ~2x of the smallest configuration.
    let rt = runtime();
    let timings = measured_timings(&rt);
    let spec = facts::workflow_spec(FactsSize::Small);
    let mut ttx = Vec::new();
    for (instances, nodes) in [(8usize, 1u32), (16, 2), (32, 4)] {
        let reg = TaskRegistry::new();
        let r = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, nodes, 16),
        )
        .execute_many(&spec, instances, &reg, facts::measured_workflow(timings))
        .unwrap();
        ttx.push(r.ttx_s);
    }
    let worst = ttx.iter().cloned().fold(0.0f64, f64::max);
    let best = ttx.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(worst / best < 2.0, "weak scaling TTX spread too wide: {ttx:?}");
}

#[test]
fn facts_strong_scaling_improves_with_cores() {
    // Fig 5 (right): strong scaling — fixed 32 instances, growing cores.
    let rt = runtime();
    let timings = measured_timings(&rt);
    let spec = facts::workflow_spec(FactsSize::Small);
    let mut ttx = Vec::new();
    // 64 instances so even 4 nodes (64 vCPUs) stay saturated — strong
    // scaling flattens once cores >= instances, as in Fig 5's Bridges2
    // plateau below 128 cores.
    for nodes in [1u32, 2, 4] {
        let reg = TaskRegistry::new();
        let r = WorkflowEngine::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::kubernetes(ProviderId::Aws, nodes, 16),
        )
        .execute_many(&spec, 64, &reg, facts::measured_workflow(timings))
        .unwrap();
        ttx.push(r.ttx_s);
    }
    assert!(ttx[1] < ttx[0], "{ttx:?}");
    assert!(ttx[2] < ttx[1], "{ttx:?}");
}

#[test]
fn facts_science_results_travel_through_the_stack() {
    // The end-to-end composition check: run the real pipeline for several
    // instances, confirm distinct seeds give distinct (but plausible)
    // projections, all through the PJRT runtime.
    let rt = runtime();
    let pipe = FactsPipeline::new(&rt, FactsSize::Small);
    let mut rises = Vec::new();
    for seed in 0..5 {
        let r = pipe.run(&data::generate(seed, FactsSize::Small)).unwrap();
        assert!(r.total_rise_mm > 0.0 && r.total_rise_mm < 5000.0);
        rises.push(r.total_rise_mm);
    }
    let distinct = rises
        .windows(2)
        .filter(|w| (w[0] - w[1]).abs() > 1e-6)
        .count();
    assert!(distinct >= 3, "instances should differ: {rises:?}");
}
