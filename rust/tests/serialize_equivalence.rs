//! ISSUE 3 acceptance: the framed bulk payload is **byte-identical** for
//! thread counts {1, 2, 8} across all three managers (CaaS, FaaS, HPC)
//! at batch sizes {0, 1, 4096} — including the empty-batch and
//! single-shard edge cases. The serial `threads == 1` path is the
//! reference; the parallel paths must reproduce its bytes exactly.

use hydra::api::task::{Payload, TaskDescription, TaskId};
use hydra::api::{ProviderConfig, ResourceRequest};
use hydra::broker::data::{frame_bulk, SerializeOptions};
use hydra::broker::partitioner::{PartitionModel, Partitioner, PodBuildMode};
use hydra::broker::state::TaskRegistry;
use hydra::broker::{faas, hpc};
use hydra::sim::kubernetes::ClusterSpec;
use hydra::sim::provider::ProviderId;
use hydra::util::json;

const PARALLEL_THREADS: [usize; 2] = [2, 8];
const COUNTS: [usize; 3] = [0, 1, 4096];

/// Heterogeneous workload: varied cpu/mem, payloads, all three task
/// kinds, and names that need JSON escaping, so equivalence covers the
/// full serializer surface.
fn tasks(n: usize) -> Vec<(TaskId, TaskDescription)> {
    (0..n)
        .map(|i| {
            let mut d = if i % 5 == 0 {
                TaskDescription::executable(format!("exe \"{i}\"\n"), "/bin/step --x")
            } else if i % 5 == 2 {
                TaskDescription::function(format!("fn \"{i}\""), "pkg.module:handler")
            } else {
                TaskDescription::container(format!("ctr-{i}"), "hydra/noop:latest")
            };
            d.cpus = 1 + (i as u32) % 3;
            d.mem_mb = 128 + (i as u64 % 4) * 64;
            d.payload = match i % 3 {
                0 => Payload::Noop,
                1 => Payload::Sleep(0.5 + i as f64 * 0.25),
                _ => Payload::Work(1.75),
            };
            (TaskId(i as u64), d)
        })
        .collect()
}

fn caas_bulk(ts: &[(TaskId, TaskDescription)], model: PartitionModel, threads: usize) -> Vec<u8> {
    let opts = SerializeOptions::with_threads(threads);
    let p = Partitioner::new(model, PodBuildMode::Memory).with_serialize(opts);
    let cluster = ClusterSpec::uniform(4, 16);
    let pods = p.partition(ts, &cluster, 0).expect("workload fits");
    let w = p.build_manifests(pods, ts).expect("memory mode");
    assert_eq!(w.framed_len(), frame_bulk(&w.shards, opts).len());
    w.frame_bulk(opts)
}

fn faas_bulk(ts: &[(TaskId, TaskDescription)], threads: usize) -> Vec<u8> {
    let opts = SerializeOptions::with_threads(threads);
    frame_bulk(&faas::bulk_invoke_document(ts, opts), opts)
}

fn hpc_bulk(ts: &[(TaskId, TaskDescription)], threads: usize) -> Vec<u8> {
    let opts = SerializeOptions::with_threads(threads);
    let specs = hpc::pilot_specs(ts);
    frame_bulk(&hpc::bulk_task_document(ts, &specs, opts), opts)
}

#[test]
fn caas_bulk_bytes_identical_across_threads() {
    for model in [PartitionModel::Scpp, PartitionModel::Mcpp { max_cpp: 16 }] {
        for &n in &COUNTS {
            let ts = tasks(n);
            let serial = caas_bulk(&ts, model, 1);
            assert_eq!(serial.first(), Some(&b'['), "n={n}");
            assert_eq!(serial.last(), Some(&b']'), "n={n}");
            for &t in &PARALLEL_THREADS {
                assert_eq!(caas_bulk(&ts, model, t), serial, "model={model:?} n={n} threads={t}");
            }
        }
    }
}

#[test]
fn faas_bulk_bytes_identical_across_threads() {
    for &n in &COUNTS {
        let ts = tasks(n);
        let serial = faas_bulk(&ts, 1);
        for &t in &PARALLEL_THREADS {
            assert_eq!(faas_bulk(&ts, t), serial, "n={n} threads={t}");
        }
    }
}

#[test]
fn hpc_bulk_bytes_identical_across_threads() {
    for &n in &COUNTS {
        let ts = tasks(n);
        let serial = hpc_bulk(&ts, 1);
        for &t in &PARALLEL_THREADS {
            assert_eq!(hpc_bulk(&ts, t), serial, "n={n} threads={t}");
        }
    }
}

#[test]
fn faas_manager_end_to_end_is_thread_count_invariant() {
    // ISSUE 4 satellite: the serialize-threads knob honored by the FaaS
    // *manager* path (not just the document builder) — identical item and
    // framed byte counts for threads {1, 2, 8}.
    let run_with = |threads: usize| {
        let reg = TaskRegistry::new();
        let ts: Vec<(TaskId, TaskDescription)> = tasks(600)
            .into_iter()
            .map(|(_, d)| (reg.register(d.clone()), d))
            .collect();
        let m = faas::FaasManager::new(
            ProviderConfig::simulated(ProviderId::Aws),
            ResourceRequest::faas(ProviderId::Aws, 64),
            9,
        )
        .unwrap()
        .with_serialize(SerializeOptions::with_threads(threads));
        let r = m.execute(&ts, &reg).unwrap();
        assert!(reg.all_final(), "threads={threads}");
        (r.bytes_serialized, r.bulk_bytes)
    };
    let serial = run_with(1);
    assert!(serial.1 > serial.0, "framed envelope must add bytes");
    for &t in &PARALLEL_THREADS {
        assert_eq!(run_with(t), serial, "threads={t}");
    }
}

#[test]
fn empty_batch_frames_as_empty_array_everywhere() {
    let ts = tasks(0);
    for bulk in [
        caas_bulk(&ts, PartitionModel::Scpp, 8),
        faas_bulk(&ts, 8),
        hpc_bulk(&ts, 8),
    ] {
        assert_eq!(bulk, b"[]");
    }
}

#[test]
fn single_item_batch_stays_on_one_shard() {
    // 1 item, 8 threads: the shard floor keeps this serial — the
    // single-shard edge case must still frame as `[manifest]`.
    let ts = tasks(1);
    let opts = SerializeOptions::with_threads(8);
    assert_eq!(opts.shards_for(1), 1);
    let bulk = caas_bulk(&ts, PartitionModel::Scpp, 8);
    assert_eq!(bulk, caas_bulk(&ts, PartitionModel::Scpp, 1));
    let text = std::str::from_utf8(&bulk).unwrap();
    let doc = json::parse(text).expect("framed payload is valid JSON");
    assert_eq!(doc.as_arr().unwrap().len(), 1);
}

#[test]
fn framed_payload_is_valid_json_with_one_entry_per_item() {
    let ts = tasks(128);
    // CaaS SCPP: one manifest per task.
    let caas = caas_bulk(&ts, PartitionModel::Scpp, 8);
    let doc = json::parse(std::str::from_utf8(&caas).unwrap()).unwrap();
    assert_eq!(doc.as_arr().unwrap().len(), 128);
    // FaaS: one invocation per task.
    let faas_doc = json::parse(std::str::from_utf8(&faas_bulk(&ts, 8)).unwrap()).unwrap();
    assert_eq!(faas_doc.as_arr().unwrap().len(), 128);
    // HPC: one task dict per task, carrying the pilot spec fields.
    let hpc_doc = json::parse(std::str::from_utf8(&hpc_bulk(&ts, 8)).unwrap()).unwrap();
    let arr = hpc_doc.as_arr().unwrap();
    assert_eq!(arr.len(), 128);
    assert!(arr[0].get("uid").is_some());
    assert!(arr[0].get("executable").is_some());
}
