//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).
//! These tests prove the L2/L1 → L3 bridge: HLO text lowered from
//! JAX+Pallas loads, compiles and executes in-process with correct
//! numerics, with Python nowhere on the path.

use hydra::facts::{data, pipeline::FactsPipeline, FactsSize, QUANTILES};
use hydra::runtime::{default_artifacts_dir, PjRtRuntime, Tensor};

fn runtime() -> PjRtRuntime {
    PjRtRuntime::load(default_artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn manifest_lists_all_size_variants() {
    let rt = runtime();
    let m = rt.manifest();
    assert_eq!(m.quantiles, QUANTILES.to_vec());
    for size in ["small", "default", "large"] {
        for step in ["preprocess", "fit_k2", "fit_k4", "project_se", "project_poly",
                     "postprocess"] {
            assert!(
                m.spec(&format!("{step}_{size}")).is_some(),
                "missing artifact {step}_{size}"
            );
        }
    }
}

#[test]
fn preprocess_executes_with_correct_numerics() {
    let rt = runtime();
    let (b, t, _, _) = FactsSize::Small.dims();
    // Constant temperature 3.0 => anomaly column must be exactly 0.
    let temps = Tensor::new(vec![3.0; b * t], vec![b, t]);
    let rates = Tensor::new(vec![1.5; b * t], vec![b, t]);
    let out = rt.execute("preprocess_small", &[temps, rates]).unwrap();
    assert_eq!(out.len(), 4);
    let x4 = &out[0];
    assert_eq!(x4.shape, vec![b, t, 4]);
    for i in 0..b * t {
        assert!((x4.data[i * 4] - 1.0).abs() < 1e-6, "ones column");
        assert!(x4.data[i * 4 + 1].abs() < 1e-5, "anomaly column");
    }
    let tref = &out[3];
    for v in &tref.data {
        assert!((v - 3.0).abs() < 1e-5, "reference temperature");
    }
}

#[test]
fn fit_recovers_known_coefficients() {
    let rt = runtime();
    let (b, t, _, _) = FactsSize::Small.dims();
    // y = 2 + 3*x with x in [0,1): theta should be ~[2, 3].
    let mut x2 = Vec::with_capacity(b * t * 2);
    let mut y = Vec::with_capacity(b * t);
    for _site in 0..b {
        for i in 0..t {
            let x = i as f32 / t as f32;
            x2.extend_from_slice(&[1.0, x]);
            y.push(2.0 + 3.0 * x);
        }
    }
    let out = rt
        .execute("fit_k2_small", &[Tensor::new(x2, vec![b, t, 2]), Tensor::new(y, vec![b, t])])
        .unwrap();
    let theta = &out[0];
    assert_eq!(theta.shape, vec![b, 2]);
    for site in 0..b {
        assert!((theta.data[site * 2] - 2.0).abs() < 0.05, "intercept {}", theta.data[site * 2]);
        assert!((theta.data[site * 2 + 1] - 3.0).abs() < 0.1, "slope {}", theta.data[site * 2 + 1]);
    }
    let sigma2 = &out[1];
    for v in &sigma2.data {
        assert!(*v < 1e-3, "perfect fit has ~zero residual, got {v}");
    }
}

#[test]
fn execute_rejects_wrong_shapes_and_names() {
    let rt = runtime();
    assert!(rt.execute("nope_small", &[]).is_err());
    let bad = Tensor::zeros(&[2, 2]);
    assert!(rt.execute("preprocess_small", &[bad.clone(), bad]).is_err());
    let (b, t, _, _) = FactsSize::Small.dims();
    let one = Tensor::zeros(&[b, t]);
    assert!(rt.execute("preprocess_small", &[one]).is_err(), "arity check");
}

#[test]
fn executables_are_compiled_once_and_reused() {
    let rt = runtime();
    let (b, t, _, _) = FactsSize::Small.dims();
    let temps = Tensor::zeros(&[b, t]);
    let rates = Tensor::zeros(&[b, t]);
    assert_eq!(rt.compiled_count(), 0);
    rt.execute("preprocess_small", &[temps.clone(), rates.clone()]).unwrap();
    assert_eq!(rt.compiled_count(), 1);
    rt.execute("preprocess_small", &[temps, rates]).unwrap();
    assert_eq!(rt.compiled_count(), 1, "second call reuses the executable");
    assert_eq!(rt.executions(), 2);
}

#[test]
fn full_facts_pipeline_produces_plausible_sea_level_rise() {
    let rt = runtime();
    let pipe = FactsPipeline::new(&rt, FactsSize::Small);
    let inputs = data::generate(42, FactsSize::Small);
    let r = pipe.run(&inputs).unwrap();

    let (_, _, _, y) = FactsSize::Small.dims();
    let q = QUANTILES.len();
    assert_eq!(r.combined.shape, vec![q, y]);
    assert_eq!(r.envelope.shape, vec![2, y]);

    // Quantile fan is ordered at the horizon.
    for qi in 1..q {
        assert!(
            r.combined.data[qi * y + (y - 1)] >= r.combined.data[(qi - 1) * y + (y - 1)] - 1e-3,
            "quantiles must be ordered"
        );
    }
    // Warming scenario + positive sensitivities => rising seas, and the
    // magnitude is centimeters-to-meters over the horizon, not garbage.
    assert!(r.total_rise_mm > 0.0, "total rise {}", r.total_rise_mm);
    assert!(r.total_rise_mm < 5000.0, "total rise {} mm implausible", r.total_rise_mm);
    // Median rise grows along the projection (cumulative integral of a
    // positive forcing).
    let mid = q / 2;
    let early = r.combined.data[mid * y + 2];
    let late = r.combined.data[mid * y + (y - 1)];
    assert!(late > early, "median must grow: {early} -> {late}");
    // All four steps actually ran.
    assert!(r.timings.pre_s > 0.0 && r.timings.fit_s > 0.0);
    assert!(r.timings.project_s > 0.0 && r.timings.post_s > 0.0);
}

#[test]
fn pipeline_is_deterministic_given_inputs() {
    let rt = runtime();
    let pipe = FactsPipeline::new(&rt, FactsSize::Small);
    let inputs = data::generate(7, FactsSize::Small);
    let a = pipe.run(&inputs).unwrap();
    let b = pipe.run(&inputs).unwrap();
    assert_eq!(a.combined.data, b.combined.data);
    assert_eq!(a.total_rise_mm, b.total_rise_mm);
}

#[test]
fn larger_ensemble_tightens_or_matches_quantile_noise() {
    // large = 4x the MC samples of default; the fan should remain ordered
    // and the median should agree within MC noise.
    let rt = runtime();
    let d_def = data::generate(9, FactsSize::Default);
    let d_lrg = data::generate(9, FactsSize::Large);
    let r_def = FactsPipeline::new(&rt, FactsSize::Default).run(&d_def).unwrap();
    let r_lrg = FactsPipeline::new(&rt, FactsSize::Large).run(&d_lrg).unwrap();
    let rel = (r_def.total_rise_mm - r_lrg.total_rise_mm).abs()
        / r_def.total_rise_mm.abs().max(1.0);
    assert!(rel < 0.25, "default {} vs large {}", r_def.total_rise_mm, r_lrg.total_rise_mm);
}
