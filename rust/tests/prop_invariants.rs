//! Property tests over coordinator invariants (routing, batching, state),
//! using the hand-rolled `util::prop` harness (proptest is unavailable in
//! the offline environment — see DESIGN.md §1).

use hydra::api::task::{Payload, TaskDescription, TaskId, TaskState};
use hydra::broker::partitioner::{PartitionModel, Partitioner, PodBuildMode};
use hydra::broker::policy::{assign, BrokerPolicy};
use hydra::broker::state::TaskRegistry;
use hydra::sim::hpc::{FaultSpec, HpcSim, HpcTaskSpec, MultiPilotSim, PilotSpec};
use hydra::sim::kubernetes::{simulate_batch, ClusterSpec};
use hydra::sim::provider::{PlatformProfile, ProviderId};
use hydra::util::prop::{forall, Gen};

fn gen_task(g: &mut Gen, max_cpu: u32) -> TaskDescription {
    let name = format!("t-{}", g.u64(0, 1 << 30));
    let mut t = if g.bool() {
        TaskDescription::container(name, "img:latest")
    } else {
        TaskDescription::executable(name, "exe")
    };
    t = t.with_cpus(g.u64(1, max_cpu as u64) as u32);
    t = t.with_mem_mb(g.u64(64, 2048));
    t = match g.u64(0, 2) {
        0 => t.with_payload(Payload::Noop),
        1 => t.with_payload(Payload::Sleep(g.f64(0.1, 10.0))),
        _ => t.with_payload(Payload::Work(g.f64(0.1, 100.0))),
    };
    t
}

#[test]
fn prop_partition_conserves_tasks_and_capacity() {
    forall("partition conserves tasks and respects capacity", 150, |g| {
        let vcpus = g.u64(2, 32) as u32;
        let cluster = ClusterSpec {
            nodes: g.u64(1, 8) as u32,
            vcpus_per_node: vcpus,
            gpus_per_node: 0,
            mem_mb_per_node: 1 << 30,
        };
        let tasks: Vec<(TaskId, TaskDescription)> = g
            .vec(1, 300, |g| gen_task(g, vcpus))
            .into_iter()
            .enumerate()
            .map(|(i, t)| (TaskId(i as u64), t))
            .collect();
        let model = if g.bool() {
            PartitionModel::Scpp
        } else {
            PartitionModel::Mcpp { max_cpp: g.usize(1, 32) }
        };
        let p = Partitioner::new(model, PodBuildMode::Memory);
        let pods = p.partition(&tasks, &cluster, 0).unwrap();

        // Every task exactly once.
        let mut seen: Vec<u64> =
            pods.iter().flat_map(|p| p.containers.iter().map(|c| c.task_id)).collect();
        seen.sort();
        let want: Vec<u64> = (0..tasks.len() as u64).collect();
        assert_eq!(seen, want, "task conservation");

        // Every pod fits an empty node.
        for pod in &pods {
            assert!(pod.cpus() <= cluster.vcpus_per_node, "pod cpu over capacity");
            assert!(pod.mem_mb() <= cluster.mem_mb_per_node, "pod mem over capacity");
            match model {
                PartitionModel::Scpp => assert_eq!(pod.containers.len(), 1),
                PartitionModel::Mcpp { max_cpp } => {
                    assert!(pod.containers.len() <= max_cpp);
                }
            }
        }

        // Pod ids are consecutive from the offset.
        for (i, pod) in pods.iter().enumerate() {
            assert_eq!(pod.id, i as u64);
        }
    });
}

#[test]
fn prop_scpp_never_fewer_pods_than_mcpp() {
    forall("SCPP produces >= pods than MCPP for the same workload", 100, |g| {
        let cluster = ClusterSpec::uniform(1, 16);
        let tasks: Vec<(TaskId, TaskDescription)> = g
            .vec(1, 200, |g| gen_task(g, 4))
            .into_iter()
            .enumerate()
            .map(|(i, t)| (TaskId(i as u64), t))
            .collect();
        let scpp = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory)
            .partition(&tasks, &cluster, 0)
            .unwrap();
        let mcpp = Partitioner::new(
            PartitionModel::Mcpp { max_cpp: g.usize(2, 16) },
            PodBuildMode::Memory,
        )
        .partition(&tasks, &cluster, 0)
        .unwrap();
        assert!(scpp.len() >= mcpp.len(), "scpp {} < mcpp {}", scpp.len(), mcpp.len());
        assert_eq!(scpp.len(), tasks.len());
    });
}

#[test]
fn prop_policy_assignment_is_a_partition_of_tasks() {
    forall("policy assignment covers each task exactly once", 150, |g| {
        let n_prov = g.usize(1, 4);
        let providers: Vec<ProviderId> = ProviderId::CLOUDS[..n_prov].to_vec();
        let tasks: Vec<(TaskId, TaskDescription)> = g
            .vec(0, 250, |g| {
                let mut t = gen_task(g, 4);
                // Sometimes bind explicitly to an acquired provider.
                if g.u64(0, 3) == 0 {
                    t = t.on(*g.choice(&providers));
                }
                t
            })
            .into_iter()
            .enumerate()
            .map(|(i, t)| (TaskId(i as u64), t))
            .collect();
        let policy = match g.u64(0, 2) {
            0 => BrokerPolicy::RoundRobin,
            1 => BrokerPolicy::Weighted(
                providers.iter().map(|p| (*p, g.f64(0.1, 5.0))).collect(),
            ),
            _ => BrokerPolicy::RoundRobin,
        };
        // Kind-blind policies ignore the acquired service; CaaS everywhere
        // keeps the generator simple.
        let acquired: Vec<(ProviderId, hydra::api::resource::ServiceKind)> = providers
            .iter()
            .map(|&p| (p, hydra::api::resource::ServiceKind::Caas))
            .collect();
        let a = assign(&policy, &tasks, &acquired).unwrap();

        let mut all: Vec<u64> = a.values().flatten().map(|id| id.0).collect();
        all.sort();
        let want: Vec<u64> = (0..tasks.len() as u64).collect();
        assert_eq!(all, want, "assignment must partition the workload");

        for p in a.keys() {
            assert!(providers.contains(p), "unacquired provider in assignment");
        }
        // Explicit bindings honored.
        for (id, t) in &tasks {
            if let Some(p) = t.provider {
                assert!(a[&p].contains(id), "explicit binding broken");
            }
        }
    });
}

#[test]
fn prop_state_machine_no_final_state_escapes() {
    forall("final states are terminal under random transition storms", 100, |g| {
        let reg = TaskRegistry::new();
        let id = reg.register(TaskDescription::container("t", "i"));
        let states = [
            TaskState::Validated,
            TaskState::Partitioned,
            TaskState::Submitted,
            TaskState::Running,
            TaskState::Done,
            TaskState::Failed,
            TaskState::Canceled,
        ];
        let mut was_final = false;
        for _ in 0..g.usize(1, 40) {
            let target = *g.choice(&states);
            let before = reg.state_of(id).unwrap();
            let r = reg.transition(id, target);
            let after = reg.state_of(id).unwrap();
            if was_final {
                assert!(r.is_err(), "transition out of final state accepted");
                assert_eq!(before, after);
            }
            if r.is_err() {
                assert_eq!(before, after, "failed transition must not change state");
            }
            was_final = after.is_final();
        }
    });
}

#[test]
fn prop_simulation_conserves_tasks_and_orders_time() {
    forall("kubernetes sim conserves tasks and orders timestamps", 60, |g| {
        let cluster = ClusterSpec::uniform(g.u64(1, 4) as u32, g.u64(2, 16) as u32);
        let tasks: Vec<(TaskId, TaskDescription)> = g
            .vec(1, 120, |g| gen_task(g, 2))
            .into_iter()
            .enumerate()
            .map(|(i, t)| (TaskId(i as u64), t))
            .collect();
        let p = Partitioner::new(PartitionModel::Scpp, PodBuildMode::Memory);
        let pods = p.partition(&tasks, &cluster, 0).unwrap();
        let n_pods = pods.len();
        let profile = PlatformProfile::of(*g.choice(&ProviderId::CLOUDS));
        let seed = g.u64(0, u64::MAX / 2);
        let report = simulate_batch(&profile, cluster, pods, seed);
        assert_eq!(report.pods_completed, n_pods);
        assert_eq!(report.tasks.len(), tasks.len());
        for t in &report.tasks {
            assert!(t.scheduled_s <= t.started_s);
            assert!(t.started_s <= t.finished_s);
            assert!(t.finished_s <= report.makespan_s + 1e-9);
        }
    });
}

#[test]
fn prop_multi_pilot_conserves_cores_and_tasks() {
    // ISSUE 5: for any pilot fleet and workload — free cores never go
    // negative (u32 underflow would panic these debug builds; the sim
    // additionally debug-asserts conservation on every TaskDone), the
    // sum of allocations never exceeds any pilot's width at any event
    // (peak_cores_busy <= total_cores), every submitted task appears in
    // exactly one record on exactly one pilot, and every reservation is
    // returned by the end of the run.
    let profile = PlatformProfile::of(ProviderId::Bridges2);
    forall("multi-pilot sim conserves cores and tasks", 60, |g| {
        let pilot_count = g.usize(1, 6);
        let specs: Vec<PilotSpec> = (0..pilot_count)
            .map(|_| PilotSpec { nodes: g.u64(1, 3) as u32 })
            .collect();
        let widest = specs.iter().map(|s| s.nodes).max().unwrap() * 128;
        let tasks: Vec<HpcTaskSpec> = g
            .vec(0, 150, |g| HpcTaskSpec {
                task_id: 0, // re-keyed to the submission index below
                cores: g.u64(1, 600) as u32, // sometimes wider than the fleet
                work_s: g.f64(0.0, 50.0),
                sleep_s: if g.bool() { g.f64(0.0, 2.0) } else { 0.0 },
            })
            .into_iter()
            .enumerate()
            .map(|(i, mut t)| {
                t.task_id = i as u64;
                t
            })
            .collect();
        let n = tasks.len();
        let mut sim = MultiPilotSim::new(profile.clone(), specs.clone(), g.u64(0, u64::MAX / 2));
        sim.submit(tasks);
        let r = sim.run();

        // Every submitted task in exactly one record.
        let mut ids: Vec<u64> = r.tasks.iter().map(|t| t.task_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "task conservation");

        // Core conservation at every event, per pilot.
        assert_eq!(r.pilots.len(), pilot_count);
        let mut total_cores = 0u32;
        for (i, p) in r.pilots.iter().enumerate() {
            assert_eq!(p.total_cores, specs[i].nodes * 128);
            assert!(p.peak_cores_busy <= p.total_cores, "pilot {i} over-allocated");
            assert!((0.0..=1.0).contains(&p.utilization), "pilot {i} utilization");
            total_cores += p.total_cores;
        }
        assert_eq!(sim.free_capacity(), total_cores, "reservations leaked");

        // Oversized tasks clamp to the widest pilot: they complete, and
        // no pilot ever holds more than its own width (asserted above);
        // the clamped width itself is visible when such a task runs alone.
        assert!(r.pilots.iter().all(|p| p.peak_cores_busy <= widest));

        // Pilot assignment is consistent.
        assert_eq!(r.pilot_of.len(), n);
        for (i, p) in r.pilots.iter().enumerate() {
            let assigned = r.pilot_of.iter().filter(|&&x| x as usize == i).count();
            assert_eq!(assigned, p.tasks_executed, "pilot {i} assignment count");
        }
        assert_eq!(
            r.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(),
            n,
            "every task on exactly one pilot"
        );
        for t in &r.tasks {
            assert!(t.finished_s >= t.launched_s);
            // 1e-6: finished_s is clamped to the raw launch instant, which
            // can sit up to half a microsecond past the rounded event
            // clock that defines the makespan.
            assert!(t.finished_s <= r.makespan_s + 1e-6);
        }
    });
}

#[test]
fn prop_exactly_once_under_pilot_faults() {
    // ISSUE 6: under any mix of pilot-level faults — injected kills,
    // MTBF draws, walltime expiry, materialization failure, any retry
    // budget — every submitted task ends exactly once: in one completed
    // record on one pilot, or in `abandoned`. Never both, never twice,
    // never silently dropped. Every reservation a dying pilot rolled
    // back is returned (free capacity ends at the fleet total), and the
    // re-queue accounting agrees between pilots and recorded waves.
    let profile = PlatformProfile::of(ProviderId::Bridges2);
    forall("exactly-once completion under pilot faults", 60, |g| {
        let pilot_count = g.usize(1, 5);
        let specs: Vec<PilotSpec> = (0..pilot_count)
            .map(|_| PilotSpec { nodes: g.u64(1, 3) as u32 })
            .collect();
        let fault = FaultSpec {
            walltime_s: if g.bool() { g.f64(10.0, 500.0) } else { 0.0 },
            mtbf_s: if g.bool() { g.f64(50.0, 2000.0) } else { 0.0 },
            materialization_failure_p: if g.u64(0, 3) == 0 { g.f64(0.0, 1.0) } else { 0.0 },
            retry_budget: g.u64(0, 4) as u32,
            injected_kill: if g.bool() {
                Some((g.u64(0, pilot_count as u64 - 1) as u32, g.f64(0.0, 120.0)))
            } else {
                None
            },
        };
        let tasks: Vec<HpcTaskSpec> = g
            .vec(0, 120, |g| HpcTaskSpec {
                task_id: 0, // re-keyed to the submission index below
                cores: g.u64(1, 600) as u32,
                work_s: g.f64(0.0, 50.0),
                sleep_s: if g.bool() { g.f64(0.0, 2.0) } else { 0.0 },
            })
            .into_iter()
            .enumerate()
            .map(|(i, mut t)| {
                t.task_id = i as u64;
                t
            })
            .collect();
        let n = tasks.len();
        let mut sim =
            MultiPilotSim::new(profile.clone(), specs.clone(), g.u64(0, u64::MAX / 2))
                .with_faults(fault);
        sim.submit(tasks);
        let r = sim.run();

        // Completed records + abandoned ids partition the submission.
        let mut ids: Vec<u64> = r.tasks.iter().map(|t| t.task_id).collect();
        ids.extend(r.abandoned.iter().copied());
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "exactly-once partition");

        // Every rolled-back reservation was returned.
        let total: u32 = specs.iter().map(|s| s.nodes * 128).sum();
        assert_eq!(sim.free_capacity(), total, "reservations leaked across pilot deaths");

        // Per-pilot bounds and assignment consistency over the survivors'
        // completed work.
        assert_eq!(r.pilot_of.len(), r.tasks.len());
        for (i, p) in r.pilots.iter().enumerate() {
            assert!(p.peak_cores_busy <= p.total_cores, "pilot {i} over-allocated");
            assert!((0.0..=1.0).contains(&p.utilization), "pilot {i} utilization");
            let assigned = r.pilot_of.iter().filter(|&&x| x as usize == i).count();
            assert_eq!(assigned, p.tasks_executed, "pilot {i} assignment count");
            if !p.materialized {
                assert_eq!(p.tasks_executed, 0, "unmaterialized pilot {i} ran tasks");
            }
        }

        // Re-queue accounting: pilots' rollback counters match the waves.
        let waved: usize = r.retry_waves.iter().map(|w| w.tasks.len()).sum();
        assert_eq!(
            r.pilots.iter().map(|p| p.tasks_requeued).sum::<usize>(),
            waved,
            "requeue accounting out of sync with recorded waves"
        );
        for w in &r.retry_waves {
            assert!((w.pilot as usize) < pilot_count);
            assert!(r.pilots[w.pilot as usize].died_at.is_some(), "wave from a live pilot");
        }
    });
}

#[test]
fn prop_exactly_once_under_provider_faults() {
    // ISSUE 7: under any mix of provider control-plane faults — outage
    // windows, transient submit error rates, byte throttles, any attempt
    // budget — every submitted task ends exactly once: driven to `Done`
    // by exactly one provider (its primary or a failover target), or
    // canceled in `abandoned`. Never both, never twice, never dropped.
    use hydra::api::ResourceRequest;
    use hydra::broker::{BrokerError, Hydra, ProviderFaultSpec, RetryPolicy};

    forall("exactly-once completion under provider faults", 25, |g| {
        let fault = |g: &mut Gen| ProviderFaultSpec {
            outage_window: if g.u64(0, 3) == 0 { Some((0.0, g.f64(0.0, 1e5))) } else { None },
            transient_error_p: if g.u64(0, 3) == 0 { g.f64(0.0, 1.0) } else { 0.0 },
            throttle_after_bytes: if g.u64(0, 5) == 0 { g.usize(1, 20_000) } else { 0 },
        };
        let retry = |g: &mut Gen| RetryPolicy {
            max_attempts: g.u64(1, 6) as u32,
            base_backoff_s: g.f64(0.01, 0.2),
            ..RetryPolicy::default()
        };
        // Two CaaS providers (so container slices have a failover
        // target), one Batch, one FaaS — all with independent faults.
        let mut b = Hydra::builder().seed(g.u64(0, u64::MAX / 2));
        for p in [ProviderId::Jetstream2, ProviderId::Chameleon] {
            b = b.simulated_provider(p).resource(
                ResourceRequest::kubernetes(p, 1, 16)
                    .with_provider_faults(fault(g))
                    .with_retry_policy(retry(g)),
            );
        }
        b = b.simulated_provider(ProviderId::Bridges2).resource(
            ResourceRequest::pilot(ProviderId::Bridges2, 1)
                .with_provider_faults(fault(g))
                .with_retry_policy(retry(g)),
        );
        b = b.simulated_provider(ProviderId::Aws).resource(
            ResourceRequest::faas(ProviderId::Aws, 64)
                .with_provider_faults(fault(g))
                .with_retry_policy(retry(g)),
        );
        let hydra = b.build().unwrap();

        let n = g.usize(1, 60);
        let tasks: Vec<TaskDescription> = (0..n)
            .map(|i| match g.u64(0, 2) {
                0 => TaskDescription::container(format!("c{i}"), "img:latest"),
                1 => TaskDescription::executable(format!("e{i}"), "exe"),
                _ => TaskDescription::function(format!("f{i}"), "pkg.handler"),
            })
            .collect();

        match hydra.submit(tasks, &hydra::broker::BrokerPolicy::ByTaskKind) {
            Ok(run) => {
                // `Done` ids plus abandoned ids partition the submission.
                let mut ids: Vec<u64> = run
                    .assignment
                    .values()
                    .flatten()
                    .filter(|id| hydra.registry().state_of(**id) == Some(TaskState::Done))
                    .map(|id| id.0)
                    .collect();
                ids.extend(run.abandoned.iter().map(|id| id.0));
                ids.sort_unstable();
                assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "exactly-once partition");
                // Abandoned tasks really were canceled, not silently run.
                for id in &run.abandoned {
                    assert_eq!(hydra.registry().state_of(*id), Some(TaskState::Canceled));
                }
                // Failover accounting agrees with the recorded legs.
                let tallied: usize =
                    run.failovers.iter().map(|f| f.report.run().faults.failed_over).sum();
                assert_eq!(
                    tallied,
                    run.failovers.iter().map(|f| f.tasks).sum::<usize>(),
                    "failover tally out of sync with the legs"
                );
                assert!(hydra.registry().all_final());
            }
            Err(BrokerError::Resource(msg)) => {
                // Every provider's control plane failed: the whole
                // workload must end canceled, nothing half-run.
                assert!(msg.contains("every provider failed"), "unexpected: {msg}");
                assert!(hydra.registry().all_final());
            }
            Err(e) => panic!("broker must absorb provider faults, got: {e}"),
        }
    });
}

#[test]
fn oversized_task_clamps_to_pilot_width_serial_reference() {
    // Direct unit coverage for the serial path's clamp (hpc.rs
    // `try_launch`: `t.cores.min(self.total_cores)`), which previously
    // had only an indirect "it completes" test: the clamped width must
    // be exactly the pilot's width, visible via peak_cores_busy.
    let profile = PlatformProfile::of(ProviderId::Bridges2);
    let mut sim = HpcSim::new(profile.clone(), PilotSpec { nodes: 1 }, 3);
    sim.submit(vec![HpcTaskSpec { task_id: 0, cores: 10_000, work_s: 5.0, sleep_s: 0.0 }]);
    let r = sim.run();
    assert_eq!(r.tasks.len(), 1, "oversized task must not deadlock the FIFO head");
    assert_eq!(r.peak_cores_busy, 128, "clamped to the pilot width, not beyond");
    // Clamping also feeds the payload-duration core count.
    let t = &r.tasks[0];
    let want = profile.payload_duration_s(5.0, 128);
    assert!(((t.finished_s - t.launched_s) - want).abs() < 1e-6);
}

#[test]
fn oversized_task_clamps_to_widest_pilot_multi() {
    // Multi-pilot generalization: the clamp target is the *widest* pilot
    // in the fleet, and only a widest pilot can host the task.
    let profile = PlatformProfile::of(ProviderId::Bridges2);
    let mut sim = MultiPilotSim::new(
        profile,
        vec![PilotSpec { nodes: 1 }, PilotSpec { nodes: 3 }, PilotSpec { nodes: 2 }],
        7,
    );
    sim.submit(vec![HpcTaskSpec { task_id: 0, cores: 9_999, work_s: 1.0, sleep_s: 0.0 }]);
    let r = sim.run();
    assert_eq!(r.tasks.len(), 1);
    assert_eq!(r.pilot_of[0], 1, "only the 3-node pilot fits the clamped task");
    assert_eq!(r.pilots[1].peak_cores_busy, 3 * 128);
    assert_eq!(r.pilots[0].peak_cores_busy, 0);
    assert_eq!(r.pilots[2].peak_cores_busy, 0);
}

#[test]
fn prop_json_roundtrip_arbitrary_documents() {
    use hydra::util::json::{parse, Json};
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.u64(0, 3) } else { g.u64(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e9, 1e9) * 100.0).round() / 100.0),
            3 => Json::Str(g.string(24)),
            4 => Json::Arr((0..g.usize(0, 5)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..g.usize(0, 5) {
                    o = o.set(&format!("k{i}-{}", g.string(6)), gen_json(g, depth - 1));
                }
                o
            }
        }
    }
    forall("json serialize/parse roundtrip", 200, |g| {
        let doc = gen_json(g, 3);
        let text = doc.to_string_compact();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc, "roundtrip failed for {text}");
        let pretty = doc.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), doc, "pretty roundtrip failed");
    });
}
