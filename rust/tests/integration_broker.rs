//! Integration: the broker end to end across simulated platforms.
//!
//! Exercises the full paper pipeline — provider proxy → service proxy →
//! policy binding → CaaS/HPC managers → partitioning → bulk submission →
//! platform simulation → tracing — at Experiment-like scales (shrunk for
//! CI wall-time; the benches run the paper-scale versions).

use hydra::api::task::{Payload, TaskDescription, TaskState};
use hydra::api::ResourceRequest;
use hydra::broker::{BrokerPolicy, Hydra, ManagerReport, PartitionModel, PodBuildMode};
use hydra::sim::provider::ProviderId;

fn containers(n: usize) -> Vec<TaskDescription> {
    (0..n)
        .map(|i| TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"))
        .collect()
}

#[test]
fn experiment1_shape_per_provider_scaling() {
    // Exp 1 (shrunk): per-provider runs; TPT shrinks with vCPUs.
    for provider in [ProviderId::Jetstream2, ProviderId::Aws] {
        let mut tpts = Vec::new();
        for vcpus in [4u32, 8, 16] {
            let hydra = Hydra::builder()
                .simulated_provider(provider)
                .resource(ResourceRequest::kubernetes(provider, 1, vcpus))
                .partition_model(PartitionModel::Scpp)
                .seed(1)
                .build()
                .unwrap();
            let run = hydra.submit(containers(400), &BrokerPolicy::RoundRobin).unwrap();
            tpts.push(run.aggregate.tpt_s);
        }
        assert!(tpts[1] < tpts[0] && tpts[2] < tpts[1], "{provider}: strong scaling {tpts:?}");
    }
}

#[test]
fn experiment2_shape_cross_provider_consistency() {
    // Exp 2 (shrunk): concurrent 4-provider run; tasks conserved, all
    // traced to Done, equal split.
    let mut b = Hydra::builder().partition_model(PartitionModel::Mcpp { max_cpp: 16 });
    for p in ProviderId::CLOUDS {
        b = b
            .simulated_provider(p)
            .resource(ResourceRequest::kubernetes(p, 1, 16));
    }
    let hydra = b.seed(2).build().unwrap();
    let run = hydra.submit(containers(1600), &BrokerPolicy::RoundRobin).unwrap();
    assert_eq!(run.reports.len(), 4);
    assert_eq!(run.aggregate.tasks, 1600);
    for m in run.per_provider() {
        assert_eq!(m.tasks, 400);
    }
    let counts = hydra.registry().counts();
    assert_eq!(counts.get(&TaskState::Done), Some(&1600));
}

#[test]
fn experiment3a_shape_adding_hpc_keeps_broker_overhead() {
    // Exp 3A (shrunk): the HPC path must not add disproportionate broker
    // overhead per task compared to the cloud path.
    let cloud_only = {
        let hydra = Hydra::builder()
            .simulated_provider(ProviderId::Aws)
            .resource(ResourceRequest::kubernetes(ProviderId::Aws, 1, 16))
            .partition_model(PartitionModel::Scpp)
            .seed(3)
            .build()
            .unwrap();
        let run = hydra.submit(containers(500), &BrokerPolicy::RoundRobin).unwrap();
        run.aggregate.ovh_s / 500.0
    };
    let with_hpc = {
        let hydra = Hydra::builder()
            .simulated_provider(ProviderId::Bridges2)
            .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
            .seed(3)
            .build()
            .unwrap();
        let tasks: Vec<TaskDescription> = (0..500)
            .map(|i| TaskDescription::executable(format!("noop-{i}"), "true"))
            .collect();
        let run = hydra.submit(tasks, &BrokerPolicy::RoundRobin).unwrap();
        run.aggregate.ovh_s / 500.0
    };
    let ratio = with_hpc / cloud_only;
    assert!(
        ratio < 5.0,
        "HPC per-task OVH {with_hpc} vs cloud {cloud_only} (x{ratio})"
    );
}

#[test]
fn experiment3b_shape_heterogeneous_tasks() {
    // Exp 3B (shrunk): heterogeneous durations/sizes across cloud + HPC;
    // everything completes; container/executable routing holds.
    let mut b = Hydra::builder();
    for p in [ProviderId::Jetstream2, ProviderId::Azure] {
        b = b.simulated_provider(p).resource(
            ResourceRequest::kubernetes(p, 2, 16).with_gpus_per_node(8),
        );
    }
    b = b
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1));
    let hydra = b.partition_model(PartitionModel::Scpp).seed(4).build().unwrap();

    let mut rng_state = 12345u64;
    let mut next = move || {
        rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1);
        rng_state >> 33
    };
    let tasks: Vec<TaskDescription> = (0..512)
        .map(|i| {
            let dur = 1.0 + (next() % 10) as f64; // 1-10 s
            let cpus = 1 + (next() % 4) as u32; // 1-4 cpus
            let gpus = (next() % 9) as u32 / 2; // 0-4 gpus (cluster cap 8)
            if i % 2 == 0 {
                TaskDescription::container(format!("con-{i}"), "hydra/sleep")
                    .with_cpus(cpus)
                    .with_gpus(gpus)
                    .with_payload(Payload::Sleep(dur))
            } else {
                TaskDescription::executable(format!("exe-{i}"), "sleep")
                    .with_cpus(cpus)
                    .with_payload(Payload::Sleep(dur))
            }
        })
        .collect();
    let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
    assert_eq!(run.aggregate.tasks, 512);
    assert!(hydra.registry().all_final());
    assert_eq!(run.assignment[&ProviderId::Bridges2].len(), 256);
    assert_eq!(
        run.assignment[&ProviderId::Jetstream2].len() + run.assignment[&ProviderId::Azure].len(),
        256
    );
}

#[test]
fn mixed_caas_hpc_faas_run_by_task_kind() {
    // ISSUE 4: all three service managers — CaaS, HPC batch, FaaS — in
    // one brokered run through the `Hydra` facade. Containers,
    // executables, and functions route to their matching service; every
    // report kind is present and every task traces to a final state.
    let hydra = Hydra::builder()
        .simulated_provider(ProviderId::Jetstream2)
        .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1))
        .simulated_provider(ProviderId::Aws)
        .resource(ResourceRequest::faas(ProviderId::Aws, 32))
        .seed(11)
        .build()
        .unwrap();
    let mut tasks = containers(90);
    tasks.extend((0..90).map(|i| TaskDescription::executable(format!("exe-{i}"), "noop")));
    tasks.extend((0..90).map(|i| {
        TaskDescription::function(format!("fn-{i}"), "pkg.module:handler")
            .with_payload(Payload::Work(0.5))
    }));
    let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
    assert_eq!(run.aggregate.tasks, 270);
    assert_eq!(run.reports.len(), 3);
    assert!(matches!(run.reports[&ProviderId::Jetstream2], ManagerReport::Caas(_)));
    assert!(matches!(run.reports[&ProviderId::Bridges2], ManagerReport::Hpc(_)));
    assert!(matches!(run.reports[&ProviderId::Aws], ManagerReport::Faas(_)));
    for report in run.reports.values() {
        let r = report.run();
        assert_eq!(r.metrics.tasks, 90);
        assert!(r.bulk_bytes > r.bytes_serialized, "{}", r.metrics.provider);
    }
    assert!(hydra.registry().all_final());
    let counts = hydra.registry().counts();
    assert_eq!(counts.get(&TaskState::Done), Some(&270));
}

#[test]
fn mixed_run_with_multi_pilot_sharded_submission() {
    // ISSUE 5 satellite: CaaS + HPC + FaaS end to end with pilots = 4.
    // The HPC bulk payload is sharded across the pilot agents; the
    // unified ManagerRun byte accounting must still reconcile exactly —
    // with n tasks over k payloads: item bytes + (n - k) separators
    // between items + 2k brackets = item_bytes + n + k — and the
    // per-pilot utilization report must cover the whole slice.
    let hydra = Hydra::builder()
        .simulated_provider(ProviderId::Jetstream2)
        .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1).with_pilots(4))
        .simulated_provider(ProviderId::Aws)
        .resource(ResourceRequest::faas(ProviderId::Aws, 32))
        .seed(17)
        .build()
        .unwrap();
    let mut tasks = containers(90);
    tasks.extend((0..90).map(|i| {
        TaskDescription::executable(format!("exe-{i}"), "noop")
            .with_payload(Payload::Work(5.0))
    }));
    tasks.extend((0..90).map(|i| TaskDescription::function(format!("fn-{i}"), "pkg.handler")));
    let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind).unwrap();
    assert_eq!(run.aggregate.tasks, 270);
    assert_eq!(run.reports.len(), 3);

    // Byte accounting reconciles for every manager; exactly for HPC.
    for report in run.reports.values() {
        let r = report.run();
        assert!(r.bulk_bytes > r.bytes_serialized, "{}", r.metrics.provider);
    }
    let hpc = run.reports[&ProviderId::Bridges2].run();
    let (n, payloads) = (90usize, 4usize);
    assert_eq!(
        hpc.bulk_bytes,
        hpc.bytes_serialized + n + payloads,
        "sharded bulk framing must account every byte"
    );

    // The fleet executed the whole HPC slice, each task on one pilot.
    let sim = hpc.detail.hpc_sim().unwrap();
    assert_eq!(sim.pilots.len(), 4);
    assert_eq!(sim.tasks.len(), n);
    assert_eq!(sim.pilots.iter().map(|p| p.tasks_executed).sum::<usize>(), n);
    let mut ids: Vec<u64> = sim.tasks.iter().map(|t| t.task_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "every HPC task completed exactly once");
    for p in &sim.pilots {
        assert!(p.peak_cores_busy <= p.total_cores);
        assert!((0.0..=1.0).contains(&p.utilization));
    }

    assert!(hydra.registry().all_final());
    let counts = hydra.registry().counts();
    assert_eq!(counts.get(&TaskState::Done), Some(&270));
}

#[test]
fn disk_vs_memory_build_modes_same_platform_outcome() {
    // The §6 ablation: identical platform-side results (same pods, same
    // seed); only the broker-side cost differs.
    let dir = std::env::temp_dir().join(format!("hydra-it-disk-{}", std::process::id()));
    let run_with = |mode: PodBuildMode, seed: u64| {
        let hydra = Hydra::builder()
            .simulated_provider(ProviderId::Chameleon)
            .resource(ResourceRequest::kubernetes(ProviderId::Chameleon, 1, 16))
            .partition_model(PartitionModel::Scpp)
            .build_mode(mode)
            .seed(seed)
            .build()
            .unwrap();
        let run = hydra.submit(containers(300), &BrokerPolicy::RoundRobin).unwrap();
        (run.aggregate.ovh_s, run.aggregate.tpt_s)
    };
    let (_ovh_mem, tpt_mem) = run_with(PodBuildMode::Memory, 9);
    let (_ovh_disk, tpt_disk) = run_with(PodBuildMode::Disk { staging_dir: dir.clone() }, 9);
    assert!((tpt_mem - tpt_disk).abs() < 1e-9);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unschedulable_task_fails_workload_cleanly() {
    let hydra = Hydra::builder()
        .simulated_provider(ProviderId::Aws)
        .resource(ResourceRequest::kubernetes(ProviderId::Aws, 1, 8))
        .seed(5)
        .build()
        .unwrap();
    let mut tasks = containers(3);
    tasks[1] = tasks[1].clone().with_cpus(64); // cannot fit any node
    assert!(hydra.submit(tasks, &BrokerPolicy::RoundRobin).is_err());
}

#[test]
fn trace_records_full_lifecycle_ordering() {
    let hydra = Hydra::builder()
        .simulated_provider(ProviderId::Azure)
        .resource(ResourceRequest::kubernetes(ProviderId::Azure, 1, 8))
        .seed(6)
        .build()
        .unwrap();
    hydra.submit(containers(20), &BrokerPolicy::RoundRobin).unwrap();
    let trace = hydra.registry().trace_json();
    let events = trace.as_arr().unwrap();
    assert_eq!(events.len(), 120, "20 tasks x 6 lifecycle states");
    for task in 0..20u64 {
        let mut last = 0u64;
        for e in events.iter().filter(|e| e.get("task").unwrap().as_u64() == Some(task)) {
            let ts = e.get("wall_us").unwrap().as_u64().unwrap();
            assert!(ts >= last);
            last = ts;
        }
    }
}
