//! Multi-cloud brokering: one workload concurrently across four cloud
//! providers plus an HPC pilot (the paper's Experiments 2–3 scenario).
//!
//! ```bash
//! cargo run --release --example multi_cloud
//! ```
//!
//! Demonstrates concurrent service managers, the MCPP/SCPP choice, and
//! the ByTaskKind policy (containers → clouds, executables → HPC).

use hydra::api::task::Payload;
use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::provider::ProviderId;
use hydra::util::fmt_secs;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut b = Hydra::builder()
        .partition_model(PartitionModel::Scpp)
        .seed(7);
    for p in ProviderId::CLOUDS {
        b = b
            .simulated_provider(p)
            .resource(ResourceRequest::kubernetes(p, 1, 16));
    }
    b = b
        .simulated_provider(ProviderId::Bridges2)
        .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1));
    let hydra = b.build()?;

    // Heterogeneous workload: 2,000 containers + 500 MPI-style executables.
    let mut tasks: Vec<TaskDescription> = (0..2000)
        .map(|i| TaskDescription::container(format!("con-{i}"), "hydra/noop:latest"))
        .collect();
    tasks.extend((0..500).map(|i| {
        TaskDescription::executable(format!("mpi-{i}"), "mpirun -n 4 sim")
            .with_cpus(4)
            .with_payload(Payload::Work(20.0))
    }));

    let run = hydra.submit(tasks, &BrokerPolicy::ByTaskKind)?;

    println!("{:<10} {:>7} {:>7} {:>10} {:>12} {:>10}", "PROVIDER", "TASKS", "PODS", "OVH",
             "TH (t/s)", "TPT/TTX");
    for m in run.per_provider() {
        println!(
            "{:<10} {:>7} {:>7} {:>10} {:>12.0} {:>10}",
            m.provider.short_name(),
            m.tasks,
            m.pods,
            fmt_secs(m.ovh.total_s()),
            m.throughput_tps(),
            fmt_secs(m.ttx_s)
        );
    }
    println!(
        "{:<10} {:>7} {:>7} {:>10} {:>12.0} {:>10}",
        "AGGREGATE",
        run.aggregate.tasks,
        run.aggregate.pods,
        fmt_secs(run.aggregate.ovh_s),
        run.aggregate.th_tps,
        fmt_secs(run.aggregate.ttx_s)
    );

    // The paper's Exp 2 consistency check: per-provider OVH under
    // concurrency stays in the same regime as Experiment 1.
    let containers_went_to_clouds = ProviderId::CLOUDS
        .iter()
        .map(|p| run.assignment[p].len())
        .sum::<usize>();
    assert_eq!(containers_went_to_clouds, 2000);
    assert_eq!(run.assignment[&ProviderId::Bridges2].len(), 500);
    println!("routing: {} containers -> clouds, 500 executables -> pilot",
             containers_went_to_clouds);
    Ok(())
}
