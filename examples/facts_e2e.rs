//! End-to-end driver: the full Hydra stack on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example facts_e2e
//! ```
//!
//! This is the repo's composition proof (recorded in EXPERIMENTS.md):
//!
//! 1. **L1/L2 compute** — loads the AOT artifacts (JAX + Pallas lowered to
//!    HLO text) and executes 32 *real* FACTS workflow instances through
//!    PJRT: pre-process → fit (Pallas batched-Gram) → project (Pallas
//!    ensemble kernels) → post-process. Reports the science: sea-level
//!    quantile fans and per-step latencies.
//! 2. **Data manager** — stages the generated input records onto each
//!    target site.
//! 3. **L3 broker** — uses the measured step timings to broker 200 FACTS
//!    workflow instances per platform (Jetstream2, AWS, Bridges2 — the
//!    paper's Fig 5 platform set) and reports TTX/OVH plus the paper's
//!    ordering checks.

use hydra::api::{ProviderConfig, ResourceRequest};
use hydra::broker::data::{DataManager, LocalFs, SimObjectStore};
use hydra::broker::state::TaskRegistry;
use hydra::facts::{self, data, pipeline::FactsPipeline, FactsSize, StepTimings};
use hydra::runtime::{default_artifacts_dir, PjRtRuntime};
use hydra::sim::provider::ProviderId;
use hydra::util::{fmt_secs, Stopwatch};
use hydra::util::stats::Summary;
use hydra::workflow::engine::WorkflowEngine;

const REAL_INSTANCES: usize = 32;
const BROKERED_INSTANCES: usize = 200;
const SIZE: FactsSize = FactsSize::Default;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("=== FACTS end-to-end driver (Experiment 4 workload) ===\n");

    // ---------- 1. Real compute through PJRT --------------------------------
    let rt = PjRtRuntime::load(default_artifacts_dir())?;
    let pipe = FactsPipeline::new(&rt, SIZE);
    println!("[1/3] executing {REAL_INSTANCES} real FACTS instances ({:?} artifacts)...",
             SIZE.suffix());

    // Warm-up: compile all five executables once.
    pipe.run(&data::generate(0, SIZE))?;

    let sw = Stopwatch::start();
    let mut rises = Vec::new();
    let mut per_step = StepTimings::default();
    let mut latencies = Vec::new();
    for seed in 0..REAL_INSTANCES as u64 {
        let t0 = Stopwatch::start();
        let r = pipe.run(&data::generate(seed, SIZE))?;
        latencies.push(t0.elapsed_secs());
        per_step.pre_s += r.timings.pre_s;
        per_step.fit_s += r.timings.fit_s;
        per_step.project_s += r.timings.project_s;
        per_step.post_s += r.timings.post_s;
        rises.push(r.total_rise_mm);
    }
    let wall = sw.elapsed_secs();
    let n = REAL_INSTANCES as f64;
    let timings = StepTimings {
        pre_s: per_step.pre_s / n,
        fit_s: per_step.fit_s / n,
        project_s: per_step.project_s / n,
        post_s: per_step.post_s / n,
    };
    let lat = Summary::of(&latencies);
    let rise = Summary::of(&rises);
    println!("  science: median-total sea-level rise at horizon = {:.1} ± {:.1} mm \
              (min {:.1}, max {:.1})",
             rise.mean, rise.std, rise.min, rise.max);
    println!("  per-instance latency: mean {} (p50 {}, max {}); throughput {:.1} inst/s",
             fmt_secs(lat.mean), fmt_secs(lat.median), fmt_secs(lat.max), n / wall);
    println!("  mean step times: pre {} | fit {} | project {} | post {}",
             fmt_secs(timings.pre_s), fmt_secs(timings.fit_s),
             fmt_secs(timings.project_s), fmt_secs(timings.post_s));
    println!("  PJRT executions: {} ({} executables compiled once)\n",
             rt.executions(), rt.compiled_count());

    // ---------- 2. Data staging ---------------------------------------------
    println!("[2/3] staging input records to each target site...");
    let staging_root = std::env::temp_dir().join("hydra-facts-e2e");
    let mut dm = DataManager::new();
    dm.register("local", Box::new(LocalFs::new(staging_root.clone())?));
    dm.register("jet2", Box::new(SimObjectStore::new(200e6, 0.05)));
    dm.register("aws", Box::new(SimObjectStore::new(120e6, 0.08)));
    dm.register("bridges2", Box::new(SimObjectStore::new(400e6, 0.02)));
    let inputs = data::generate(1, SIZE);
    let blob: Vec<u8> = inputs.temps.data.iter().chain(&inputs.rates.data)
        .flat_map(|f| f.to_le_bytes())
        .collect();
    dm.put("local://facts/input.bin", &blob)?;
    for (site, rep) in dm.stage_to_sites("local://facts/input.bin",
                                         &["jet2", "aws", "bridges2"], "facts/input.bin")? {
        println!("  staged {} bytes -> {site} (simulated {})", rep.bytes,
                 fmt_secs(rep.virtual_secs));
    }
    println!();

    // ---------- 3. Brokered execution at scale -------------------------------
    println!("[3/3] brokering {BROKERED_INSTANCES} FACTS workflows per platform \
              (measured compute x WORK_SCALE={})...", facts::WORK_SCALE);
    let spec = facts::workflow_spec(SIZE);
    println!("  {:<10} {:>7} {:>12} {:>12} {:>14}", "PLATFORM", "CORES", "OVH", "TTX",
             "TTX/workflow");
    let mut ttx_by: Vec<(ProviderId, f64)> = Vec::new();
    for (provider, nodes, req) in [
        (ProviderId::Jetstream2, 8u32,
         ResourceRequest::kubernetes(ProviderId::Jetstream2, 8, 16)),
        (ProviderId::Aws, 8, ResourceRequest::kubernetes(ProviderId::Aws, 8, 16)),
        (ProviderId::Bridges2, 1, ResourceRequest::pilot(ProviderId::Bridges2, 1)),
    ] {
        let engine = WorkflowEngine::new(ProviderConfig::simulated(provider), req);
        let reg = TaskRegistry::new();
        let r = engine.execute_many(&spec, BROKERED_INSTANCES, &reg,
                                    facts::measured_workflow(timings))?;
        assert!(reg.all_final());
        let cores = match provider {
            ProviderId::Bridges2 => 128 * nodes,
            _ => 16 * nodes,
        };
        println!("  {:<10} {:>7} {:>12} {:>12} {:>14}",
                 provider.short_name(), cores, fmt_secs(r.ovh_s()), fmt_secs(r.ttx_s),
                 fmt_secs(r.ttx_s / BROKERED_INSTANCES as f64));
        ttx_by.push((provider, r.ttx_s));
    }

    // Paper Fig 5 ordering: Bridges2 < Jetstream2 < AWS on TTX, and OVH
    // negligible vs makespan.
    let get = |p: ProviderId| ttx_by.iter().find(|(q, _)| *q == p).unwrap().1;
    let (jet2, aws, b2) = (get(ProviderId::Jetstream2), get(ProviderId::Aws),
                           get(ProviderId::Bridges2));
    println!("\n  ordering: BRIDGES2 {} < JET2 {} < AWS {}  (paper: B2 ~5x JET2 ~2.5x AWS)",
             fmt_secs(b2), fmt_secs(jet2), fmt_secs(aws));
    assert!(b2 < jet2 && jet2 < aws, "Fig 5 platform ordering must hold");
    println!("  speedups: JET2/AWS = {:.1}x, B2/JET2 = {:.1}x, B2/AWS = {:.1}x",
             aws / jet2, jet2 / b2, aws / b2);
    std::fs::remove_dir_all(&staging_root).ok();
    println!("\nend-to-end driver complete: all three layers composed.");
    Ok(())
}
